#!/usr/bin/env bash
# Tier-1 gate + kernel perf smoke: what a CI runner executes on every PR.
#
#   scripts/ci.sh
#
# Runs the full test suite (property tests auto-skip when hypothesis is
# absent; heavy replay tests are deselected by default via pytest.ini),
# then the kernel micro-benchmarks in --check mode: fresh rows are gated
# against the committed BENCH_kernels.json (>1.5x us_per_call regression
# or any vmem_bytes/buffer_ratio growth fails the run) before the fresh
# JSON is written for the perf trajectory.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -q
python -m benchmarks.run --only kernels --fast --check --json BENCH_kernels.json
