#!/usr/bin/env bash
# Tier-1 gate + kernel perf smoke: what a CI runner executes on every PR.
#
#   scripts/ci.sh
#
# Runs the full test suite (property tests auto-skip when hypothesis is
# absent; heavy replay tests are deselected by default via pytest.ini) and
# the kernel micro-benchmarks, leaving BENCH_kernels.json for the perf
# trajectory.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -q
python -m benchmarks.run --only kernels --fast --json BENCH_kernels.json
