#!/usr/bin/env bash
# Tier-1 gate + kernel perf smoke: what a CI runner executes on every PR.
#
#   scripts/ci.sh              # fast lane (every PR/push)
#   CI_SLOW=1 scripts/ci.sh    # + slow-marked shard_map/replay tests and
#                              # the chaos/switching subprocess tests
#                              # (nightly lane)
#
# CI
# --
# .github/workflows/ci.yml runs this script UNMODIFIED in two lanes:
#  * `test` (every push/PR): this script as-is, then uploads the fresh
#    BENCH_kernels.json as an artifact so the perf trajectory is
#    recorded per commit.
#  * `slow` (nightly cron + manual dispatch): same script with CI_SLOW=1,
#    which widens the pytest marker expression to include the
#    `slow`-marked shard_map / replay integration tests that pytest.ini
#    deselects by default.
#
# Gate order (each stage fails fast):
#  1. syntax gate: python -m compileall over src/benchmarks/tests — a
#     file that cannot even compile fails before pytest spends minutes.
#  2. collection smoke: pytest --collect-only; an import/collection error
#     cannot hide behind marker deselection.
#  3. baseline hygiene: the committed BENCH_kernels.json must be clean in
#     git — gating fresh numbers against a locally-edited baseline is
#     meaningless (skipped outside a git checkout).
#  4. the full test suite (property tests auto-skip without hypothesis).
#  5. static audit: python -m repro.analysis --check traces (never runs)
#     every registered arch's hot paths against the rule registry —
#     collective census vs the declared layer-grouped schedule, scalar-
#     only psum, decode collective-free, dtype/donation/retrace lints,
#     the Pallas tile/VMEM/grid checks over exported launch metas, the
#     GBA-FLOW staleness-taint dataflow pass (Eq. (1) decay on every
#     gradient path, exact-zero tombstone weights, residual closure,
#     f32-master chain, masked aggregate divisor), and the GBA-RACE
#     lock-discipline lint over the serving modules.  Suppressions live
#     in the checked-in .gba-audit.toml (empty: the tree audits clean);
#     any unsuppressed finding fails the lane with its rule ID.
#  6. kernel micro-benchmarks in --check mode: fresh rows are gated
#     against the committed BENCH_kernels.json (>5x us_per_call
#     regression — interpret-mode wall time is load noise, only
#     catastrophic blowups should trip it — any vmem_bytes/buffer_ratio
#     growth, any launch_ratio shrink, any change of an exact-gated
#     audit_* column, a disappeared row, or a fresh row
#     missing from the committed baseline — i.e. uncommitted drift — all
#     fail) before the fresh JSON is written for the perf trajectory;
#     --summary prints the one-line-per-row table of gated rows.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== syntax gate (compileall) =="
python -m compileall -q src benchmarks tests

echo "== collection smoke (pytest --collect-only) =="
python -m pytest --collect-only -q >/dev/null

if git rev-parse --is-inside-work-tree >/dev/null 2>&1; then
    echo "== baseline hygiene (committed BENCH_kernels.json) =="
    if ! git diff --quiet HEAD -- BENCH_kernels.json; then
        echo "BENCH_kernels.json has uncommitted edits; the perf gate" \
             "only means something against the committed baseline." >&2
        exit 1
    fi
fi

echo "== test suite =="
if [ -n "${CI_SLOW:-}" ]; then
    python -m pytest -q -m "slow or not slow"
else
    python -m pytest -q
fi

echo "== static audit (hot-path rules, all archs) =="
python -m repro.analysis --check --baseline .gba-audit.toml

echo "== kernel perf gate =="
# kernels (interpret-mode micro-benches) + switching (the end-to-end
# sync<->async trajectory: switch_count / time_to_switch_steps monotone,
# strained speedup_vs_sync floored — bench_fig6_switching.run_switching
# spawns the 4-host-device switch_driver subprocess) + serving (the V=1M
# online-learning rows: hit_rate floored, freshness_lag_steps monotone,
# cache geometry and the all-hit-skips-kernel proof exact)
python -m benchmarks.run --only kernels,switching,serving --fast --check \
    --summary --json BENCH_kernels.json
