"""One entry point for every compiled training program: ``build_programs``.

Historically the launcher grew six parallel factories (``make_train_step``,
``make_fused_train_step``, ``make_wire_psum_steps`` and their three state
initializers), and every call site — ``launch.train``, the switching
harness, the auditor, the benches — re-assembled the same (factory, init,
specs, device_put) choreography by hand.  ``build_programs`` owns that
choreography: given a model config (or a raw loss function), a GBA config
and a mode, it returns a :class:`TrainPrograms` bundle holding the jitted
step(s), the initialized (and, when sharded, device_put) state, the flat
layout and the wire state.  The old factory names survive in
``launch.steps`` as thin deprecation shims over the implementations here.

Modes
-----
``pytree``
    The per-leaf XLA step (:func:`make_train_step`): pytree gradient
    accumulator + any optimizer, M-slot GBA under ``lax.cond``.
``fused``
    The single-entry fused flat-buffer step (:func:`jit_fused_train_step`):
    ONE ``gba_apply`` launch per global step (per PS shard when ``mesh``
    has a multi-device ``axis``), state donated, sharded state placed with
    ``fused_state_specs``.
``wire``
    The worker-parallel layer-grouped fused-psum pair
    (:func:`make_wire_psum_steps`): ``(warm_step, compressed_step)`` with
    an optional quantized routing wire; ``wire_state`` initialized and
    placed.  With ``compress=None`` both entries are the same uncompressed
    program — this is also the async program of the switching harness.
``sync_psum``
    The pytree all-reduce sync program
    (:func:`repro.core.gba_shard_map.make_gba_psum_step`) with Adagrad —
    the switching harness's sync mode.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import GBAConfig, ModelConfig
from repro.core.staleness import threshold_decay
from repro.models import transformer as T
from repro.optim import Optimizer, get_optimizer

# the paper's GBA mode runs Adam (Tab. 5.1, "Others"); the 1T MoE cannot hold
# Adam's two f32 moments at 512 chips, so it trains with Adagrad — the very
# optimizer the paper uses for its async mode (DESIGN.md §5)
ARCH_OPTIMIZER = {"kimi-k2-1t-a32b": "adagrad"}
ARCH_ACC_DTYPE = {"kimi-k2-1t-a32b": jnp.bfloat16}


# ---------------------------------------------------------------------------
# loss closure
# ---------------------------------------------------------------------------

def _loss_from_batch(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    memory = batch.get("image_embeds")
    if "frames" in batch:
        memory = T.encode_audio(params, cfg, batch["frames"])
    return T.lm_loss(params, cfg, batch["tokens"], batch["labels"],
                     memory=memory)


def make_loss_fn(cfg: ModelConfig):
    """Standalone ``(params, batch) -> scalar loss`` closure over ``cfg``
    — the signature the shard_map step builders
    (:func:`repro.core.gba_shard_map.make_gba_psum_step` /
    ``make_gba_fused_psum_step``) and the switching harness
    (:class:`repro.launch.switch_driver.SwitchDriver`) consume."""
    def loss_fn(params, batch):
        return _loss_from_batch(params, cfg, batch)
    return loss_fn


def _resolve_loss(cfg: ModelConfig | None, loss_fn: Callable | None):
    if loss_fn is not None:
        return loss_fn
    if cfg is None:
        raise ValueError("build_programs needs a ModelConfig or a loss_fn")
    return make_loss_fn(cfg)


# ---------------------------------------------------------------------------
# pytree mode: per-leaf accumulator + arbitrary optimizer
# ---------------------------------------------------------------------------

def init_train_state(params: Any, optimizer: Optimizer,
                     acc_dtype=jnp.float32) -> dict:
    return {
        "params": params,
        "opt": optimizer.init(params),
        "acc": jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dtype), params),
        "micro": jnp.zeros((), jnp.int32),
        "gstep": jnp.zeros((), jnp.int32),
    }


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    gba: GBAConfig):
    """Returns train_step(state, batch, token) -> (state, loss)."""
    m = gba.buffer_size
    iota = gba.staleness_tolerance

    def train_step(state, batch, token):
        loss, grads = jax.value_and_grad(_loss_from_batch)(
            state["params"], cfg, batch)
        # token-control decay at the step this slot lands in (Eq. 1)
        w = threshold_decay(token[None], state["gstep"], iota)[0]
        acc = jax.tree.map(
            lambda a, g: a + (g.astype(a.dtype) * (w / m).astype(a.dtype)),
            state["acc"], grads)
        micro = state["micro"] + 1
        is_full = (micro % m) == 0

        def apply(operands):
            params, opt, acc = operands
            params, opt = optimizer.update(params, acc, opt)
            zeros = jax.tree.map(jnp.zeros_like, acc)
            return params, opt, zeros

        def noop(operands):
            return operands

        params, opt, acc = jax.lax.cond(
            is_full, apply, noop, (state["params"], state["opt"], acc))
        new_state = {"params": params, "opt": opt, "acc": acc,
                     "micro": micro,
                     "gstep": state["gstep"] + is_full.astype(jnp.int32)}
        return new_state, loss

    return train_step


# ---------------------------------------------------------------------------
# fused mode: flat (M, N) buffer + one gba_apply launch (per PS shard)
# ---------------------------------------------------------------------------

def init_fused_train_state(params: Any, gba: GBAConfig,
                           initial_accum: float = 0.1,
                           mesh: Mesh | None = None, axis: str = "data",
                           tile: int | None = None,
                           layer_groups: bool = True):
    """State for the fused flat-buffer GBA step: params stay a pytree (the
    model consumes them), the Adagrad accumulator and the M-slot gradient
    buffer live flat.  Returns (layout, state).

    With a ``mesh`` whose ``axis`` has >1 device the flat arrays use the
    sharding-aware :class:`repro.core.flat_sharded.ShardedFlatLayout`
    (leaf- and tile-aligned slices, one per PS shard); otherwise the
    single-host ``FlatLayout``.  ``layer_groups`` (default on) makes the
    sharded layout layer-grouped under the model's canonical grouping
    (``models.transformer.param_group_key``): each layer group's extent
    is contiguous and shard-aligned, so the layer-grouped collective
    schedule (``core.gba_shard_map.make_gba_fused_psum_step``) gathers
    one group at a time — per-device peak gathered bytes is the largest
    group (``layout.peak_gather_bytes``), not the whole vector.  Pass
    ``layer_groups=False`` for the ungrouped PR-4 layout.
    """
    if mesh is not None and mesh.shape[axis] > 1:
        from repro.core.flat_sharded import init_sharded_flat_buffer
        from repro.kernels.gba_apply import BLOCK_N
        layout, buffer = init_sharded_flat_buffer(
            params, gba.buffer_size, mesh.shape[axis],
            tile or BLOCK_N,
            group_by=T.param_group_key if layer_groups else None)
        total = layout.padded_total
    else:
        from repro.core.gba import init_flat_buffer
        layout, buffer = init_flat_buffer(params, gba.buffer_size)
        total = layout.total
    state = {
        "params": params,
        "accum": jnp.full((total,), initial_accum, jnp.float32),
        "buffer": buffer,
    }
    return layout, state


def make_fused_train_step(cfg: ModelConfig, gba: GBAConfig, layout,
                          lr: float = 1e-3, eps: float = 1e-10,
                          mesh: Mesh | None = None, axis: str = "data"):
    """Adagrad GBA step on the flat buffer: push the raveled gradient; on
    the M-th microstep ONE ``gba_apply`` kernel launch does the token-decay
    aggregation and the Adagrad update for the whole dense module (vs the
    per-leaf aggregate -> optimizer XLA chain of ``make_train_step``).

    With a ``mesh`` and a :class:`~repro.core.flat_sharded.ShardedFlatLayout`
    the apply branch routes through ``make_sharded_apply``: the buffer
    columns are sliced over ``axis`` (``P(None, axis)``) and every PS
    shard launches ``gba_apply`` on its own contiguous tile-aligned slice
    — still one launch per shard per global step, bit-exact with the
    single-host path.  Without a mesh the layout is the single-host
    ``FlatLayout`` and the apply is one global launch.

    The param ravel/unravel lives INSIDE the apply branch: the M-1
    buffer-fill microsteps pay only the gradient ravel (which feeds the
    buffer anyway), not two whole-model copies.
    """
    from repro.core.gba import flat_buffer_push
    from repro.kernels import ops
    iota = gba.staleness_tolerance

    sharded_apply = None
    if mesh is not None:
        from repro.core.flat_sharded import (ShardedFlatLayout,
                                             make_sharded_apply)
        if isinstance(layout, ShardedFlatLayout):
            sharded_apply = make_sharded_apply(mesh, layout, axis=axis,
                                               iota=iota, eps=eps)

    def train_step(state, batch, token):
        loss, grads = jax.value_and_grad(_loss_from_batch)(
            state["params"], cfg, batch)
        new_buffer, is_full = flat_buffer_push(
            state["buffer"], layout.ravel(grads), token)

        def do_apply(operands):
            params, accum, grads_buf, tokens, step = operands
            if sharded_apply is not None:
                flat_p, new_accum = sharded_apply(
                    layout.ravel(params), accum, grads_buf, tokens, step,
                    jnp.asarray(lr, jnp.float32))
            else:
                flat_p, new_accum = ops.gba_apply_flat(
                    layout.ravel(params), accum, grads_buf, tokens, step,
                    lr, iota=iota, eps=eps)
            return layout.unravel(flat_p), new_accum

        def do_noop(operands):
            params, accum, *_ = operands
            return params, accum

        params, accum = jax.lax.cond(
            is_full, do_apply, do_noop,
            (state["params"], state["accum"], new_buffer["grads"],
             new_buffer["tokens"], state["buffer"]["step"]))
        return {"params": params, "accum": accum,
                "buffer": new_buffer}, loss

    return train_step


def jit_fused_train_step(cfg: ModelConfig, gba: GBAConfig, layout,
                         lr: float = 1e-3, eps: float = 1e-10,
                         mesh: Mesh | None = None, axis: str = "data"):
    """The canonical jitted form of :func:`make_fused_train_step`: state is
    DONATED (``donate_argnums=0``), so the flat (M, shard) buffer, the
    Adagrad accumulator, and the params reuse their buffers every step
    instead of double-allocating.  The static auditor's GBA-DON-001 rule
    checks this property; launchers should jit through here rather than
    wrapping ``make_fused_train_step`` ad hoc."""
    return jax.jit(
        make_fused_train_step(cfg, gba, layout, lr=lr, eps=eps,
                              mesh=mesh, axis=axis),
        donate_argnums=0)


# ---------------------------------------------------------------------------
# wire mode: worker-parallel fused-psum pair with optional quantized wire
# ---------------------------------------------------------------------------

def make_wire_psum_steps(cfg: ModelConfig | None, gba: GBAConfig, layout,
                         mesh: Mesh, *, compress=None, lr: float = 1e-3,
                         eps: float = 1e-10, axis: str = "data",
                         loss_fn: Callable | None = None):
    """Jitted (warm_step, compressed_step) pair for the worker-parallel
    layer-grouped fused-psum schedule (``core.gba_shard_map``) with an
    optional quantized wire (``core.compression.CompressionPolicy``).

    Both phases share the model loss (``_loss_from_batch``, or a caller
    ``loss_fn`` for non-LM workloads).  With a lossy policy the two
    entries are SEPARATE jitted programs — warmup routes f32 (PR-5
    bit-exact), the compressed phase routes int8 + the per-tile sideband
    — and the driver (``launch.train``) switches at the
    ``compress.warmup_steps`` boundary by calling the other function,
    i.e. a re-jit, so each phase's jaxpr carries exactly one wire dtype
    (auditor rule GBA-COLL-005).  With ``compress=None`` / scheme
    ``"none"`` both entries are the same 5-arg uncompressed step.
    """
    from repro.core.gba_shard_map import make_gba_fused_psum_step

    build = functools.partial(
        make_gba_fused_psum_step, mesh, _resolve_loss(cfg, loss_fn), layout,
        iota=gba.staleness_tolerance, lr=lr, eps=eps, axis=axis,
        compress=compress)
    if compress is None or not compress.stateful:
        step = jax.jit(build())
        return step, step
    return jax.jit(build(warm=True)), jax.jit(build(warm=False))


def init_wire_state(layout, compress, mesh: Mesh, axis: str = "data"):
    """Zero per-worker wire state (residual, and momentum for onebit)
    placed with ``distributed.sharding.wire_state_specs`` —
    ``(M, padded_total)`` f32 rows sharded ``P(axis, None)`` so worker
    ``w``'s row lives with worker ``w``.  ``None`` for lossless
    policies."""
    from repro.distributed import sharding as S
    if compress is None or not compress.stateful:
        return None
    wire = compress.init_wire_state(layout, mesh.shape[axis])
    specs = S.wire_state_specs(layout, mesh, compress.scheme, axis)
    return jax.device_put(wire, S.to_named(specs, mesh))


# ---------------------------------------------------------------------------
# the bundle
# ---------------------------------------------------------------------------

@dataclass
class TrainPrograms:
    """Everything a launcher needs to run one training mode: the jitted
    step(s), the initialized (placed) state, the flat layout, the wire
    state and the resolved optimizer.  Which fields are populated depends
    on ``mode`` — see :func:`build_programs`."""

    mode: str
    gba: GBAConfig
    mesh: Mesh | None = None
    axis: str = "data"
    cfg: ModelConfig | None = None
    optimizer: Optimizer | None = None
    layout: Any = None
    state: Any = None
    state_specs: Any = None
    wire_state: Any = None
    # jitted programs
    step: Callable | None = None            # pytree / fused / sync_psum
    warm_step: Callable | None = None       # wire mode (== compressed when
    compressed_step: Callable | None = None  # the policy is lossless)
    compress: Any = None
    notes: dict = field(default_factory=dict)

    def wire_step_for(self, async_steps_taken: int) -> Callable:
        """The wire-mode entry for the given number of async global steps
        already taken: the warmup program until ``compress.warmup_steps``,
        the compressed program after (re-jit boundary, GBA-COLL-005)."""
        if self.compress is None or not self.compress.stateful:
            return self.warm_step
        return (self.warm_step
                if async_steps_taken < self.compress.warmup_steps
                else self.compressed_step)


def _resolve_layer_groups(layer_groups) -> bool:
    if isinstance(layer_groups, str):
        return layer_groups in ("auto", "on")
    return bool(layer_groups)


def build_programs(cfg: ModelConfig | None, gba: GBAConfig, *,
                   mode: str = "fused", params: Any = None,
                   mesh: Mesh | None = None, axis: str = "data",
                   layer_groups: bool | str = "auto", compress=None,
                   optimizer: Optimizer | None = None, lr: float = 1e-3,
                   eps: float = 1e-10, acc_dtype=None,
                   initial_accum: float = 0.1, tile: int | None = None,
                   layout: Any = None, loss_fn: Callable | None = None,
                   place_state: bool = True) -> TrainPrograms:
    """Build the compiled program bundle for one training mode.

    ``cfg`` may be ``None`` when ``loss_fn`` is given (non-LM workloads,
    e.g. the switching harness's toy losses).  ``params`` initializes the
    state; pass ``params=None`` with an explicit ``layout`` to build
    steps only (the switching harness owns its own state).  Sharded fused
    state is device_put with ``fused_state_specs`` unless
    ``place_state=False``.
    """
    from repro.distributed import sharding as S

    if mode == "pytree":
        opt = optimizer or get_optimizer(
            ARCH_OPTIMIZER.get(cfg.name, "adam") if cfg else "adam", lr)
        step = jax.jit(make_train_step(cfg, opt, gba), donate_argnums=0)
        state = None
        if params is not None:
            dt = acc_dtype or (ARCH_ACC_DTYPE.get(cfg.name, jnp.float32)
                               if cfg else jnp.float32)
            state = init_train_state(params, opt, dt)
        return TrainPrograms(mode=mode, gba=gba, mesh=mesh, axis=axis,
                             cfg=cfg, optimizer=opt, state=state, step=step)

    if mode == "fused":
        state = None
        if params is not None:
            layout, state = init_fused_train_state(
                params, gba, initial_accum, mesh, axis, tile,
                _resolve_layer_groups(layer_groups))
        if layout is None:
            raise ValueError("fused mode needs params or an explicit layout")
        step = jit_fused_train_step(cfg, gba, layout, lr=lr, eps=eps,
                                    mesh=mesh, axis=axis)
        specs = None
        from repro.core.flat_sharded import ShardedFlatLayout
        if (state is not None and mesh is not None
                and isinstance(layout, ShardedFlatLayout)):
            pspecs = S.param_specs(
                jax.eval_shape(lambda t: t, params), mesh)
            specs = S.fused_state_specs(layout, mesh, pspecs, axis)
            if place_state:
                state = jax.device_put(state, S.to_named(specs, mesh))
        return TrainPrograms(mode=mode, gba=gba, mesh=mesh, axis=axis,
                             cfg=cfg, layout=layout, state=state,
                             state_specs=specs, step=step)

    if mode == "wire":
        if mesh is None:
            raise ValueError("wire mode needs a mesh")
        state = None
        if layout is None:
            if params is None:
                raise ValueError(
                    "wire mode needs params or an explicit layout")
            layout, fused_state = init_fused_train_state(
                params, gba, initial_accum, mesh, axis, tile,
                _resolve_layer_groups(layer_groups))
            state = {"param_flat": jnp.asarray(layout.ravel(params)),
                     "accum": fused_state["accum"]}
        warm, comp = make_wire_psum_steps(
            cfg, gba, layout, mesh, compress=compress, lr=lr, eps=eps,
            axis=axis, loss_fn=loss_fn)
        wire = init_wire_state(layout, compress, mesh, axis)
        return TrainPrograms(mode=mode, gba=gba, mesh=mesh, axis=axis,
                             cfg=cfg, layout=layout, state=state,
                             wire_state=wire, warm_step=warm,
                             compressed_step=comp, compress=compress)

    if mode == "sync_psum":
        from repro.core.gba_shard_map import make_gba_psum_step
        if mesh is None:
            raise ValueError("sync_psum mode needs a mesh")
        opt = optimizer or get_optimizer("adagrad", lr, eps=eps,
                                         initial_accum=initial_accum)
        step = jax.jit(make_gba_psum_step(
            mesh, _resolve_loss(cfg, loss_fn), opt,
            gba.staleness_tolerance, axis=axis))
        state = None
        if params is not None:
            state = {"params": params, "opt": opt.init(params)}
        return TrainPrograms(mode=mode, gba=gba, mesh=mesh, axis=axis,
                             cfg=cfg, optimizer=opt, state=state, step=step)

    raise ValueError(
        f"unknown mode {mode!r}: expected pytree|fused|wire|sync_psum")
