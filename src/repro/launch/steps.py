"""Sharded train / prefill / decode steps + input_specs for every
(architecture x input-shape) combination.

GBA is a first-class feature of the compiled train step: each step computes
one buffer slot's gradient (the pod acts as one PS "worker"; the slot's
local batch is the input-shape global batch), decays it by the token-control
rule against the current global step, accumulates into the sharded gradient
accumulator, and applies the optimizer every M-th microstep under
``lax.cond`` — the TPU-SPMD rendering of Algorithm 2's buffer (DESIGN.md
§2).  Setting ``buffer_size=1, iota=big`` recovers plain synchronous
training, which is exactly the paper's tuning-free switch.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import GBAConfig, InputShape, ModelConfig
from repro.core.staleness import threshold_decay
from repro.distributed import sharding as S
from repro.distributed.act_sharding import set_act_spec, set_expert_spec
from repro.models import transformer as T
from repro.optim import Optimizer, get_optimizer

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# abstract inputs (deliverable f): ShapeDtypeStruct stand-ins, no allocation
# ---------------------------------------------------------------------------

def model_inputs(cfg: ModelConfig, shape: InputShape) -> dict[str, SDS]:
    """Abstract model inputs for one input shape.  Modality frontends are
    stubs per the carve-out: VLM/audio entries carry precomputed patch /
    frame embeddings of the right shape."""
    B = shape.global_batch
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        out = {"tokens": SDS((B, shape.seq_len), jnp.int32),
               "labels": SDS((B, shape.seq_len), jnp.int32)}
    elif shape.kind == "prefill":
        out = {"tokens": SDS((B, shape.seq_len), jnp.int32)}
    else:  # decode: ONE new token against a cache of seq_len
        # frontend embeddings live in the cache ("memory"), not the batch
        return {"tokens": SDS((B, 1), jnp.int32)}
    if cfg.family == "vlm":
        out["image_embeds"] = SDS((B, cfg.num_image_tokens, cfg.d_model), dt)
    if cfg.family == "audio":
        out["frames"] = SDS((B, cfg.encoder_frames, cfg.d_model), dt)
    return out


def abstract_params(cfg: ModelConfig) -> Any:
    return jax.eval_shape(
        functools.partial(T.init_model, cfg=cfg), jax.random.PRNGKey(0))


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int,
                   memory_len: int = 0) -> Any:
    mem = (SDS((batch, memory_len, cfg.d_model), jnp.dtype(cfg.dtype))
           if memory_len else None)

    def build(m):
        return T.init_cache(cfg, batch, cache_len, memory=m)

    return jax.eval_shape(build, mem) if mem is not None else \
        jax.eval_shape(lambda: T.init_cache(cfg, batch, cache_len))


def _memory_len(cfg: ModelConfig) -> int:
    if cfg.family == "vlm":
        return cfg.num_image_tokens
    if cfg.family == "audio":
        return cfg.encoder_frames
    return 0


# ---------------------------------------------------------------------------
# train step with first-class GBA
# ---------------------------------------------------------------------------

def _loss_from_batch(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    memory = batch.get("image_embeds")
    if "frames" in batch:
        memory = T.encode_audio(params, cfg, batch["frames"])
    return T.lm_loss(params, cfg, batch["tokens"], batch["labels"],
                     memory=memory)


def make_loss_fn(cfg: ModelConfig):
    """Standalone ``(params, batch) -> scalar loss`` closure over ``cfg``
    — the signature the shard_map step builders
    (:func:`repro.core.gba_shard_map.make_gba_psum_step` /
    ``make_gba_fused_psum_step``) and the switching harness
    (:class:`repro.launch.switch_driver.SwitchDriver`) consume."""
    def loss_fn(params, batch):
        return _loss_from_batch(params, cfg, batch)
    return loss_fn


def init_train_state(params: Any, optimizer: Optimizer,
                     acc_dtype=jnp.float32) -> dict:
    return {
        "params": params,
        "opt": optimizer.init(params),
        "acc": jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dtype), params),
        "micro": jnp.zeros((), jnp.int32),
        "gstep": jnp.zeros((), jnp.int32),
    }


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    gba: GBAConfig):
    """Returns train_step(state, batch, token) -> (state, loss)."""
    m = gba.buffer_size
    iota = gba.staleness_tolerance

    def train_step(state, batch, token):
        loss, grads = jax.value_and_grad(_loss_from_batch)(
            state["params"], cfg, batch)
        # token-control decay at the step this slot lands in (Eq. 1)
        w = threshold_decay(token[None], state["gstep"], iota)[0]
        acc = jax.tree.map(
            lambda a, g: a + (g.astype(a.dtype) * (w / m).astype(a.dtype)),
            state["acc"], grads)
        micro = state["micro"] + 1
        is_full = (micro % m) == 0

        def apply(operands):
            params, opt, acc = operands
            params, opt = optimizer.update(params, acc, opt)
            zeros = jax.tree.map(jnp.zeros_like, acc)
            return params, opt, zeros

        def noop(operands):
            return operands

        params, opt, acc = jax.lax.cond(
            is_full, apply, noop, (state["params"], state["opt"], acc))
        new_state = {"params": params, "opt": opt, "acc": acc,
                     "micro": micro,
                     "gstep": state["gstep"] + is_full.astype(jnp.int32)}
        return new_state, loss

    return train_step


def init_fused_train_state(params: Any, gba: GBAConfig,
                           initial_accum: float = 0.1,
                           mesh: Mesh | None = None, axis: str = "data",
                           tile: int | None = None,
                           layer_groups: bool = True):
    """State for the fused flat-buffer GBA step: params stay a pytree (the
    model consumes them), the Adagrad accumulator and the M-slot gradient
    buffer live flat.  Returns (layout, state).

    With a ``mesh`` whose ``axis`` has >1 device the flat arrays use the
    sharding-aware :class:`repro.core.flat_sharded.ShardedFlatLayout`
    (leaf- and tile-aligned slices, one per PS shard); otherwise the
    single-host ``FlatLayout``.  ``layer_groups`` (default on) makes the
    sharded layout layer-grouped under the model's canonical grouping
    (``models.transformer.param_group_key``): each layer group's extent
    is contiguous and shard-aligned, so the layer-grouped collective
    schedule (``core.gba_shard_map.make_gba_fused_psum_step``) gathers
    one group at a time — per-device peak gathered bytes is the largest
    group (``layout.peak_gather_bytes``), not the whole vector.  Pass
    ``layer_groups=False`` for the ungrouped PR-4 layout.
    """
    if mesh is not None and mesh.shape[axis] > 1:
        from repro.core.flat_sharded import init_sharded_flat_buffer
        from repro.kernels.gba_apply import BLOCK_N
        layout, buffer = init_sharded_flat_buffer(
            params, gba.buffer_size, mesh.shape[axis],
            tile or BLOCK_N,
            group_by=T.param_group_key if layer_groups else None)
        total = layout.padded_total
    else:
        from repro.core.gba import init_flat_buffer
        layout, buffer = init_flat_buffer(params, gba.buffer_size)
        total = layout.total
    state = {
        "params": params,
        "accum": jnp.full((total,), initial_accum, jnp.float32),
        "buffer": buffer,
    }
    return layout, state


def fused_state_specs(layout, mesh: Mesh, pspecs: Any,
                      axis: str = "data") -> dict:
    """PartitionSpecs matching ``init_fused_train_state``'s output —
    canonical constructor in ``distributed.sharding``."""
    return S.fused_state_specs(layout, mesh, pspecs, axis)


def make_fused_train_step(cfg: ModelConfig, gba: GBAConfig, layout,
                          lr: float = 1e-3, eps: float = 1e-10,
                          mesh: Mesh | None = None, axis: str = "data"):
    """Adagrad GBA step on the flat buffer: push the raveled gradient; on
    the M-th microstep ONE ``gba_apply`` kernel launch does the token-decay
    aggregation and the Adagrad update for the whole dense module (vs the
    per-leaf aggregate -> optimizer XLA chain of ``make_train_step``).

    With a ``mesh`` and a :class:`~repro.core.flat_sharded.ShardedFlatLayout`
    the apply branch routes through ``make_sharded_apply``: the buffer
    columns are sliced over ``axis`` (``P(None, axis)``) and every PS
    shard launches ``gba_apply`` on its own contiguous tile-aligned slice
    — still one launch per shard per global step, bit-exact with the
    single-host path.  Without a mesh the layout is the single-host
    ``FlatLayout`` and the apply is one global launch.

    The param ravel/unravel lives INSIDE the apply branch: the M-1
    buffer-fill microsteps pay only the gradient ravel (which feeds the
    buffer anyway), not two whole-model copies.
    """
    from repro.core.gba import flat_buffer_push
    from repro.kernels import ops
    iota = gba.staleness_tolerance

    sharded_apply = None
    if mesh is not None:
        from repro.core.flat_sharded import (ShardedFlatLayout,
                                             make_sharded_apply)
        if isinstance(layout, ShardedFlatLayout):
            sharded_apply = make_sharded_apply(mesh, layout, axis=axis,
                                               iota=iota, eps=eps)

    def train_step(state, batch, token):
        loss, grads = jax.value_and_grad(_loss_from_batch)(
            state["params"], cfg, batch)
        new_buffer, is_full = flat_buffer_push(
            state["buffer"], layout.ravel(grads), token)

        def do_apply(operands):
            params, accum, grads_buf, tokens, step = operands
            if sharded_apply is not None:
                flat_p, new_accum = sharded_apply(
                    layout.ravel(params), accum, grads_buf, tokens, step,
                    jnp.asarray(lr, jnp.float32))
            else:
                flat_p, new_accum = ops.gba_apply_flat(
                    layout.ravel(params), accum, grads_buf, tokens, step,
                    lr, iota=iota, eps=eps)
            return layout.unravel(flat_p), new_accum

        def do_noop(operands):
            params, accum, *_ = operands
            return params, accum

        params, accum = jax.lax.cond(
            is_full, do_apply, do_noop,
            (state["params"], state["accum"], new_buffer["grads"],
             new_buffer["tokens"], state["buffer"]["step"]))
        return {"params": params, "accum": accum,
                "buffer": new_buffer}, loss

    return train_step


def jit_fused_train_step(cfg: ModelConfig, gba: GBAConfig, layout,
                         lr: float = 1e-3, eps: float = 1e-10,
                         mesh: Mesh | None = None, axis: str = "data"):
    """The canonical jitted form of :func:`make_fused_train_step`: state is
    DONATED (``donate_argnums=0``), so the flat (M, shard) buffer, the
    Adagrad accumulator, and the params reuse their buffers every step
    instead of double-allocating.  The static auditor's GBA-DON-001 rule
    checks this property; launchers should jit through here rather than
    wrapping ``make_fused_train_step`` ad hoc."""
    return jax.jit(
        make_fused_train_step(cfg, gba, layout, lr=lr, eps=eps,
                              mesh=mesh, axis=axis),
        donate_argnums=0)


def make_wire_psum_steps(cfg: ModelConfig, gba: GBAConfig, layout,
                         mesh: Mesh, *, compress=None, lr: float = 1e-3,
                         eps: float = 1e-10, axis: str = "data"):
    """Jitted (warm_step, compressed_step) pair for the worker-parallel
    layer-grouped fused-psum schedule (``core.gba_shard_map``) with an
    optional quantized wire (``core.compression.CompressionPolicy``).

    Both phases share the model loss (``_loss_from_batch``).  With a
    lossy policy the two entries are SEPARATE jitted programs — warmup
    routes f32 (PR-5 bit-exact), the compressed phase routes int8 + the
    per-tile sideband — and the driver (``launch.train``) switches at the
    ``compress.warmup_steps`` boundary by calling the other function,
    i.e. a re-jit, so each phase's jaxpr carries exactly one wire dtype
    (auditor rule GBA-COLL-005).  With ``compress=None`` / scheme
    ``"none"`` both entries are the same 5-arg uncompressed step.
    """
    from repro.core.gba_shard_map import make_gba_fused_psum_step

    def loss_fn(params, batch):
        return _loss_from_batch(params, cfg, batch)

    build = functools.partial(
        make_gba_fused_psum_step, mesh, loss_fn, layout,
        iota=gba.staleness_tolerance, lr=lr, eps=eps, axis=axis,
        compress=compress)
    if compress is None or not compress.stateful:
        step = jax.jit(build())
        return step, step
    return jax.jit(build(warm=True)), jax.jit(build(warm=False))


def init_wire_state(layout, compress, mesh: Mesh, axis: str = "data"):
    """Zero per-worker wire state (residual, and momentum for onebit)
    placed with ``distributed.sharding.wire_state_specs`` —
    ``(M, padded_total)`` f32 rows sharded ``P(axis, None)`` so worker
    ``w``'s row lives with worker ``w``.  ``None`` for lossless
    policies."""
    if compress is None or not compress.stateful:
        return None
    wire = compress.init_wire_state(layout, mesh.shape[axis])
    specs = S.wire_state_specs(layout, mesh, compress.scheme, axis)
    return jax.device_put(wire, S.to_named(specs, mesh))


def opt_state_specs(optimizer: Optimizer, pspecs: Any) -> Any:
    if optimizer.name == "adam":
        return {"m": pspecs, "v": pspecs, "count": P()}
    if optimizer.name == "adagrad":
        return {"accum": pspecs}
    return {}


def train_state_specs(optimizer: Optimizer, pspecs: Any) -> dict:
    return {
        "params": pspecs,
        "opt": opt_state_specs(optimizer, pspecs),
        "acc": pspecs,
        "micro": P(),
        "gstep": P(),
    }


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        memory = batch.get("image_embeds")
        if "frames" in batch:
            memory = T.encode_audio(params, cfg, batch["frames"])
        return T.prefill(params, cfg, batch["tokens"], memory=memory)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, token, cache):
        logits, cache = T.decode_step(params, cfg, token, cache)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, cache

    return decode_step


# ---------------------------------------------------------------------------
# jit assembly per (arch x shape x mesh)
# ---------------------------------------------------------------------------

# the paper's GBA mode runs Adam (Tab. 5.1, "Others"); the 1T MoE cannot hold
# Adam's two f32 moments at 512 chips, so it trains with Adagrad — the very
# optimizer the paper uses for its async mode (DESIGN.md §5)
ARCH_OPTIMIZER = {"kimi-k2-1t-a32b": "adagrad"}
ARCH_ACC_DTYPE = {"kimi-k2-1t-a32b": jnp.bfloat16}


def build_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
               gba: GBAConfig | None = None, serve_tp: bool = False,
               moe_ep: bool = False):
    """Returns (jitted_fn, abstract_args tuple) ready for .lower()."""
    gba = gba or GBAConfig(local_batch=shape.global_batch, buffer_size=8)
    if moe_ep and cfg.num_experts \
            and cfg.num_experts % mesh.shape["model"] == 0:
        set_expert_spec(NamedSharding(mesh, P("model", None, None)))
    else:
        set_expert_spec(None)
    # pin the residual stream to batch-sharded layout (act_sharding docs);
    # long_500k (batch=1) replicates instead
    dp = S.data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    act_spec = P(dp, None, None) if shape.global_batch % dp_size == 0 \
        else P(None, None, None)
    set_act_spec(NamedSharding(mesh, act_spec))
    pshapes = abstract_params(cfg)
    if serve_tp and shape.kind != "train":
        pspecs = S.serve_param_specs(pshapes, mesh)
    else:
        pspecs = S.param_specs(pshapes, mesh)
    named = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda s: isinstance(s, P))
    binputs = model_inputs(cfg, shape)
    bspecs = {k: S.batch_partition(mesh, v.shape[0], v.ndim)
              for k, v in binputs.items()}

    if shape.kind == "train":
        opt = get_optimizer(ARCH_OPTIMIZER.get(cfg.name, "adam"), 1e-3)
        acc_dt = ARCH_ACC_DTYPE.get(cfg.name, jnp.float32)
        sspecs = train_state_specs(opt, pspecs)
        state_sds = jax.eval_shape(
            functools.partial(init_train_state, optimizer=opt,
                              acc_dtype=acc_dt), pshapes)
        # donate the state like launch.train does — without this the
        # dryrun-lowered step double-allocates params + opt + acc
        # (auditor rule GBA-DON-001)
        fn = jax.jit(make_train_step(cfg, opt, gba),
                     in_shardings=(named(sspecs), named(bspecs),
                                   NamedSharding(mesh, P())),
                     out_shardings=(named(sspecs), None),
                     donate_argnums=0)
        return fn, (state_sds, binputs, SDS((), jnp.int32))

    if shape.kind == "prefill":
        cache_sds = abstract_cache(cfg, shape.global_batch, shape.seq_len,
                                   _memory_len(cfg))
        cspecs = S.cache_specs(cache_sds, cfg, mesh, shape.global_batch)
        fn = jax.jit(make_prefill_step(cfg),
                     in_shardings=(named(pspecs), named(bspecs)),
                     out_shardings=(None, named(cspecs)))
        return fn, (pshapes, binputs)

    # decode
    mem_len = _memory_len(cfg)
    cache_sds = abstract_cache(cfg, shape.global_batch, shape.seq_len,
                               mem_len)
    cspecs = S.cache_specs(cache_sds, cfg, mesh, shape.global_batch)
    tok_sds = binputs["tokens"]
    fn = jax.jit(make_decode_step(cfg),
                 in_shardings=(named(pspecs), named(bspecs["tokens"]),
                               named(cspecs)),
                 out_shardings=(named(bspecs["tokens"]), None,
                                named(cspecs)))
    return fn, (pshapes, tok_sds, cache_sds)
