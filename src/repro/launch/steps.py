"""Sharded train / prefill / decode steps + input_specs for every
(architecture x input-shape) combination.

GBA is a first-class feature of the compiled train step: each step computes
one buffer slot's gradient (the pod acts as one PS "worker"; the slot's
local batch is the input-shape global batch), decays it by the token-control
rule against the current global step, accumulates into the sharded gradient
accumulator, and applies the optimizer every M-th microstep under
``lax.cond`` — the TPU-SPMD rendering of Algorithm 2's buffer (DESIGN.md
§2).  Setting ``buffer_size=1, iota=big`` recovers plain synchronous
training, which is exactly the paper's tuning-free switch.

.. deprecated::
    The six training-program factories that used to live here
    (``make_train_step`` / ``init_train_state`` / ``make_fused_train_step``
    / ``init_fused_train_state`` / ``make_wire_psum_steps`` /
    ``init_wire_state``, plus ``jit_fused_train_step``) moved to
    :mod:`repro.launch.programs`; build them through
    :func:`repro.launch.programs.build_programs` instead.  The names here
    are thin shims that forward to the same implementations with a
    ``DeprecationWarning``, so existing call sites keep working
    bit-for-bit.  Serve-step builders and the dryrun ``build_step``
    assembly remain canonical here.
"""
from __future__ import annotations

import functools
import warnings
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import GBAConfig, InputShape, ModelConfig
from repro.distributed import sharding as S
from repro.distributed.act_sharding import set_act_spec, set_expert_spec
from repro.launch import programs as _P
from repro.launch.programs import (  # noqa: F401  (re-exports)
    ARCH_ACC_DTYPE,
    ARCH_OPTIMIZER,
    _loss_from_batch,
    make_loss_fn,
)
from repro.models import transformer as T
from repro.optim import Optimizer, get_optimizer

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# abstract inputs (deliverable f): ShapeDtypeStruct stand-ins, no allocation
# ---------------------------------------------------------------------------

def model_inputs(cfg: ModelConfig, shape: InputShape) -> dict[str, SDS]:
    """Abstract model inputs for one input shape.  Modality frontends are
    stubs per the carve-out: VLM/audio entries carry precomputed patch /
    frame embeddings of the right shape."""
    B = shape.global_batch
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        out = {"tokens": SDS((B, shape.seq_len), jnp.int32),
               "labels": SDS((B, shape.seq_len), jnp.int32)}
    elif shape.kind == "prefill":
        out = {"tokens": SDS((B, shape.seq_len), jnp.int32)}
    else:  # decode: ONE new token against a cache of seq_len
        # frontend embeddings live in the cache ("memory"), not the batch
        return {"tokens": SDS((B, 1), jnp.int32)}
    if cfg.family == "vlm":
        out["image_embeds"] = SDS((B, cfg.num_image_tokens, cfg.d_model), dt)
    if cfg.family == "audio":
        out["frames"] = SDS((B, cfg.encoder_frames, cfg.d_model), dt)
    return out


def abstract_params(cfg: ModelConfig) -> Any:
    return jax.eval_shape(
        functools.partial(T.init_model, cfg=cfg), jax.random.PRNGKey(0))


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int,
                   memory_len: int = 0) -> Any:
    mem = (SDS((batch, memory_len, cfg.d_model), jnp.dtype(cfg.dtype))
           if memory_len else None)

    def build(m):
        return T.init_cache(cfg, batch, cache_len, memory=m)

    return jax.eval_shape(build, mem) if mem is not None else \
        jax.eval_shape(lambda: T.init_cache(cfg, batch, cache_len))


def _memory_len(cfg: ModelConfig) -> int:
    if cfg.family == "vlm":
        return cfg.num_image_tokens
    if cfg.family == "audio":
        return cfg.encoder_frames
    return 0


# ---------------------------------------------------------------------------
# deprecation shims over repro.launch.programs
# ---------------------------------------------------------------------------

def _shim(name: str, fn):
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        warnings.warn(
            f"repro.launch.steps.{name} is deprecated; build training "
            "programs through repro.launch.programs.build_programs "
            "(or import the factory from repro.launch.programs).",
            DeprecationWarning, stacklevel=2)
        return fn(*args, **kwargs)
    return wrapper


init_train_state = _shim("init_train_state", _P.init_train_state)
make_train_step = _shim("make_train_step", _P.make_train_step)
init_fused_train_state = _shim("init_fused_train_state",
                               _P.init_fused_train_state)
make_fused_train_step = _shim("make_fused_train_step",
                              _P.make_fused_train_step)
jit_fused_train_step = _shim("jit_fused_train_step", _P.jit_fused_train_step)
make_wire_psum_steps = _shim("make_wire_psum_steps", _P.make_wire_psum_steps)
init_wire_state = _shim("init_wire_state", _P.init_wire_state)


def fused_state_specs(layout, mesh: Mesh, pspecs: Any,
                      axis: str = "data") -> dict:
    """PartitionSpecs matching ``init_fused_train_state``'s output —
    canonical constructor in ``distributed.sharding``."""
    return S.fused_state_specs(layout, mesh, pspecs, axis)


def opt_state_specs(optimizer: Optimizer, pspecs: Any) -> Any:
    if optimizer.name == "adam":
        return {"m": pspecs, "v": pspecs, "count": P()}
    if optimizer.name == "adagrad":
        return {"accum": pspecs}
    return {}


def train_state_specs(optimizer: Optimizer, pspecs: Any) -> dict:
    return {
        "params": pspecs,
        "opt": opt_state_specs(optimizer, pspecs),
        "acc": pspecs,
        "micro": P(),
        "gstep": P(),
    }


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        memory = batch.get("image_embeds")
        if "frames" in batch:
            memory = T.encode_audio(params, cfg, batch["frames"])
        return T.prefill(params, cfg, batch["tokens"], memory=memory)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, token, cache):
        logits, cache = T.decode_step(params, cfg, token, cache)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, cache

    return decode_step


# ---------------------------------------------------------------------------
# jit assembly per (arch x shape x mesh)
# ---------------------------------------------------------------------------

def build_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
               gba: GBAConfig | None = None, serve_tp: bool = False,
               moe_ep: bool = False):
    """Returns (jitted_fn, abstract_args tuple) ready for .lower()."""
    gba = gba or GBAConfig(local_batch=shape.global_batch, buffer_size=8)
    if moe_ep and cfg.num_experts \
            and cfg.num_experts % mesh.shape["model"] == 0:
        set_expert_spec(NamedSharding(mesh, P("model", None, None)))
    else:
        set_expert_spec(None)
    # pin the residual stream to batch-sharded layout (act_sharding docs);
    # long_500k (batch=1) replicates instead
    dp = S.data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    act_spec = P(dp, None, None) if shape.global_batch % dp_size == 0 \
        else P(None, None, None)
    set_act_spec(NamedSharding(mesh, act_spec))
    pshapes = abstract_params(cfg)
    if serve_tp and shape.kind != "train":
        pspecs = S.serve_param_specs(pshapes, mesh)
    else:
        pspecs = S.param_specs(pshapes, mesh)
    named = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda s: isinstance(s, P))
    binputs = model_inputs(cfg, shape)
    bspecs = {k: S.batch_partition(mesh, v.shape[0], v.ndim)
              for k, v in binputs.items()}

    if shape.kind == "train":
        opt = get_optimizer(ARCH_OPTIMIZER.get(cfg.name, "adam"), 1e-3)
        acc_dt = ARCH_ACC_DTYPE.get(cfg.name, jnp.float32)
        sspecs = train_state_specs(opt, pspecs)
        state_sds = jax.eval_shape(
            functools.partial(_P.init_train_state, optimizer=opt,
                              acc_dtype=acc_dt), pshapes)
        # donate the state like launch.train does — without this the
        # dryrun-lowered step double-allocates params + opt + acc
        # (auditor rule GBA-DON-001)
        fn = jax.jit(_P.make_train_step(cfg, opt, gba),
                     in_shardings=(named(sspecs), named(bspecs),
                                   NamedSharding(mesh, P())),
                     out_shardings=(named(sspecs), None),
                     donate_argnums=0)
        return fn, (state_sds, binputs, SDS((), jnp.int32))

    if shape.kind == "prefill":
        cache_sds = abstract_cache(cfg, shape.global_batch, shape.seq_len,
                                   _memory_len(cfg))
        cspecs = S.cache_specs(cache_sds, cfg, mesh, shape.global_batch)
        fn = jax.jit(make_prefill_step(cfg),
                     in_shardings=(named(pspecs), named(bspecs)),
                     out_shardings=(None, named(cspecs)))
        return fn, (pshapes, binputs)

    # decode
    mem_len = _memory_len(cfg)
    cache_sds = abstract_cache(cfg, shape.global_batch, shape.seq_len,
                               mem_len)
    cspecs = S.cache_specs(cache_sds, cfg, mesh, shape.global_batch)
    tok_sds = binputs["tokens"]
    fn = jax.jit(make_decode_step(cfg),
                 in_shardings=(named(pspecs), named(bspecs["tokens"]),
                               named(cspecs)),
                 out_shardings=(named(bspecs["tokens"]), None,
                                named(cspecs)))
    return fn, (pshapes, tok_sds, cache_sds)
