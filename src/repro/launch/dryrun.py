"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all

Proves the distribution config is coherent without hardware: 512 placeholder
CPU devices stand in for 2 TPU v5e pods; ``.lower().compile()`` must succeed
and we record memory_analysis / cost_analysis / collective bytes for the
roofline (EXPERIMENTS.md §Dry-run, §Roofline).
"""
# The XLA flag MUST precede any other import (jax locks device count on
# first init) — see task spec.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.launch.steps import build_step                     # noqa: E402
from repro.launch.variants import VARIANTS                    # noqa: E402

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[16,512]' -> bytes.  Tuple shapes handled by summing parts."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op in the (SPMD) HLO."""
    out: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.lstrip()
        # ops look like:  %name = f32[..]{..} all-reduce(...), or
        #                 ROOT %x = (f32[..], ..) all-gather-start(...)
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)$", s)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.search(r"\b(all-gather|all-reduce|reduce-scatter|"
                        r"all-to-all|collective-permute)(?:-start)?\(", rhs)
        if not opm:
            continue
        # -done ops would double count; they carry the same bytes as -start
        if re.search(r"\b[a-z-]+-done\(", rhs):
            continue
        shape_part = rhs[:opm.start()]
        out[opm.group(1)] += _shape_bytes(shape_part)
    return out


def dryrun_one(arch: str, shape_name: str, multi_pod: bool,
               verbose: bool = True, variant: str = "baseline") -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "kind": shape.kind, "variant": variant}
    if shape_name == "long_500k" and not cfg.supports_long_context:
        rec["status"] = "skipped"
        rec["reason"] = ("pure full-attention architecture; 500k decode "
                        "requires sub-quadratic/windowed attention "
                        "(DESIGN.md §4)")
        return rec
    cfg, opts = VARIANTS[variant](cfg, {})
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with mesh:
            fn, args = build_step(cfg, shape, mesh, **opts)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = collective_bytes(compiled.as_text())
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "collective_bytes": coll,
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_bytes":
                    getattr(mem, "generated_code_size_in_bytes", 0),
            },
        })
        if verbose:
            print(f"[ok] {arch} x {shape_name} x {rec['mesh']} "
                  f"[{variant}]: "
                  f"flops={rec['flops']:.3e} "
                  f"bytes={rec['bytes_accessed']:.3e} "
                  f"coll={sum(coll.values()):.3e} "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)
            print(f"     memory: {rec['memory']}", flush=True)
    except Exception as e:  # a failure here is a bug in our sharding
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        if verbose:
            print(f"[FAIL] {arch} x {shape_name} x {rec['mesh']}", flush=True)
            traceback.print_exc()
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=tuple(VARIANTS))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, "dry-run expects 512 host devices"

    keyof = lambda r: (r["arch"], r["shape"], r["mesh"],
                   r.get("variant", "baseline"))
    merged: dict = {}
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            merged = {keyof(r): r for r in json.load(f)}

    def save(rec):
        merged[keyof(rec)] = rec
        if args.out:
            with open(args.out, "w") as f:
                json.dump(list(merged.values()), f, indent=1)

    records = []
    if args.all:
        for arch in ARCH_IDS:
            for shape_name in INPUT_SHAPES:
                for mp in (False, True):
                    key = (arch, shape_name,
                           "2x16x16" if mp else "16x16", "baseline")
                    prev = merged.get(key)
                    if prev and prev.get("status") in ("ok", "skipped"):
                        records.append(prev)   # resume support
                        continue
                    rec = dryrun_one(arch, shape_name, mp)
                    records.append(rec)
                    save(rec)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        rec = dryrun_one(args.arch, args.shape, args.multi_pod,
                         variant=args.variant)
        records.append(rec)
        save(rec)
    ok = sum(r["status"] == "ok" for r in records)
    sk = sum(r["status"] == "skipped" for r in records)
    fl = sum(r["status"] == "failed" for r in records)
    print(f"\ndry-run: {ok} ok, {sk} skipped, {fl} FAILED")
    if fl:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
