"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        --steps 20 --reduced [--buffer 8] [--iota 4]

On real TPU hardware this launches the sharded GBA train step on the
production mesh; in this CPU container use ``--reduced`` (smoke variant,
1-device mesh) — the full configs are exercised by launch.dryrun.

``--vocab N`` runs the sparse-module smoke instead: N-row hashed embedding
table trained through the DMA-streamed pooled-lookup kernels on the smoke
mesh.  ``--vocab 1000000`` exercises a table ~250x larger than a VMEM bank
without ever materializing a (V, D) VMEM block (the streamed pipeline
holds O(block) scratch; see repro.kernels.embedding_bag).

    PYTHONPATH=src python -m repro.launch.train --vocab 1000000 --steps 5 \
        [--embed-dim 16] [--block-v 512] [--block-d 128] [--chunk-e 256]

``--mesh DxT`` (e.g. ``--mesh 4x1``) trains on an explicit (data, model)
mesh instead of the smoke/production default; with ``--fused`` the GBA
state uses the sharding-aware flat layout — buffer columns sliced across
the ``data`` axis, ONE ``gba_apply`` launch per PS shard per global step
(core.flat_sharded).  ``--layer-groups`` (default on for ``--fused`` with
a multi-device ``--mesh``) makes that layout layer-grouped under the
model's canonical grouping, so the grouped collective schedule
(core.gba_shard_map) gathers one layer group at a time — per-device peak
gathered bytes is the largest group, not the whole flat vector.  On CPU, pair it with ``--host-devices N`` to force
N host-platform devices (sets ``--xla_force_host_platform_device_count``
before jax device init — the same path the shard_map tests use):

    PYTHONPATH=src python -m repro.launch.train --arch kimi-k2-1t-a32b \
        --reduced --fused --mesh 4x1 --host-devices 4 --steps 8

``--compress {int8,onebit}`` (with ``--fused`` and a multi-device data
axis) switches to the worker-parallel fused-psum loop with a quantized
routing wire (core.gba_shard_map + core.compression): f32 warmup for
``--compress-warmup`` global steps, then int8 payload + per-tile f32
sideband with per-shard error feedback (~0.25x wire bytes).  Sync /
single-device runs auto-fall back to ``none``:

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-780m \
        --reduced --fused --mesh 4x1 --host-devices 4 --steps 8 \
        --compress int8 --compress-warmup 2

``--autoswitch`` (with a multi-device data axis) hands the run to the
end-to-end switching harness (launch.switch_driver): the REAL compiled
sync (pytree psum + Adagrad) and async (token-controlled fused-psum)
steps for this arch run under a ``--plan`` fault plan (quiet|strained),
an AutoSwitchController decides the mode from live per-worker rates, and
the sync<->async swaps carry the flat params/accum across bit-exactly:

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-780m \
        --reduced --mesh 4x1 --host-devices 4 --autoswitch \
        --plan strained --batches 120
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# --host-devices must land in XLA_FLAGS before ANY jax backend init, and
# the repro imports below create arrays at import time — so peek at argv
# here instead of waiting for argparse (both --host-devices N and
# --host-devices=N forms; a malformed value is left for argparse to
# report)
def _peek_host_devices(argv: list[str]) -> str | None:
    for i, a in enumerate(argv):
        if a == "--host-devices" and i + 1 < len(argv):
            return argv[i + 1]
        if a.startswith("--host-devices="):
            return a.split("=", 1)[1]
    return None


_n = _peek_host_devices(sys.argv)
if _n and _n.isdigit():
    os.environ["XLA_FLAGS"] = (
        f"{os.environ.get('XLA_FLAGS', '')} "
        f"--xla_force_host_platform_device_count={_n}").strip()

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import GBAConfig
from repro.data import make_lm_stream
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.launch.programs import ARCH_OPTIMIZER, build_programs
from repro.models import transformer as T
from repro.optim import get_optimizer


def run_embedding_smoke(args) -> None:
    """Sparse-module smoke: a --vocab-row hashed table trained end-to-end
    through the streamed pooled-lookup kernels (forward tile stream +
    sorted-scatter backward) on the smoke mesh.  The (V, D) table lives in
    HBM for both passes; VMEM holds only the double-buffered blocks."""
    from repro import embeddings
    from repro.kernels.embedding_bag import (BLOCK_D, BLOCK_V, CHUNK_E,
                                             stream_vmem_bytes)
    cap, dim, f = args.vocab, args.embed_dim, 26
    stream = embeddings.StreamConfig(
        block_v=args.block_v or None, block_d=args.block_d or None,
        chunk_e=args.chunk_e or None)
    vm = stream_vmem_bytes(dim, block_v=stream.block_v or BLOCK_V,
                           block_d=stream.block_d or BLOCK_D,
                           chunk_e=stream.chunk_e or CHUNK_E)
    mesh = make_smoke_mesh()
    tbl = embeddings.init_table(jax.random.PRNGKey(0), cap, dim)
    print(f"embedding smoke: V={cap:,} D={dim} "
          f"table={cap * dim * 4 / 1e6:.0f}MB HBM-resident; "
          f"streamed VMEM fwd={vm['fwd']:,}B bwd={vm['bwd']:,}B "
          f"(block-bounded, V-independent)")

    def loss_fn(table_arr, ids, labels):
        pooled = embeddings.pooled_lookup(
            embeddings.EmbeddingTable(table_arr, tbl.last_update), ids,
            stream=stream)
        logit = pooled.sum(axis=-1)
        return jnp.mean(jnp.maximum(logit, 0) - logit * labels
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    table_arr = tbl.table
    t0 = time.perf_counter()
    with mesh:
        for i in range(args.steps):
            key = jax.random.PRNGKey(1000 + i)
            raw = jax.random.randint(key, (args.batch, f), 0, 1 << 30)
            ids = embeddings.hash_ids(raw, cap)
            labels = (jax.random.uniform(key, (args.batch,)) < 0.5
                      ).astype(jnp.float32)
            loss, gtable = grad_fn(table_arr, ids, labels)
            table_arr = table_arr - args.lr * gtable
            rate = (i + 1) * args.batch * f / (time.perf_counter() - t0)
            print(f"step {i:4d}  loss {float(loss):.4f}  "
                  f"{rate:,.0f} lookups/s")
    assert jnp.isfinite(loss), "embedding smoke diverged"


def run_wire_train(args, cfg, mesh, gba, stream, params,
                   scheme: str) -> None:
    """Worker-parallel fused-psum loop with the quantized wire: every
    device along ``data`` is its own PS worker AND shard
    (core.gba_shard_map), gradients route worker->shard per layer group,
    and past ``--compress-warmup`` global steps the routing payload is
    int8 (+ per-tile f32 sideband) with per-shard error feedback.  The
    warmup->compressed switch is a re-jit: two separate jitted programs,
    each with exactly one wire dtype (auditor rule GBA-COLL-005)."""
    from repro.core.compression import CompressionPolicy
    m = mesh.shape["data"]
    pol = CompressionPolicy(scheme=scheme,
                            warmup_steps=args.compress_warmup)
    progs = build_programs(cfg, gba, mode="wire", params=params, mesh=mesh,
                           compress=pol, lr=args.lr)
    layout = progs.layout
    warm_step, comp_step = progs.warm_step, progs.compressed_step
    wire = progs.wire_state
    param_flat = progs.state["param_flat"]
    accum = progs.state["accum"]
    f32_bytes = layout.padded_total * 4
    print(f"quantized wire ({scheme}): {m} workers x {layout.num_groups} "
          f"groups; route "
          f"{pol.wire_bytes(layout) / 1e6:.2f}MB/worker/step vs "
          f"{f32_bytes / 1e6:.2f}MB f32 "
          f"(ratio {pol.compression_ratio(layout):.3f}); "
          f"warmup {pol.warmup_steps} steps f32, then "
          f"{pol.wire_dtype()} payload + "
          f"{pol.sideband_floats_per_tile()} f32 sideband(s)/tile; "
          f"wire state: {', '.join(pol.state_names())}")
    t0 = time.perf_counter()
    for i in range(args.steps):
        b = stream.batch(i)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (args.batch, cfg.num_image_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_frames, cfg.d_model),
                jnp.dtype(cfg.dtype))
        tokens = jnp.full((m,), i, jnp.int32)
        gstep = jnp.asarray(i, jnp.int32)
        warm = i < pol.warmup_steps
        fn = warm_step if warm else comp_step
        param_flat, accum, loss, wire = fn(
            param_flat, accum, batch, tokens, gstep, wire)
        if i % 5 == 0 or i == args.steps - 1 or i == pol.warmup_steps:
            phase = "warmup/f32" if warm else f"{scheme} wire"
            print(f"step {i:4d}  loss {float(loss):.4f}  [{phase}]  "
                  f"{(i + 1) * args.batch * args.seq / (time.perf_counter() - t0):,.0f} tok/s")
    assert jnp.isfinite(loss), "quantized-wire run diverged"


def run_autoswitch(args, cfg, mesh, params) -> None:
    """End-to-end tuning-free switching on this arch's REAL compiled
    steps: SwitchDriver runs sync (pytree psum + Adagrad) vs async
    (token-controlled fused-psum on the canonical layer-grouped layout)
    under the ``--plan`` fault plan, switching on live telemetry."""
    from repro.core.autoswitch import AutoSwitchController
    from repro.launch.programs import make_loss_fn
    from repro.launch.switch_driver import (SwitchConfig, SwitchDriver,
                                            demo_plan)
    from repro.sim.cluster import ClusterSpec

    m = mesh.shape["data"]
    gba = GBAConfig(local_batch=args.batch, buffer_size=m,
                    staleness_tolerance=args.iota)
    # build_programs for the canonical layer-grouped layout only — the
    # driver compiles its own sync/async program pair from it
    layout = build_programs(cfg, gba, mode="fused", params=params,
                            mesh=mesh, place_state=False).layout
    stream = make_lm_stream(cfg.vocab_size, args.seq, args.batch, seed=0)

    def batch_fn(i: int) -> dict:
        b = stream.batch(i)
        return {"tokens": b["tokens"], "labels": b["labels"]}

    spec = ClusterSpec(num_workers=m, base_speed=10_000.0, jitter=0.05,
                       allreduce_latency=0.005, ps_roundtrip=0.001,
                       seed=0)
    plan = demo_plan(args.plan, m)
    swcfg = SwitchConfig(local_batch=args.batch, iota=args.iota,
                         lr=args.lr)
    driver = SwitchDriver(mesh, make_loss_fn(cfg), params, spec=spec,
                          plan=plan, cfg=swcfg, batch_fn=batch_fn,
                          layout=layout)
    res = driver.run(args.batches, mode="auto",
                     controller=AutoSwitchController(
                         min_dwell=swcfg.min_dwell))
    print(f"autoswitch ({args.plan}): {res.num_global_steps} global "
          f"steps, {res.switch_count} switch(es), mode steps "
          f"{res.mode_steps}, first switch at gstep "
          f"{res.time_to_first_switch_steps}, sim qps {res.qps:,.0f}, "
          f"crashes {res.crashes} rejoins {res.rejoins} timeouts "
          f"{res.timeouts}, swaps verified {res.swaps_verified}, "
          f"final loss {res.losses[-1] if res.losses else float('nan'):.4f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS,
                    help="LM architecture (required unless --vocab)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--buffer", type=int, default=4, help="GBA M")
    ap.add_argument("--iota", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke variant on the 1-device mesh (CPU)")
    ap.add_argument("--fused", action="store_true",
                    help="flat-buffer GBA + fused gba_apply kernel; "
                         "FORCES Adagrad (implied for Adagrad archs with "
                         "--reduced); under a multi-device --mesh the "
                         "flat state shards per-slice (one launch per "
                         "PS shard)")
    ap.add_argument("--mesh", default="",
                    help="explicit DATAxMODEL mesh, e.g. 4x1; overrides "
                         "the smoke/production default")
    ap.add_argument("--layer-groups", choices=("auto", "on", "off"),
                    default="auto",
                    help="layer-grouped flat layout for the sharded fused "
                         "state: per-group contiguous shard-aligned "
                         "slices, so the grouped collective schedule "
                         "gathers one layer group at a time (peak gather "
                         "= largest group, not N_total).  auto = on for "
                         "--fused with a multi-device --mesh")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N host-platform devices before jax device "
                         "init (CPU test path for --mesh)")
    ap.add_argument("--compress", choices=("none", "int8", "onebit"),
                    default="none",
                    help="quantize the gradient routing wire of the "
                         "worker-parallel fused-psum step (implies that "
                         "step; needs --fused and a multi-device data "
                         "axis).  int8 = per-tile min-max with error "
                         "feedback; onebit = sign-of-momentum after "
                         "--compress-warmup full-precision global steps. "
                         "Sync / single-device runs auto-fall back to "
                         "none — there is no wire to compress")
    ap.add_argument("--compress-warmup", type=int, default=2,
                    help="full-precision warmup global steps before the "
                         "lossy wire engages (re-jit at the boundary)")
    ap.add_argument("--autoswitch", action="store_true",
                    help="run the end-to-end switching harness "
                         "(launch.switch_driver) on this arch's compiled "
                         "sync/async steps under a --plan fault plan "
                         "(needs a multi-device data axis)")
    ap.add_argument("--plan", choices=("quiet", "strained"),
                    default="strained",
                    help="fault plan for --autoswitch: quiet (vacant "
                         "cluster) or strained (25%% stragglers at 4x + "
                         "one transient crash)")
    ap.add_argument("--batches", type=int, default=120,
                    help="local batches to stream through --autoswitch")
    ap.add_argument("--vocab", type=int, default=0,
                    help="run the streamed-embedding sparse smoke at this "
                         "hash capacity (e.g. 1000000) instead of an LM "
                         "arch")
    ap.add_argument("--embed-dim", type=int, default=16)
    ap.add_argument("--block-v", type=int, default=0,
                    help="vocab rows per streamed table tile (0 = default)")
    ap.add_argument("--block-d", type=int, default=0,
                    help="embedding cols per output tile (0 = default)")
    ap.add_argument("--chunk-e", type=int, default=0,
                    help="sorted entries per pipeline step (0 = default)")
    args = ap.parse_args()

    if args.vocab:
        run_embedding_smoke(args)
        return
    if not args.arch:
        ap.error("--arch is required unless --vocab is given")

    cfg = get_config(args.arch)
    # resolve the optimizer from the canonical name BEFORE .reduced()
    # renames the config (…-smoke), so smoke runs match production
    opt_name = ARCH_OPTIMIZER.get(cfg.name, "adam")
    if args.reduced:
        cfg = cfg.reduced()
    if args.mesh:
        d, _, t = args.mesh.partition("x")
        shape = (int(d), int(t or 1))
        if jax.device_count() < shape[0] * shape[1]:
            ap.error(f"--mesh {args.mesh} needs {shape[0] * shape[1]} "
                     f"devices, have {jax.device_count()} "
                     f"(use --host-devices on CPU)")
        mesh = jax.make_mesh(shape, ("data", "model"))
    elif args.reduced:
        mesh = make_smoke_mesh()
    else:
        mesh = make_production_mesh()

    params = T.init_model(jax.random.PRNGKey(0), cfg)
    print(f"{cfg.name}: {T.param_count(params) / 1e6:.1f}M params, "
          f"mesh {dict(mesh.shape)}")
    if args.autoswitch:
        if mesh.shape["data"] < 2:
            ap.error("--autoswitch needs a multi-device data axis "
                     "(e.g. --mesh 4x1 --host-devices 4 on CPU)")
        with mesh:
            run_autoswitch(args, cfg, mesh, params)
        return
    # the fused flat buffer is single-host (no per-leaf shardings) and
    # costs buffer_size f32 copies of the params: auto-enable only for
    # Adagrad archs on the smoke mesh, explicit --fused elsewhere
    fused = args.fused or (opt_name == "adagrad" and args.reduced)
    if fused and opt_name != "adagrad":
        print(f"--fused forces Adagrad (arch default was {opt_name})")
    opt = get_optimizer(opt_name, args.lr)
    gba = GBAConfig(local_batch=args.batch, buffer_size=args.buffer,
                    staleness_tolerance=args.iota)
    stream = make_lm_stream(cfg.vocab_size, args.seq, args.batch, seed=0)

    # keyed off the actual mesh, not --mesh: the sharded fused path (and
    # so the grouped layout) engages whenever the data axis is >1 wide,
    # including the production default mesh
    multi_dev = mesh.shape["data"] > 1
    layer_groups = (args.layer_groups == "on"
                    or (args.layer_groups == "auto" and fused and multi_dev))
    compress = args.compress
    if compress != "none" and not (fused and multi_dev):
        # sync / single-device mode has no worker->shard wire to quantize
        print(f"--compress {compress}: needs --fused and a multi-device "
              f"data axis (worker-parallel fused-psum wire); this "
              f"sync/single-device run falls back to none")
        compress = "none"
    if compress != "none":
        if args.batch % mesh.shape["data"]:
            ap.error(f"--compress needs --batch divisible by the data "
                     f"axis ({mesh.shape['data']})")
        with mesh:
            run_wire_train(args, cfg, mesh, gba, stream, params, compress)
        return
    with mesh:
        if fused:
            progs = build_programs(cfg, gba, mode="fused", params=params,
                                   mesh=mesh, lr=args.lr,
                                   layer_groups=layer_groups)
            layout, state, step_fn = progs.layout, progs.state, progs.step
            from repro.core.flat_sharded import ShardedFlatLayout
            if isinstance(layout, ShardedFlatLayout):
                print(f"sharded fused gba_apply path (Adagrad): flat "
                      f"buffer ({gba.buffer_size}, {layout.padded_total}) "
                      f"sliced over data={layout.num_shards} "
                      f"(shard_size={layout.shard_size}, "
                      f"tile={layout.tile}; 1 apply launch/shard vs "
                      f"{len(layout.sizes)} per-leaf)")
                if layout.num_groups > 1:
                    table = ", ".join(
                        f"{r['key']}={r['bytes'] / 1e6:.2f}MB"
                        for r in layout.group_table())
                    print(f"layer groups ({layout.num_groups}): {table}; "
                          f"peak_gather="
                          f"{layout.peak_gather_bytes / 1e6:.2f}MB vs "
                          f"full_gather="
                          f"{layout.full_gather_bytes / 1e6:.2f}MB")
            else:
                print(f"fused gba_apply path (Adagrad): flat buffer "
                      f"({gba.buffer_size}, {layout.total})")
        else:
            progs = build_programs(cfg, gba, mode="pytree", params=params,
                                   optimizer=opt, acc_dtype=jnp.float32)
            step_fn, state = progs.step, progs.state
        t0 = time.perf_counter()
        for i in range(args.steps):
            b = stream.batch(i)
            batch = {"tokens": jnp.asarray(b["tokens"]),
                     "labels": jnp.asarray(b["labels"])}
            if cfg.family == "vlm":
                batch["image_embeds"] = jnp.zeros(
                    (args.batch, cfg.num_image_tokens, cfg.d_model),
                    jnp.dtype(cfg.dtype))
            if cfg.family == "audio":
                batch["frames"] = jnp.zeros(
                    (args.batch, cfg.encoder_frames, cfg.d_model),
                    jnp.dtype(cfg.dtype))
            token = jnp.asarray(i // args.buffer, jnp.int32)
            state, loss = step_fn(state, batch, token)
            if i % 5 == 0 or i == args.steps - 1:
                gstep = int(state["buffer"]["step"] if fused
                            else state["gstep"])
                print(f"step {i:4d}  loss {float(loss):.4f}  "
                      f"gstep {gstep}  "
                      f"{(i + 1) * args.batch * args.seq /  (time.perf_counter() - t0):,.0f} tok/s")


if __name__ == "__main__":
    main()
