"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        --steps 20 --reduced [--buffer 8] [--iota 4]

On real TPU hardware this launches the sharded GBA train step on the
production mesh; in this CPU container use ``--reduced`` (smoke variant,
1-device mesh) — the full configs are exercised by launch.dryrun.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import GBAConfig
from repro.data import make_lm_stream
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.launch.steps import (ARCH_OPTIMIZER, init_fused_train_state,
                                init_train_state, make_fused_train_step,
                                make_train_step)
from repro.models import transformer as T
from repro.optim import get_optimizer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--buffer", type=int, default=4, help="GBA M")
    ap.add_argument("--iota", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke variant on the 1-device mesh (CPU)")
    ap.add_argument("--fused", action="store_true",
                    help="flat-buffer GBA + fused gba_apply kernel; "
                         "FORCES Adagrad and a single-host flat state "
                         "(implied for Adagrad archs with --reduced)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    # resolve the optimizer from the canonical name BEFORE .reduced()
    # renames the config (…-smoke), so smoke runs match production
    opt_name = ARCH_OPTIMIZER.get(cfg.name, "adam")
    if args.reduced:
        cfg = cfg.reduced()
        mesh = make_smoke_mesh()
    else:
        mesh = make_production_mesh()

    params = T.init_model(jax.random.PRNGKey(0), cfg)
    print(f"{cfg.name}: {T.param_count(params) / 1e6:.1f}M params, "
          f"mesh {dict(mesh.shape)}")
    # the fused flat buffer is single-host (no per-leaf shardings) and
    # costs buffer_size f32 copies of the params: auto-enable only for
    # Adagrad archs on the smoke mesh, explicit --fused elsewhere
    fused = args.fused or (opt_name == "adagrad" and args.reduced)
    if fused and opt_name != "adagrad":
        print(f"--fused forces Adagrad (arch default was {opt_name})")
    opt = get_optimizer(opt_name, args.lr)
    gba = GBAConfig(local_batch=args.batch, buffer_size=args.buffer,
                    staleness_tolerance=args.iota)
    stream = make_lm_stream(cfg.vocab_size, args.seq, args.batch, seed=0)

    with mesh:
        if fused:
            layout, state = init_fused_train_state(params, gba)
            step_fn = jax.jit(
                make_fused_train_step(cfg, gba, layout, lr=args.lr),
                donate_argnums=0)
            print(f"fused gba_apply path (Adagrad): flat buffer "
                  f"({gba.buffer_size}, {layout.total})")
        else:
            step_fn = jax.jit(make_train_step(cfg, opt, gba),
                              donate_argnums=0)
            state = init_train_state(params, opt)
        t0 = time.perf_counter()
        for i in range(args.steps):
            b = stream.batch(i)
            batch = {"tokens": jnp.asarray(b["tokens"]),
                     "labels": jnp.asarray(b["labels"])}
            if cfg.family == "vlm":
                batch["image_embeds"] = jnp.zeros(
                    (args.batch, cfg.num_image_tokens, cfg.d_model),
                    jnp.dtype(cfg.dtype))
            if cfg.family == "audio":
                batch["frames"] = jnp.zeros(
                    (args.batch, cfg.encoder_frames, cfg.d_model),
                    jnp.dtype(cfg.dtype))
            token = jnp.asarray(i // args.buffer, jnp.int32)
            state, loss = step_fn(state, batch, token)
            if i % 5 == 0 or i == args.steps - 1:
                gstep = int(state["buffer"]["step"] if fused
                            else state["gstep"])
                print(f"step {i:4d}  loss {float(loss):.4f}  "
                      f"gstep {gstep}  "
                      f"{(i + 1) * args.batch * args.seq /  (time.perf_counter() - t0):,.0f} tok/s")


if __name__ == "__main__":
    main()
