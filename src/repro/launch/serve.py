"""Serving launcher: batched prefill + decode.

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b \
        --reduced --batch 8 --prompt-len 32 --gen-len 32

``--engine`` runs the continuous-batching :class:`repro.serving.
ServingEngine` instead of the fixed-batch loop: requests are admitted
into decode slots from a :class:`ParamSource` — frozen init by default,
``--ckpt PATH`` (an npz file or a CheckpointManager directory, newest
step wins) for checkpoint serving:

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
        --reduced --engine --requests 8 --gen-len 24
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.launch.steps import make_decode_step
from repro.models import transformer as T


def run_engine(args, cfg, mesh) -> None:
    """Continuous-batching serving from a ParamSource."""
    from repro.serving import (Request, ServingConfig, ServingEngine,
                               StaticSource)
    if args.ckpt:
        source = StaticSource.from_checkpoint(args.ckpt,
                                              select=args.ckpt_select or None)
    else:
        source = StaticSource(T.init_model(jax.random.PRNGKey(0), cfg))
    scfg = ServingConfig(num_slots=args.batch,
                         max_len=args.prompt_len + args.gen_len)
    eng = ServingEngine(source, cfg, config=scfg)
    rng = np.random.default_rng(0)
    with mesh:
        for uid in range(args.requests):
            plen = int(rng.integers(4, args.prompt_len + 1))
            eng.submit(Request(
                uid=uid,
                prompt=rng.integers(0, cfg.vocab_size, plen,
                                    dtype=np.int64).astype(np.int32),
                max_new_tokens=args.gen_len))
        stats = eng.run()
    print(f"engine: {stats['completed']} completed in "
          f"{stats['decode_steps']} steps, "
          f"{stats['tokens_per_s']:,.0f} tok/s, slot util "
          f"{stats['slot_utilization']:.2f}, param v{stats['param_version']} "
          f"(step {stats['param_step']}), clamped "
          f"{stats['clamped_requests']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching ServingEngine from a "
                         "ParamSource instead of the fixed-batch loop")
    ap.add_argument("--requests", type=int, default=8,
                    help="requests to submit with --engine")
    ap.add_argument("--ckpt", default="",
                    help="serve params from this checkpoint (npz file or "
                         "CheckpointManager dir) instead of fresh init")
    ap.add_argument("--ckpt-select", default="",
                    help="subtree of the checkpoint holding the params "
                         "(e.g. 'params' for a full train state)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        mesh = make_smoke_mesh()
    else:
        mesh = make_production_mesh()
    if args.engine:
        run_engine(args, cfg, mesh)
        return
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    memory = None
    if cfg.family == "vlm":
        memory = jax.random.normal(
            key, (args.batch, cfg.num_image_tokens, cfg.d_model),
            jnp.float32).astype(jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        frames = jax.random.normal(
            key, (args.batch, cfg.encoder_frames, cfg.d_model),
            jnp.float32).astype(jnp.dtype(cfg.dtype))
        memory = T.encode_audio(params, cfg, frames)

    cache_len = args.prompt_len + args.gen_len
    with mesh:
        t0 = time.perf_counter()
        logits, cache = jax.jit(
            lambda p, t: T.prefill(p, cfg, t, memory=memory,
                                   cache_len=cache_len))(params, prompts)
        jax.block_until_ready(logits)
        print(f"prefill {args.batch}x{args.prompt_len}: "
              f"{(time.perf_counter() - t0) * 1e3:.0f} ms")
        decode = jax.jit(make_decode_step(cfg))
        token = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        t0 = time.perf_counter()
        for _ in range(args.gen_len - 1):
            token, _, cache = decode(params, token, cache)
        jax.block_until_ready(token)
        dt = time.perf_counter() - t0
        print(f"decode {args.gen_len - 1} steps: {dt * 1e3:.0f} ms "
              f"({args.batch * (args.gen_len - 1) / dt:,.0f} tok/s)")


if __name__ == "__main__":
    main()
