"""End-to-end tuning-free sync<->async switching on the REAL compiled steps.

The paper's headline claim (Fig. 6): because GBA holds the global batch
and the token-control rule needs no retuning, a job can switch between
synchronous AR training and asynchronous GBA training mid-run, following
the cluster status.  ``core.autoswitch`` decides *when*; this module is
the harness that actually *does* it:

* **sync mode** runs :func:`repro.core.gba_shard_map.make_gba_psum_step`
  — the pytree all-reduce program with Adagrad (``sync_impl="psum"``) —
  or the uncompressed fused-psum step with all-fresh tokens
  (``sync_impl="fused"``, the tuning-free degenerate form the parity
  tests use as a bit-exactness oracle);
* **async mode** runs the token-controlled layer-grouped fused-psum step
  (:func:`~repro.core.gba_shard_map.make_gba_fused_psum_step`),
  optionally with the quantized wire (warmup/compressed re-jit pair);
* a sim-clock event loop (same timing vocabulary as ``sim.cluster``)
  drives per-worker pulls/pushes under a :class:`repro.sim.faults.FaultPlan`
  — straggler windows, transient crashes with token loss and timed
  recovery (Alg. 1), telemetry-scrape dropouts, async apply failures —
  and feeds per-worker completion rates to an
  :class:`~repro.core.autoswitch.AutoSwitchController`.

Switch protocol (see launch/README.md for the operator view):

1. **drain**: in-flight worker batches are cancelled; their tokens are
   discarded (counted in ``SwitchResult.drained``) and the batches
   requeued so no data is lost across the swap;
2. **state carryover**: the canonical training state is the layout's
   flat (param, accum) pair.  ``sync_impl="fused"`` shares it between
   modes (zero-copy swap); ``sync_impl="psum"`` converts pytree
   params + Adagrad accum <-> flat vectors via :func:`tree_to_flat` /
   :func:`flat_to_tree`, bit-exactly (padding positions carry param 0 /
   accum ``initial_accum``, matching an unswitched fused run, where
   padding gradient is identically zero).  With ``verify_swap`` every
   swap round-trips the conversion and raises on any bit difference;
3. **token reissue**: sync mode stamps every participating slot with the
   current global step (fresh tokens, weight 1); async dispatches stamp
   the pull-time step.  A worker excluded from the sync barrier (dead,
   or timed out past the retry budget) contributes a **tombstone** slot:
   token ``gstep - iota - 1``, which Eq. (1) decays to EXACTLY zero —
   the barrier never waits on it and bit-exactness is preserved;
4. **compression warmup re-entry**: each entry into async mode zeroes
   the wire state and restarts the warmup counter, so the
   warmup->compressed re-jit boundary is re-entered safely (two
   pre-built jitted programs; no mid-run retrace).

Graceful degradation: per-worker pull timeouts with bounded
retry+backoff (``push_timeout``/``max_retries``/``backoff``); a crashed
worker is discovered by one timeout burst, then excluded from the
barrier until its recovery time instead of hanging sync mode; repeated
async apply failures (``breaker_threshold`` consecutive) trip a
fallback-to-sync circuit breaker that also restarts the controller's
dwell window.

Run it directly for the Fig. 6 trajectory (used by
``benchmarks.bench_fig6_switching``):

    PYTHONPATH=src python -m repro.launch.switch_driver \
        --host-devices 4 --workers 4 --batches 240 --plan strained \
        --compare-sync --json
"""
from __future__ import annotations

import heapq
import itertools
import math
import os
import sys
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

# --host-devices must land in XLA_FLAGS before jax initializes the
# backend (same argv-peek idiom as launch.train); only the __main__ path
# does this — library imports never touch jax device state.
if __name__ == "__main__":                        # pragma: no cover
    for _i, _a in enumerate(sys.argv):
        _n = None
        if _a == "--host-devices" and _i + 1 < len(sys.argv):
            _n = sys.argv[_i + 1]
        elif _a.startswith("--host-devices="):
            _n = _a.split("=", 1)[1]
        if _n and _n.isdigit():
            os.environ["XLA_FLAGS"] = (
                f"{os.environ.get('XLA_FLAGS', '')} "
                f"--xla_force_host_platform_device_count={_n}").strip()
            break

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import GBAConfig
from repro.core.autoswitch import AutoSwitchController
from repro.core.flat_sharded import ShardedFlatLayout
from repro.launch.programs import build_programs
from repro.sim.cluster import ClusterSpec
from repro.sim.faults import FaultInjector, FaultPlan


# ---------------------------------------------------------------------------
# state carryover: pytree params/Adagrad accum <-> canonical flat vectors
# ---------------------------------------------------------------------------

def pad_mask(layout: ShardedFlatLayout) -> jax.Array:
    """(padded_total,) f32: 1.0 where a real parameter element lives, 0.0
    in tile/shard padding — the positions ``layout.ravel`` zero-fills."""
    ones = jax.tree.unflatten(
        layout.treedef,
        [jnp.ones(s, jnp.float32) for s in layout.shapes])
    return layout.ravel(ones)


def tree_to_flat(layout: ShardedFlatLayout, params: Any, accum_tree: Any,
                 *, initial_accum: float = 0.1
                 ) -> tuple[jax.Array, jax.Array]:
    """(params pytree, Adagrad accum pytree) -> flat (param, accum).

    Padding positions get param 0 and accum ``initial_accum`` — exactly
    the state an unswitched fused run carries there (padding gradient is
    identically zero, so fused Adagrad never moves those elements off
    their init), which is what makes a sync->async->sync round trip
    bit-exact against a run that never switched."""
    pf = layout.ravel(params)
    af = layout.ravel(accum_tree) \
        + (1.0 - pad_mask(layout)) * initial_accum
    return pf, af


def flat_to_tree(layout: ShardedFlatLayout, param_flat: jax.Array,
                 accum_flat: jax.Array) -> tuple[Any, dict]:
    """Flat (param, accum) -> (params pytree, Adagrad opt_state).  The
    accum leaves stay f32 (the optimizer's dtype) even for a bf16-param
    model — ``layout.unravel`` would otherwise cast them to the PARAM
    leaf dtypes."""
    return (layout.unravel(param_flat),
            {"accum": layout.unravel(accum_flat, jnp.float32)})


# ---------------------------------------------------------------------------
# configuration / results
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SwitchConfig:
    """Knobs of the switching harness (see launch/README.md).

    ``push_timeout`` / ``backoff`` default to ``None`` = auto: 8x / 2x
    the healthy batch duration (``local_batch / spec.base_speed``), so a
    4x straggler never times out but a dead worker is discovered within
    one bounded retry burst."""
    local_batch: int = 256
    iota: int = 4               # Eq. (1) staleness tolerance
    lr: float = 0.05
    eps: float = 1e-10
    initial_accum: float = 0.1  # Adagrad init (matches the fused kernel)
    decide_every: int = 4       # global steps per telemetry decision
    min_dwell: int = 2          # controller cooldown, in decisions
    push_timeout: float | None = None   # sim-seconds per pull attempt
    max_retries: int = 2        # extra pull attempts before exclusion
    backoff: float | None = None        # extra wait between attempts
    breaker_threshold: int = 3  # consecutive async apply failures ->
                                # forced fallback to sync
    sync_impl: str = "psum"     # "psum" | "fused" (see module docstring)
    verify_swap: bool = True    # bit-exact round-trip check at each swap

    def __post_init__(self):
        if self.sync_impl not in ("psum", "fused"):
            raise ValueError(f"sync_impl must be 'psum' or 'fused', "
                             f"got {self.sync_impl!r}")
        if self.local_batch < 1:
            raise ValueError(f"local_batch must be >= 1, "
                             f"got {self.local_batch}")
        if self.decide_every < 1:
            raise ValueError(f"decide_every must be >= 1, "
                             f"got {self.decide_every}")
        if self.breaker_threshold < 1:
            raise ValueError(f"breaker_threshold must be >= 1, "
                             f"got {self.breaker_threshold}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, "
                             f"got {self.max_retries}")


@dataclass(frozen=True)
class GlobalStep:
    """One replayable global step: per-slot tokens and batch indices
    (batch index < 0 = tombstone slot: zero batch, weight-0 token)."""
    tokens: tuple[int, ...]
    batches: tuple[int, ...]


@dataclass
class SwitchResult:
    """What one driver run measured.  ``param_flat`` / ``accum_flat`` are
    the final CANONICAL flat state (converted from the pytree if the run
    ended in psum-sync mode), so two runs compare bit-for-bit regardless
    of which mode they ended in."""
    wall_time: float = 0.0      # sim-clock seconds
    samples: int = 0            # aggregated (weight-1) samples
    num_global_steps: int = 0
    switch_count: int = 0
    time_to_first_switch_steps: int | None = None
    mode_timeline: list = field(default_factory=list)  # (gstep, t, mode)
    mode_steps: dict = field(default_factory=dict)     # mode -> gsteps
    mode_time: dict = field(default_factory=dict)      # mode -> sim secs
    losses: list = field(default_factory=list)
    crashes: int = 0
    rejoins: int = 0
    timeouts: int = 0
    lost_batches: int = 0       # tokens lost to crashes (Alg. 1)
    dropped_batches: int = 0    # Eq. (1) weight-0 slots (real, stale)
    tombstones: int = 0         # synthetic weight-0 slots (exclusions)
    drained: int = 0            # in-flight tokens discarded at swaps
    stalled_barriers: int = 0   # sync rounds with zero live workers
    apply_failures: int = 0
    breaker_trips: int = 0
    dropped_scrapes: int = 0
    swaps_verified: int = 0
    warm_steps: int = 0         # async steps run on the warmup program
    param_flat: np.ndarray | None = None
    accum_flat: np.ndarray | None = None
    controller_summary: dict | None = None

    @property
    def qps(self) -> float:
        return self.samples / self.wall_time if self.wall_time else 0.0

    def to_json(self) -> dict:
        def py(v):
            if isinstance(v, (np.floating, np.integer)):
                return v.item()
            if isinstance(v, dict):
                return {k: py(x) for k, x in v.items()}
            if isinstance(v, (list, tuple)):
                return [py(x) for x in v]
            return v
        out = {k: py(v) for k, v in self.__dict__.items()
               if k not in ("param_flat", "accum_flat", "losses")}
        out["qps"] = py(self.qps)
        out["final_loss"] = self.losses[-1] if self.losses else None
        return out


class _RunState:
    """Mutable per-run bookkeeping (mode, live training state, event
    heap, telemetry window, counters that land in :class:`SwitchResult`)."""

    def __init__(self, num_workers: int):
        self.mode = "sync"
        self.finished = False
        # training state: exactly one representation is live at a time
        self.params = None          # pytree (psum sync mode)
        self.opt = None             # {"accum": pytree}
        self.pf = None              # flat params (fused modes)
        self.af = None              # flat accum
        self.wire = None
        self.warm_count = 0
        # sim clock / data
        self.t = 0.0
        self.gstep = 0
        self.inj = None             # set by run(); None in run_schedule
        self.num_batches = 0
        self.next_batch = 0
        self.requeue: list[int] = []
        self.heap: list = []        # async events
        self.seq = itertools.count()
        self.down: set[int] = set()
        self.breaker = 0
        # telemetry window
        self.win_completions = np.zeros(num_workers)
        self.win_busy = np.zeros(num_workers)
        self.result = SwitchResult()


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------

class SwitchDriver:
    """Runs the real compiled sync/async steps under a fault plan with
    live mode switching.  Programs are jitted once in the constructor;
    :meth:`run` (event-driven sim) and :meth:`run_schedule` (fixed
    schedule replay) can both be called repeatedly — e.g. once in
    ``mode="auto"`` and once in ``mode="sync"`` on the same plan for a
    like-for-like speedup — sharing the compiled steps."""

    def __init__(self, mesh: Mesh, loss_fn: Callable, params: Any, *,
                 spec: ClusterSpec, plan: FaultPlan,
                 cfg: SwitchConfig = SwitchConfig(),
                 batch_fn: Callable[[int], dict],
                 compress=None, layout: ShardedFlatLayout | None = None,
                 group_by=None, tile: int | None = None,
                 axis: str = "data"):
        self.mesh, self.axis, self.cfg = mesh, axis, cfg
        self.m = mesh.shape[axis]
        if spec.num_workers != self.m or plan.num_workers != self.m:
            raise ValueError(
                f"mesh axis {axis!r} has {self.m} devices; spec has "
                f"{spec.num_workers} workers, plan has {plan.num_workers}")
        self.spec, self.plan = spec, plan
        self.loss_fn, self.batch_fn = loss_fn, batch_fn
        self.compress = (compress if compress is not None
                         and compress.stateful else None)
        if layout is None:
            from repro.kernels.gba_apply import BLOCK_N
            layout = ShardedFlatLayout.from_params(
                params, self.m, tile or BLOCK_N, group_by=group_by)
        if layout.num_shards != self.m:
            raise ValueError(
                f"layout has {layout.num_shards} shards, mesh axis "
                f"{axis!r} has {self.m} devices")
        self.layout = layout
        self._params0 = params
        # resolved timeout/backoff (sim-seconds): a healthy pull costs
        # compute + PS roundtrip, so auto must budget BOTH — a roundtrip
        # that dominates a small local batch must not read as a timeout
        base_dur = cfg.local_batch / spec.base_speed + spec.ps_roundtrip
        self.push_timeout = (cfg.push_timeout if cfg.push_timeout
                             is not None else 8.0 * base_dur)
        self.backoff = (cfg.backoff if cfg.backoff is not None
                        else 2.0 * base_dur)
        # shardings
        self._flat_shd = NamedSharding(mesh, P(axis))
        self._repl_shd = NamedSharding(mesh, P())
        self._pad_accum = np.asarray(
            (1.0 - pad_mask(layout)) * cfg.initial_accum)
        # compiled programs, all through the unified builder
        # (launch.programs.build_programs): async = the wire-mode
        # fused-psum pair, sync = either the plain wire step shared
        # zero-copy or a sync_psum bundle with its Adagrad
        gba_cfg = GBAConfig(local_batch=cfg.local_batch,
                            buffer_size=self.m,
                            staleness_tolerance=cfg.iota)
        self._fused_plain = build_programs(
            None, gba_cfg, mode="wire", mesh=mesh, axis=axis,
            layout=layout, loss_fn=loss_fn, lr=cfg.lr,
            eps=cfg.eps).warm_step
        if self.compress is not None:
            wp = build_programs(
                None, gba_cfg, mode="wire", mesh=mesh, axis=axis,
                layout=layout, loss_fn=loss_fn, compress=self.compress,
                lr=cfg.lr, eps=cfg.eps)
            self._fused_warm = wp.warm_step
            self._fused_main = wp.compressed_step
        if cfg.sync_impl == "psum":
            sp = build_programs(
                None, gba_cfg, mode="sync_psum", mesh=mesh, axis=axis,
                loss_fn=loss_fn, lr=cfg.lr, eps=cfg.eps,
                initial_accum=cfg.initial_accum)
            self._opt = sp.optimizer
            self._sync_step = sp.step
        # zero batch template for tombstone slots (weight is exactly 0,
        # so content never reaches the params; zeros keep losses finite)
        tmpl = batch_fn(0)
        lead = {jax.tree.leaves(tmpl)[0].shape[0]}
        if lead != {cfg.local_batch}:
            raise ValueError(
                f"batch_fn leading dim {lead} != local_batch "
                f"{cfg.local_batch}")
        self._zeros_batch = jax.tree.map(np.zeros_like, tmpl)

    # -- state management ---------------------------------------------------
    def _fresh_state(self, mode: str) -> _RunState:
        st = _RunState(self.m)
        st.mode = mode
        if mode == "sync" and self.cfg.sync_impl == "psum":
            st.params = jax.device_put(self._params0, self._repl_shd)
            st.opt = jax.device_put(self._opt.init(self._params0),
                                    self._repl_shd)
        else:
            pf, af = tree_to_flat(self.layout, self._params0,
                                  self._opt_init_accum(),
                                  initial_accum=self.cfg.initial_accum)
            st.pf = jax.device_put(pf, self._flat_shd)
            st.af = jax.device_put(af, self._flat_shd)
            if mode == "gba":
                self._reset_wire(st)
        return st

    def _opt_init_accum(self):
        return jax.tree.map(
            lambda p: jnp.full(p.shape, self.cfg.initial_accum,
                               jnp.float32), self._params0)

    def _reset_wire(self, st: _RunState) -> None:
        """Compression warmup re-entry: zero wire state, restart the
        warmup counter — each entry into async mode replays the
        warmup->compressed re-jit boundary safely."""
        st.warm_count = 0
        if self.compress is None:
            st.wire = None
            return
        from repro.distributed import sharding as S
        wire = self.compress.init_wire_state(self.layout, self.m)
        specs = S.wire_state_specs(self.layout, self.mesh,
                                   self.compress.scheme, self.axis)
        st.wire = jax.device_put(wire, S.to_named(specs, self.mesh))

    def _swap(self, st: _RunState, new_mode: str, controller=None) -> None:
        """Execute the switch protocol: drain in-flight, convert state
        (verified bit-exact when ``verify_swap``), reissue from the
        requeue, re-enter compression warmup."""
        if new_mode == st.mode:
            return
        r = st.result
        if st.mode == "gba":
            # drain: discard in-flight tokens, requeue their batches
            for ev in st.heap:
                if ev[2] == "push":
                    st.requeue.append(ev[4])
                    r.drained += 1
            st.heap = []
        if self.cfg.sync_impl == "psum":
            if new_mode == "gba":       # pytree -> flat
                pf, af = tree_to_flat(self.layout, st.params,
                                      st.opt["accum"],
                                      initial_accum=self.cfg.initial_accum)
                if self.cfg.verify_swap:
                    # flat -> tree must reproduce the source pytree
                    # bit-for-bit (f32 holds every bf16 value exactly)
                    p2, o2 = flat_to_tree(self.layout, pf, af)
                    self._check_equal(st.params, p2, "params")
                    self._check_equal(st.opt["accum"], o2["accum"],
                                      "accum")
                    r.swaps_verified += 1
                st.pf = jax.device_put(pf, self._flat_shd)
                st.af = jax.device_put(af, self._flat_shd)
                st.params = st.opt = None
            else:                       # flat -> pytree
                params, opt = flat_to_tree(self.layout, st.pf, st.af)
                if self.cfg.verify_swap:
                    # tree -> flat must reproduce the source vectors.
                    # The accum is exact always (f32 end to end, and the
                    # pad positions are reconstructed by the same
                    # formula).  Params are exact when the model is f32;
                    # a bf16-param model inherently rounds to the model
                    # dtype here — sync mode has no wider home for them
                    # — so the param check only applies to f32 leaves.
                    pf2, af2 = tree_to_flat(
                        self.layout, params, opt["accum"],
                        initial_accum=self.cfg.initial_accum)
                    self._check_equal(st.af, af2, "accum")
                    if all(d == jnp.float32 for d in self.layout.dtypes):
                        self._check_equal(st.pf, pf2, "params")
                    r.swaps_verified += 1
                st.params = jax.device_put(params, self._repl_shd)
                st.opt = jax.device_put(opt, self._repl_shd)
                st.pf = st.af = None
        # sync_impl="fused": flat state is shared — zero-copy swap
        if new_mode == "gba":
            self._reset_wire(st)
            if st.inj is not None:      # event-driven run, not a replay
                self._enter_async(st)
        st.mode = new_mode
        r.switch_count += 1
        if r.time_to_first_switch_steps is None:
            r.time_to_first_switch_steps = st.gstep
        r.mode_timeline.append((st.gstep, st.t, new_mode))

    @staticmethod
    def _check_equal(a, b, what: str) -> None:
        """Bit-exactness of the carryover: the round-tripped
        representation must reproduce the source exactly."""
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            if not bool(jnp.array_equal(x, y, equal_nan=True)):
                raise RuntimeError(f"switch carryover: {what} round-trip "
                                   "is not bit-exact")

    def _canonical_flat(self, st: _RunState
                        ) -> tuple[np.ndarray, np.ndarray]:
        if st.pf is not None:
            return (np.asarray(jax.device_get(st.pf)),
                    np.asarray(jax.device_get(st.af)))
        pf = self.layout.ravel(st.params)
        af = self.layout.ravel(st.opt["accum"]) + self._pad_accum
        return (np.asarray(jax.device_get(pf)),
                np.asarray(jax.device_get(af)))

    # -- compiled-step execution --------------------------------------------
    def _put_batch(self, slot_batches: list) -> Any:
        stacked = jax.tree.map(
            lambda *xs: np.concatenate(xs, axis=0), *slot_batches)
        return jax.device_put(stacked, self._flat_shd)

    def _exec(self, st: _RunState, tokens: np.ndarray,
              slot_batches: list) -> float:
        """Run one global step of the CURRENT mode's compiled program.
        Returns the loss; the caller decides whether to commit (async
        apply failures leave state untouched)."""
        batch = self._put_batch(slot_batches)
        tok = jax.device_put(tokens.astype(np.int32), self._flat_shd)
        gstep = jnp.asarray(st.gstep, jnp.int32)
        if st.mode == "sync" and self.cfg.sync_impl == "psum":
            params, opt, loss = self._sync_step(st.params, st.opt, batch,
                                                tok, gstep)
            loss = float(loss)
            if math.isfinite(loss):
                st.params, st.opt = params, opt
            return loss
        if st.mode == "sync" or self.compress is None:
            pf, af, loss = self._fused_plain(st.pf, st.af, batch, tok,
                                             gstep)
            loss = float(loss)
            if math.isfinite(loss):
                st.pf, st.af = pf, af
            return loss
        warm = st.warm_count < self.compress.warmup_steps
        fn = self._fused_warm if warm else self._fused_main
        pf, af, loss, wire = fn(st.pf, st.af, batch, tok, gstep, st.wire)
        loss = float(loss)
        if math.isfinite(loss):
            st.pf, st.af, st.wire = pf, af, wire
            st.warm_count += 1
            if warm:
                st.result.warm_steps += 1
        return loss

    # -- batch bookkeeping --------------------------------------------------
    def _take_batch(self, st: _RunState, num_batches: int) -> int | None:
        if st.requeue:
            return st.requeue.pop(0)
        if st.next_batch < num_batches:
            b = st.next_batch
            st.next_batch += 1
            return b
        return None

    def _has_batches(self, st: _RunState, num_batches: int) -> bool:
        return bool(st.requeue) or st.next_batch < num_batches

    # -- sync mode: one barrier round ---------------------------------------
    def _sync_round(self, st: _RunState, inj: FaultInjector,
                    num_batches: int) -> None:
        r, cfg, m = st.result, self.cfg, self.m
        t0 = st.t
        # health check: recovered workers rejoin the barrier
        for w in sorted(st.down):
            if not inj.is_down(w, t0):
                st.down.discard(w)
                r.rejoins += 1
        lat = np.zeros(m)
        part: dict[int, int] = {}
        requeue_back: list[int] = []
        for w in range(m):
            if w in st.down:
                continue            # excluded: no probe, tombstone slot
            b = self._take_batch(st, num_batches)
            if b is None:
                continue            # data exhausted: idle, tombstone
            dur = inj.duration(w, t0, cfg.local_batch) \
                + self.spec.ps_roundtrip
            ev = inj.crash_between(w, t0, t0 + dur)
            if ev is not None:
                # the pull hangs: one bounded retry burst discovers the
                # dead worker, then it is excluded until recovery —
                # the barrier NEVER waits past the timeout budget
                lat[w] = ((1 + cfg.max_retries) * self.push_timeout
                          + cfg.max_retries * self.backoff)
                r.timeouts += 1
                r.crashes += 1
                st.down.add(w)
                requeue_back.append(b)
                continue
            if dur > self.push_timeout:
                # alive but slower than the timeout: retry with backoff,
                # give up (exclude this round only) past the budget
                cost, ok = self.push_timeout, False
                for _ in range(cfg.max_retries):
                    cost += self.backoff
                    d2 = inj.duration(w, t0 + cost, cfg.local_batch) \
                        + self.spec.ps_roundtrip
                    if d2 <= self.push_timeout:
                        cost += d2
                        ok = True
                        break
                    cost += self.push_timeout
                lat[w] = cost
                if ok:
                    part[w] = b
                else:
                    r.timeouts += 1
                    requeue_back.append(b)
                continue
            lat[w] = dur
            part[w] = b
        st.requeue.extend(requeue_back)
        if not part:
            if st.down and self._has_batches(st, num_batches):
                # every live worker idle and data remains: jump the
                # barrier clock to the earliest rejoin — no deadlock
                r.stalled_barriers += 1
                st.t = max(st.t, float(min(inj.down_until[w]
                                           for w in st.down)))
            else:
                st.finished = True
            return
    # tombstone token: Eq. (1) weight is EXACTLY zero, so excluded
    # slots change neither params nor loss, bit-for-bit
        tokens = np.full(m, st.gstep - cfg.iota - 1, np.int64)
        slot_batches: list = [self._zeros_batch] * m
        for w, b in part.items():
            tokens[w] = st.gstep
            slot_batches[w] = self.batch_fn(b)
        r.tombstones += m - len(part)
        loss = self._exec(st, tokens, slot_batches)
        step_time = float(lat.max()) + self.spec.allreduce_latency
        st.t = t0 + step_time
        if not math.isfinite(loss):
            r.apply_failures += 1
            return
        st.gstep += 1
        r.num_global_steps += 1
        r.mode_steps["sync"] = r.mode_steps.get("sync", 0) + 1
        r.samples += len(part) * cfg.local_batch
        r.losses.append(loss)
        for w in part:
            st.win_completions[w] += 1
            st.win_busy[w] += lat[w]

    # -- async mode: dispatch / fill / apply --------------------------------
    def _dispatch(self, st: _RunState, inj: FaultInjector, w: int,
                  now: float, num_batches: int) -> None:
        b = self._take_batch(st, num_batches)
        if b is None:
            return
        dur = inj.duration(w, now, self.cfg.local_batch) \
            + self.spec.ps_roundtrip
        heapq.heappush(st.heap, (now + dur, next(st.seq), "push", w, b,
                                 st.gstep, now))

    def _enter_async(self, st: _RunState) -> None:
        """(Re)build the event heap on entry into async mode: live
        workers dispatch immediately, down workers get a rejoin event at
        their recovery time."""
        inj = st.inj
        for w in range(self.m):
            if w in st.down:
                heapq.heappush(st.heap, (float(inj.down_until[w]),
                                         next(st.seq), "rejoin", w, -1,
                                         -1, 0.0))
            else:
                self._dispatch(st, inj, w, st.t, st.num_batches)

    def _async_round(self, st: _RunState, inj: FaultInjector,
                     num_batches: int, controller) -> None:
        r, cfg, m = st.result, self.cfg, self.m
        pending: list[tuple[int, int, int]] = []
        guard = 0
        while len(pending) < m:
            guard += 1
            if guard > 100_000:
                raise RuntimeError("switch driver stalled: async buffer "
                                   "fill made no progress")
            if not st.heap:
                break               # data exhausted: flush partial fill
            time_, _, kind, w, b, tok, t_disp = heapq.heappop(st.heap)
            if kind == "rejoin":
                st.t = max(st.t, time_)
                if w in st.down:
                    st.down.discard(w)
                    r.rejoins += 1
                self._dispatch(st, inj, w, time_, num_batches)
                continue
            ev = inj.crash_between(w, t_disp, time_)
            if ev is not None:
                # Alg. 1: the worker's gradient AND its token disappear;
                # it rejoins after recovery — the buffer keeps filling
                # from the surviving workers, so no pull ever blocks on
                # the crashed one
                r.crashes += 1
                r.lost_batches += 1
                st.down.add(w)
                st.t = max(st.t, ev.at)
                heapq.heappush(st.heap, (float(inj.down_until[w]),
                                         next(st.seq), "rejoin", w, -1,
                                         -1, 0.0))
                continue
            st.t = max(st.t, time_)
            pending.append((w, b, tok))
            st.win_completions[w] += 1
            st.win_busy[w] += time_ - t_disp
            self._dispatch(st, inj, w, time_, num_batches)
        if not pending:
            st.finished = True
            return
        gstep = st.gstep
        tokens = np.full(m, gstep - cfg.iota - 1, np.int64)
        slot_batches: list = [self._zeros_batch] * m
        for i, (w, b, tok) in enumerate(pending):
            tokens[i] = tok
            slot_batches[i] = self.batch_fn(b)
        r.tombstones += m - len(pending)
        if inj.apply_fails(gstep):
            # PS write dropped: gradients lost, params NOT committed
            r.apply_failures += 1
            self._breaker_tick(st, controller)
            return
        loss = self._exec(st, tokens, slot_batches)
        if not math.isfinite(loss):
            r.apply_failures += 1
            self._breaker_tick(st, controller)
            return
        st.breaker = 0
        kept = sum(1 for i in range(len(pending))
                   if gstep - tokens[i] <= cfg.iota)
        r.dropped_batches += len(pending) - kept
        r.samples += kept * cfg.local_batch
        st.gstep += 1
        r.num_global_steps += 1
        r.mode_steps["gba"] = r.mode_steps.get("gba", 0) + 1
        r.losses.append(loss)

    def _breaker_tick(self, st: _RunState, controller) -> None:
        """Consecutive async apply failures trip the fallback-to-sync
        circuit breaker; forcing the controller restarts its dwell
        window so the next decisions cannot flip straight back."""
        st.breaker += 1
        if st.breaker >= self.cfg.breaker_threshold and st.mode == "gba":
            st.result.breaker_trips += 1
            st.breaker = 0
            if controller is not None:
                controller.force("sync")
            self._swap(st, "sync", controller)

    # -- telemetry ----------------------------------------------------------
    def _window_rates(self, st: _RunState) -> np.ndarray:
        """Per-worker samples/s over the window, from BUSY time (compute
        only, not barrier wait) so sync mode still exposes per-worker
        capability; a worker with no completions reads exactly 0 — the
        controller's dead-worker marker."""
        rates = np.zeros(self.m)
        mask = st.win_busy > 0
        rates[mask] = (st.win_completions[mask] * self.cfg.local_batch
                       / st.win_busy[mask])
        return rates

    # -- entry points -------------------------------------------------------
    def run(self, num_batches: int, *, mode: str = "auto",
            controller: AutoSwitchController | None = None,
            mode_schedule: Callable[[int], str] | None = None,
            seed: int = 0) -> SwitchResult:
        """Event-driven run over ``num_batches`` local batches.

        ``mode="auto"`` lets the controller decide every
        ``decide_every`` global steps from live telemetry;
        ``mode="sync"`` / ``mode="gba"`` force one mode (the circuit
        breaker can still force sync); ``mode_schedule`` (gstep ->
        mode) overrides both — the forced-swap path the parity tests
        drive."""
        if mode not in ("auto", "sync", "gba"):
            raise ValueError(f"unknown mode {mode!r}")
        inj = FaultInjector(self.plan, self.spec, seed)
        if mode == "auto" and controller is None and mode_schedule is None:
            controller = AutoSwitchController(min_dwell=self.cfg.min_dwell)
        if mode != "auto":
            controller = None
        start = (mode_schedule(0) if mode_schedule is not None
                 else mode if mode != "auto" else "sync")
        st = self._fresh_state(start)
        st.mode = start
        st.inj = inj
        st.num_batches = num_batches
        if start == "gba":
            st.heap = []
            self._enter_async(st)
        last_decide = -1
        rounds = 0
        while not st.finished:
            rounds += 1
            if rounds > 1000 + 100 * num_batches:
                raise RuntimeError("switch driver stalled: no progress "
                                   f"after {rounds} rounds")
            pre_mode, pre_t = st.mode, st.t
            if st.mode == "sync":
                self._sync_round(st, inj, num_batches)
            else:
                self._async_round(st, inj, num_batches, controller)
            st.result.mode_time[pre_mode] = (
                st.result.mode_time.get(pre_mode, 0.0) + st.t - pre_t)
            if st.finished:
                break
            if mode_schedule is not None:
                want = mode_schedule(st.gstep)
                if want != st.mode:
                    self._swap(st, want)
            elif (controller is not None and st.gstep > 0
                    and st.gstep % self.cfg.decide_every == 0
                    and st.gstep != last_decide):
                last_decide = st.gstep
                rates = inj.scrape(st.t, self._window_rates(st))
                decision = controller.decide(
                    [] if rates is None else rates)
                st.win_completions[:] = 0.0
                st.win_busy[:] = 0.0
                if decision != st.mode:
                    self._swap(st, decision, controller)
        r = st.result
        r.wall_time = st.t
        r.dropped_scrapes = inj.dropped_scrapes
        r.param_flat, r.accum_flat = self._canonical_flat(st)
        if controller is not None:
            r.controller_summary = controller.summary()
        return r

    def run_schedule(self, steps: Sequence[GlobalStep],
                     modes: Sequence[str]) -> SwitchResult:
        """Replay a FIXED schedule of global steps (tokens + batch
        indices per slot) through the mode programs, swapping wherever
        ``modes`` changes — no sim clock, no faults.  This is the parity
        entry point: the same schedule replayed with and without swaps
        must produce bit-identical flat state when ``sync_impl="fused"``
        (one program family), and kernel-tolerance-identical for
        ``sync_impl="psum"`` (XLA psum vs sequential kernel sum differ
        in the last ulp)."""
        if len(steps) != len(modes):
            raise ValueError(f"{len(steps)} steps but {len(modes)} modes")
        for md in modes:
            if md not in ("sync", "gba"):
                raise ValueError(f"unknown mode {md!r}")
        st = self._fresh_state(modes[0] if steps else "sync")
        st.mode = modes[0] if steps else "sync"
        r = st.result
        for k, (gs, md) in enumerate(zip(steps, modes)):
            if md != st.mode:
                self._swap(st, md)
            if len(gs.tokens) != self.m or len(gs.batches) != self.m:
                raise ValueError(
                    f"step {k}: expected {self.m} slots, got "
                    f"{len(gs.tokens)} tokens / {len(gs.batches)} batches")
            tokens = np.asarray(gs.tokens, np.int64)
            slot_batches = [self._zeros_batch if b < 0 else self.batch_fn(b)
                            for b in gs.batches]
            r.tombstones += sum(1 for b in gs.batches if b < 0)
            loss = self._exec(st, tokens, slot_batches)
            kept = sum(1 for i, b in enumerate(gs.batches)
                       if b >= 0 and st.gstep - tokens[i] <= self.cfg.iota)
            real = sum(1 for b in gs.batches if b >= 0)
            r.dropped_batches += real - kept
            r.samples += kept * self.cfg.local_batch
            st.gstep += 1
            r.num_global_steps += 1
            r.mode_steps[md] = r.mode_steps.get(md, 0) + 1
            r.losses.append(loss)
        r.param_flat, r.accum_flat = self._canonical_flat(st)
        return r


# ---------------------------------------------------------------------------
# demo model + CLI (the Fig. 6 switching-trajectory bench drives this)
# ---------------------------------------------------------------------------

def demo_model(seed: int = 0):
    """Tiny MLP regression with deliberately non-tile-multiple leaves
    (1221-, 33-, 792-element leaves vs a 2048 tile) across three layer
    groups — exercises the padded carryover paths without costing
    compile time.  Returns (params, loss_fn, group_by)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    params = {
        "l1": {"w": 0.3 * jax.random.normal(ks[0], (37, 33)),
               "b": jnp.zeros((33,))},
        "l2": {"w": 0.3 * jax.random.normal(ks[1], (33, 24)),
               "b": jnp.zeros((24,))},
        "head": {"w": 0.3 * jax.random.normal(ks[2], (24, 5)),
                 "b": jnp.zeros((5,))},
    }

    def loss_fn(p, batch):
        h = jnp.tanh(batch["x"] @ p["l1"]["w"] + p["l1"]["b"])
        h = jnp.tanh(h @ p["l2"]["w"] + p["l2"]["b"])
        out = h @ p["head"]["w"] + p["head"]["b"]
        return jnp.mean((out - batch["y"]) ** 2)

    return params, loss_fn, (lambda path: path[0])


def demo_batch_fn(local_batch: int):
    """Deterministic per-index batches: index i always yields the same
    (x, y) — the property the parity tests rely on."""
    def batch_fn(i: int) -> dict:
        rng = np.random.default_rng(100_000 + i)
        return {"x": rng.standard_normal((local_batch, 37)
                                         ).astype(np.float32),
                "y": rng.standard_normal((local_batch, 5)
                                         ).astype(np.float32)}
    return batch_fn


def demo_plan(name: str, workers: int) -> FaultPlan:
    if name == "quiet":
        return FaultPlan.quiet(workers)
    if name == "strained":
        # the acceptance scenario: 25% stragglers at 4x + one transient
        # crash early enough that BOTH the auto and the forced-sync run
        # live through the outage and the rejoin
        return FaultPlan.strained(workers, straggler_frac=0.25,
                                  slowdown=4.0, crash_at=1.0,
                                  recovery=2.0)
    raise ValueError(f"unknown plan {name!r} (quiet|strained)")


def main(argv: list[str] | None = None) -> dict:
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N host-platform devices (consumed before "
                         "jax init by the module prologue)")
    ap.add_argument("--batches", type=int, default=240)
    ap.add_argument("--local-batch", type=int, default=256)
    ap.add_argument("--plan", default="strained",
                    choices=("quiet", "strained"))
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "sync", "gba"))
    ap.add_argument("--sync-impl", default="psum",
                    choices=("psum", "fused"))
    ap.add_argument("--decide-every", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compare-sync", action="store_true",
                    help="also run forced-sync on the same plan and "
                         "report speedup_vs_sync")
    ap.add_argument("--json", action="store_true",
                    help="print the result as one JSON line (last line "
                         "of stdout)")
    args = ap.parse_args(argv)

    if jax.device_count() < args.workers:
        ap.error(f"need {args.workers} devices, have {jax.device_count()} "
                 f"(use --host-devices on CPU)")
    mesh = jax.make_mesh((args.workers,), ("data",))
    params, loss_fn, group_by = demo_model()
    spec = ClusterSpec(num_workers=args.workers, base_speed=10_000.0,
                       jitter=0.05, allreduce_latency=0.005,
                       ps_roundtrip=0.001, seed=args.seed)
    plan = demo_plan(args.plan, args.workers)
    cfg = SwitchConfig(local_batch=args.local_batch,
                       decide_every=args.decide_every,
                       sync_impl=args.sync_impl)
    driver = SwitchDriver(mesh, loss_fn, params, spec=spec, plan=plan,
                          cfg=cfg, batch_fn=demo_batch_fn(args.local_batch),
                          group_by=group_by)
    res = driver.run(args.batches, mode=args.mode, seed=args.seed)
    out = res.to_json()
    out["plan"] = args.plan
    out["deadlocked"] = 0           # a stalled run raises, never returns
    if args.compare_sync:
        sync = driver.run(args.batches, mode="sync", seed=args.seed)
        out["sync_wall_time"] = sync.wall_time
        out["sync_qps"] = sync.qps
        out["sync_timeouts"] = sync.timeouts
        out["sync_rejoins"] = sync.rejoins
        out["speedup_vs_sync"] = (res.qps / sync.qps if sync.qps else
                                  float("nan"))
    if args.json:
        print(json.dumps(out))
    else:
        for k, v in out.items():
            print(f"{k}: {v}")
    return out


if __name__ == "__main__":
    main()
