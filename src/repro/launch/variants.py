"""Named perf variants for the §Perf hillclimb (EXPERIMENTS.md).

Each variant transforms (cfg, build options) before build_step; the dry-run
records the variant name so baseline vs optimized roofline terms can be
diffed.  ``baseline`` is the paper-faithful configuration.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.configs.base import ModelConfig

Transform = Callable[[ModelConfig, dict], tuple[ModelConfig, dict]]


def _baseline(cfg, opts):
    return cfg, opts


def _chunked_attn(cfg, opts):
    """Flash-style query chunking + remat: kills the (S,S) score temp."""
    return dataclasses.replace(cfg, attn_q_chunk=1024), opts


def _chunked_attn_512(cfg, opts):
    return dataclasses.replace(cfg, attn_q_chunk=512), opts


def _chunked_attn_2048(cfg, opts):
    return dataclasses.replace(cfg, attn_q_chunk=2048), opts


def _serve_tp(cfg, opts):
    """Inference sharding: replicate weights over `data` (pure TP) so
    decode doesn't all-gather FSDP-sharded params every token."""
    return cfg, {**opts, "serve_tp": True}


def _moe_capacity_1(cfg, opts):
    """Tighter MoE capacity factor: less dispatch padding traffic."""
    if cfg.num_experts:
        return dataclasses.replace(cfg, moe_capacity_factor=1.0), opts
    return cfg, opts


def _gba_m16(cfg, opts):
    from repro.configs.base import GBAConfig
    return cfg, {**opts, "gba": GBAConfig(local_batch=0, buffer_size=16)}


def _remat(cfg, opts):
    """Checkpoint each scanned block: backward recomputes the block instead
    of reading saved activations -> temp ~ 1 block instead of all."""
    return dataclasses.replace(cfg, remat_blocks=True), opts


def _chunked_loss(cfg, opts):
    """Seq-chunked CE: never materialize (B, S, V) f32 logits."""
    return dataclasses.replace(cfg, loss_seq_chunk=512), opts


def _full_opt(cfg, opts):
    """All memory optimizations together (the §Perf optimized config)."""
    return _chunked_loss(*_remat(*_chunked_attn(cfg, opts)))


def _mamba_split(cfg, opts):
    """Shard-aligned per-stream projections instead of the fused in_proj."""
    return dataclasses.replace(cfg, mamba_split_proj=True), opts


def _moe_ep(cfg, opts):
    """Expert-parallel constraints on the dispatch buffers (H3)."""
    return cfg, {**opts, "moe_ep": True}


VARIANTS: dict[str, Transform] = {
    "moe_ep": _moe_ep,
    "moe_ep_full": lambda c, o: _moe_ep(*_full_opt(c, o)),
    "mamba_split": _mamba_split,
    "mamba_split_remat": lambda c, o: _remat(*_mamba_split(c, o)),
    "remat": _remat,
    "chunked_remat": lambda c, o: _remat(*_chunked_attn(c, o)),
    "chunked_loss": _chunked_loss,
    "full_opt": _full_opt,
    "full_opt_moecap1": lambda c, o: _moe_capacity_1(*_full_opt(c, o)),
    "baseline": _baseline,
    "chunked_attn": _chunked_attn,
    "chunked_attn_512": _chunked_attn_512,
    "chunked_attn_2048": _chunked_attn_2048,
    "serve_tp": _serve_tp,
    "serve_tp_chunked": lambda c, o: _serve_tp(*_chunked_attn(c, o)),
    "moe_cap1": _moe_capacity_1,
    "moe_cap1_chunked": lambda c, o: _moe_capacity_1(*_chunked_attn(c, o)),
}
