"""Production meshes (TPU v5e class).

Defined as functions, not module-level constants, so importing this module
never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count=512`` before the first mesh build,
while tests/benches see the 1-device smoke mesh.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh() -> Mesh:
    """1-device mesh with production axis names, for CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


# v5e-class hardware constants used by the roofline analysis (task spec)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~4 links/chip usable)
