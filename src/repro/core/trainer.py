"""Schedule-replay trainer: PS-semantics training in JAX.

``repro.sim.cluster.simulate`` turns a cluster scenario + training mode into
a :class:`Schedule`; this module replays it with *real* gradients: the
gradient of every slot is computed against the parameter version of its
``dispatch_step`` (a ring of recent versions), then aggregated with the
mode's rule — GBA's token decay + per-ID embedding treatment, BSP's plain
mean, Hop-BW's drop-slowest, async's immediate apply.

This gives the accuracy experiments (paper Figs. 2/6/7/8) exact parameter-
server staleness semantics while remaining deterministic and laptop-fast.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.recsys import RecsysConfig
from repro.data.clickstream import ClickStream
from repro.metrics import StreamingAUC
from repro.models import recsys as R
from repro.optim import Optimizer
from repro.sim.cluster import Schedule

Params = Any

EMBED_KEYS = ("embed", "linear")   # the sparse module (DESIGN.md §2)


@dataclass
class ReplayStats:
    applied_steps: int = 0
    kept_slots: int = 0
    dropped_slots: int = 0
    history_clamps: int = 0
    embed_rows_rescued: int = 0     # per-ID relaxation kept a stale slot's row
    losses: list[float] = field(default_factory=list)


class VersionRing:
    """Last-H parameter versions for delayed-gradient computation."""

    def __init__(self, history: int):
        self._h = history
        self._ring: collections.OrderedDict[int, Params] = \
            collections.OrderedDict()

    def put(self, version: int, params: Params):
        self._ring[version] = params
        while len(self._ring) > self._h:
            self._ring.popitem(last=False)

    def get(self, version: int) -> tuple[Params, bool]:
        if version in self._ring:
            return self._ring[version], False
        oldest = next(iter(self._ring))
        return self._ring[oldest], True


def _split_tree(grads: Params) -> tuple[Params, Params]:
    sparse = {k: v for k, v in grads.items() if k in EMBED_KEYS}
    dense = {k: v for k, v in grads.items() if k not in EMBED_KEYS}
    return sparse, dense


@dataclass
class GBATrainer:
    cfg: RecsysConfig
    optimizer: Optimizer
    iota: int = 4
    per_id_embedding_decay: bool = True   # Alg. 2 lines 21/23
    history: int = 64

    def __post_init__(self):
        self._loss_grad = jax.jit(jax.value_and_grad(
            lambda p, b: R.bce_loss(p, self.cfg, b)))
        cap = self.cfg.hash_capacity
        self._present = jax.jit(
            lambda ids: jnp.zeros((cap,), jnp.float32).at[
                ids.reshape(-1)].add(1.0))

    def _batch_ids(self, batch: dict) -> np.ndarray:
        parts = [batch["fields"].reshape(-1)]
        if "behavior" in batch:
            parts.append(batch["behavior"].reshape(-1))
            parts.append(batch["target"].reshape(-1))
        return np.concatenate(parts)

    def replay(self, params: Params, opt_state: Any, schedule: Schedule,
               stream: ClickStream, day: int, *,
               last_update: jax.Array | None = None,
               stats: ReplayStats | None = None):
        """Replay one day's schedule.  Returns (params, opt_state,
        last_update, stats)."""
        stats = stats or ReplayStats()
        if last_update is None:
            last_update = jnp.zeros((self.cfg.hash_capacity,), jnp.int32)
        ring = VersionRing(self.history)
        gba = schedule.mode == "gba" and self.per_id_embedding_decay

        for k, slots in enumerate(schedule.steps):
            ring.put(k, params)
            m = len(slots)
            agg = None
            emb_num: dict[str, jax.Array] = {}
            emb_cnt: dict[str, jax.Array] = {}
            losses = []
            for slot in slots:
                src_params, clamped = ring.get(slot.dispatch_step)
                stats.history_clamps += int(clamped)
                batch = stream.batch(day, slot.batch_index)
                loss, grads = self._loss_grad(src_params, batch)
                losses.append(float(loss))
                sparse_g, dense_g = _split_tree(grads)
                w = slot.weight
                if gba:
                    # per-ID relaxation: a slot dropped by Eq.(1) may still
                    # contribute rows whose IDs were untouched since its token
                    present = self._present(
                        jnp.asarray(self._batch_ids(batch)))
                    slot_ok = (k - slot.token) <= self.iota
                    id_fresh = last_update <= slot.token
                    keep_row = (jnp.float32(slot_ok) + (1 - jnp.float32(
                        slot_ok)) * id_fresh.astype(jnp.float32))
                    row_mask = (present > 0).astype(jnp.float32) * keep_row
                    if not slot_ok:
                        stats.embed_rows_rescued += int(
                            jnp.sum(row_mask) > 0)
                    for name, g in sparse_g.items():
                        mask = row_mask if g.ndim == 1 else row_mask[:, None]
                        emb_num[name] = emb_num.get(name, 0) + g * mask
                        emb_cnt[name] = emb_cnt.get(name, 0) + row_mask
                else:
                    # same denominator semantics as the GBA path: an ID's
                    # contributor count is the number of SLOTS that touched
                    # it (Alg. 2 line 23), not its occurrence count
                    present = self._present(
                        jnp.asarray(self._batch_ids(batch)))
                    touched01 = (present > 0).astype(jnp.float32)
                    for name, g in sparse_g.items():
                        emb_num[name] = emb_num.get(name, 0) + g * w
                        emb_cnt[name] = (emb_cnt.get(name, 0)
                                         + touched01 * w)
                if w > 0:
                    stats.kept_slots += 1
                else:
                    stats.dropped_slots += 1
                scaled = jax.tree.map(lambda g: g * (w / m), dense_g)
                agg = scaled if agg is None else jax.tree.map(
                    jnp.add, agg, scaled)

            # embedding aggregate: divide by #slots that touched the ID
            # (Alg. 2 line 23); baselines divide by the same rule for parity
            full_grads = dict(agg)
            touched = None
            for name in emb_num:
                cnt = emb_cnt[name]
                cntc = jnp.maximum(cnt, 1.0)
                g = emb_num[name]
                full_grads[name] = g / (cntc[:, None] if g.ndim > 1 else cntc)
                touched = cnt > 0 if touched is None else (touched
                                                           | (cnt > 0))
            params, opt_state = self.optimizer.update(
                params, full_grads, opt_state)
            if touched is not None:
                last_update = jnp.where(touched, k, last_update)
            stats.applied_steps += 1
            stats.losses.append(float(np.mean(losses)))
        return params, opt_state, last_update, stats


def evaluate(params: Params, cfg: RecsysConfig, stream: ClickStream,
             day: int, num_batches: int = 16) -> float:
    logit_fn = jax.jit(lambda p, b: R.recsys_logit(p, cfg, b))
    sauc = StreamingAUC()
    for i in range(num_batches):
        batch = stream.batch(day, 10_000 + i)
        sauc.update(batch["label"], np.asarray(logit_fn(params, batch)))
    return sauc.compute()
