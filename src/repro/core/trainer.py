"""Schedule-replay trainer: PS-semantics training in JAX.

``repro.sim.cluster.simulate`` turns a cluster scenario + training mode into
a :class:`Schedule`; this module replays it with *real* gradients: the
gradient of every slot is computed against the parameter version of its
``dispatch_step`` (a ring of recent versions), then aggregated with the
mode's rule — GBA's token decay + per-ID embedding treatment, BSP's plain
mean, Hop-BW's drop-slowest, async's immediate apply.

Each global step is ONE jitted call: the M slot batches are stacked, the
per-slot gradients come from a single ``vmap`` over stacked parameter
versions, and the whole aggregate — token-decay weighting of the dense
module, per-ID mask/count accumulation for the sparse module, contributor
normalization, optimizer update and ``last_update`` stamping — happens
inside the compiled step.  The previous implementation dispatched M
sequential ``value_and_grad`` calls per step and accumulated masks in
Python; the batched step removes that host round-trip from the PS hot loop.

This gives the accuracy experiments (paper Figs. 2/6/7/8) exact parameter-
server staleness semantics while remaining deterministic and laptop-fast.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.recsys import RecsysConfig
from repro.data.clickstream import ClickStream
from repro.embeddings.table import StreamConfig, presence_counts
from repro.metrics import StreamingAUC
from repro.models import recsys as R
from repro.optim import Optimizer
from repro.sim.cluster import Schedule

Params = Any

EMBED_KEYS = ("embed", "linear")   # the sparse module (DESIGN.md §2)


@dataclass
class ReplayStats:
    applied_steps: int = 0
    kept_slots: int = 0
    dropped_slots: int = 0
    history_clamps: int = 0
    embed_rows_rescued: int = 0     # per-ID relaxation kept a stale slot's row
    losses: list[float] = field(default_factory=list)


class VersionRing:
    """Last-H parameter versions for delayed-gradient computation."""

    def __init__(self, history: int):
        self._h = history
        self._ring: collections.OrderedDict[int, Params] = \
            collections.OrderedDict()

    def put(self, version: int, params: Params):
        self._ring[version] = params
        while len(self._ring) > self._h:
            self._ring.popitem(last=False)

    def get(self, version: int) -> tuple[Params, bool]:
        if version in self._ring:
            return self._ring[version], False
        oldest = next(iter(self._ring))
        return self._ring[oldest], True


def _split_tree(grads: Params) -> tuple[Params, Params]:
    sparse = {k: v for k, v in grads.items() if k in EMBED_KEYS}
    dense = {k: v for k, v in grads.items() if k not in EMBED_KEYS}
    return sparse, dense


@dataclass
class GBATrainer:
    cfg: RecsysConfig
    optimizer: Optimizer
    iota: int = 4
    per_id_embedding_decay: bool = True   # Alg. 2 lines 21/23
    history: int = 64
    # production-capacity knob: when set, the per-slot presence counts come
    # from the streamed sorted-scatter kernel (O(block) VMEM at any
    # hash_capacity) instead of an XLA one-hot scatter per slot
    embed_stream: StreamConfig | None = None

    def __post_init__(self):
        self._loss_grad_fn = jax.value_and_grad(
            lambda p, b: R.bce_loss(p, self.cfg, b))
        self._loss_grad = jax.jit(self._loss_grad_fn)
        # jitted batched-step cache keyed by (gba, m, shared_src); shapes
        # are fixed per (config, stream) so each key compiles once
        self._step_cache: dict[tuple, Any] = {}

    # -- batched global step -------------------------------------------------

    def _flat_ids(self, batches: dict, m: int) -> jax.Array:
        """All hashed IDs each slot touched: (M, n_ids)."""
        parts = [batches["fields"].reshape(m, -1)]
        if "behavior" in batches:
            parts.append(batches["behavior"].reshape(m, -1))
            parts.append(batches["target"].reshape(m, -1))
        return jnp.concatenate(parts, axis=1)

    def _make_step(self, gba: bool, m: int, shared_src: bool):
        """Build the jitted per-global-step function.

        ``shared_src``: every slot dispatched at the same parameter version
        (sync-like schedules) — the gradients vmap over batches only, with
        the params broadcast, skipping the M-way parameter stack.
        """
        cap = self.cfg.hash_capacity
        iota = self.iota
        opt_update = self.optimizer.update
        grad_fn = self._loss_grad_fn
        in_axes = (None, 0) if shared_src else (0, 0)

        def step(src_params, params, opt_state, batches, tokens, weights,
                 step_k, last_update):
            losses, grads = jax.vmap(grad_fn, in_axes=in_axes)(
                src_params, batches)
            sparse_g, dense_g = _split_tree(grads)

            # dense module: Alg. 2 line 22 — weighted sum / N_a (= m)
            wm = (weights / m).astype(jnp.float32)
            agg = jax.tree.map(
                lambda g: jnp.tensordot(wm, g.astype(jnp.float32),
                                        axes=(0, 0)).astype(g.dtype),
                dense_g)

            # sparse module: per-ID treatment (Alg. 2 lines 21/23)
            ids_all = self._flat_ids(batches, m)
            if self.embed_stream is not None:
                # streamed counts: offsetting slot i's ids by i*cap turns
                # the M per-slot histograms into ONE sorted-scatter kernel
                # launch over an (M*cap)-row id space — a single sort, no
                # XLA one-hot scatter, O(block) VMEM at any capacity
                slot_offset = (jnp.arange(m, dtype=jnp.int32) * cap)[:, None]
                present = presence_counts(
                    ids_all + slot_offset, m * cap,
                    stream=self.embed_stream).reshape(m, cap)
            else:
                present = jax.vmap(
                    lambda ids: jnp.zeros((cap,),
                                          jnp.float32).at[ids].add(1.0)
                )(ids_all)
            touched01 = (present > 0).astype(jnp.float32)       # (M, cap)
            rescued = jnp.int32(0)
            if gba:
                # per-ID relaxation: a slot dropped by Eq.(1) may still
                # contribute rows whose IDs were untouched since its token
                slot_ok = (step_k - tokens) <= iota             # (M,)
                id_fresh = last_update[None, :] <= tokens[:, None]
                keep_row = jnp.where(slot_ok[:, None], 1.0,
                                     id_fresh.astype(jnp.float32))
                row_mask = touched01 * keep_row                 # (M, cap)
                rescued = jnp.sum(
                    ((~slot_ok) & (jnp.sum(row_mask, axis=1) > 0)
                     ).astype(jnp.int32))
                emb_num = {
                    name: jnp.sum(
                        g * (row_mask[..., None] if g.ndim == 3
                             else row_mask), axis=0)
                    for name, g in sparse_g.items()
                }
                emb_cnt = jnp.sum(row_mask, axis=0)
            else:
                # same denominator semantics as the GBA path: an ID's
                # contributor count is the number of SLOTS that touched
                # it (Alg. 2 line 23), not its occurrence count
                emb_num = {
                    name: jnp.tensordot(weights, g, axes=(0, 0))
                    for name, g in sparse_g.items()
                }
                emb_cnt = jnp.sum(touched01 * weights[:, None], axis=0)

            # embedding aggregate: divide by #slots that touched the ID
            # (Alg. 2 line 23); baselines divide by the same rule for parity
            full_grads = dict(agg)
            cntc = jnp.maximum(emb_cnt, 1.0)
            for name, g in emb_num.items():
                full_grads[name] = g / (cntc[:, None] if g.ndim > 1
                                        else cntc)
            params, opt_state = opt_update(params, full_grads, opt_state)
            if sparse_g:
                touched = emb_cnt > 0
                last_update = jnp.where(touched, step_k, last_update)
            return params, opt_state, last_update, losses, rescued

        return jax.jit(step)

    def _get_step(self, gba: bool, m: int, shared_src: bool):
        key = (gba, m, shared_src)
        if key not in self._step_cache:
            self._step_cache[key] = self._make_step(gba, m, shared_src)
        return self._step_cache[key]

    # -- schedule replay -----------------------------------------------------

    def replay(self, params: Params, opt_state: Any, schedule: Schedule,
               stream: ClickStream, day: int, *,
               last_update: jax.Array | None = None,
               stats: ReplayStats | None = None):
        """Replay one day's schedule.  Returns (params, opt_state,
        last_update, stats)."""
        stats = stats or ReplayStats()
        if last_update is None:
            last_update = jnp.zeros((self.cfg.hash_capacity,), jnp.int32)
        ring = VersionRing(self.history)
        gba = schedule.mode == "gba" and self.per_id_embedding_decay

        for k, slots in enumerate(schedule.steps):
            ring.put(k, params)
            m = len(slots)
            srcs = []
            for slot in slots:
                src, clamped = ring.get(slot.dispatch_step)
                stats.history_clamps += int(clamped)
                srcs.append(src)
            shared_src = all(s.dispatch_step == slots[0].dispatch_step
                             for s in slots)
            if shared_src:
                src_params = srcs[0]
            else:
                src_params = jax.tree.map(lambda *xs: jnp.stack(xs), *srcs)
            raw = [stream.batch(day, slot.batch_index) for slot in slots]
            batches = {key: jnp.asarray(np.stack([b[key] for b in raw]))
                       for key in raw[0]}
            tokens = jnp.asarray([s.token for s in slots], jnp.int32)
            weights = jnp.asarray([s.weight for s in slots], jnp.float32)
            step_fn = self._get_step(gba, m, shared_src)
            params, opt_state, last_update, losses, rescued = step_fn(
                src_params, params, opt_state, batches, tokens, weights,
                jnp.int32(k), last_update)
            for slot in slots:
                if slot.weight > 0:
                    stats.kept_slots += 1
                else:
                    stats.dropped_slots += 1
            stats.embed_rows_rescued += int(rescued)
            stats.applied_steps += 1
            stats.losses.append(float(jnp.mean(losses)))
        return params, opt_state, last_update, stats


def evaluate(params: Params, cfg: RecsysConfig, stream: ClickStream,
             day: int, num_batches: int = 16) -> float:
    logit_fn = jax.jit(lambda p, b: R.recsys_logit(p, cfg, b))
    sauc = StreamingAUC()
    for i in range(num_batches):
        batch = stream.batch(day, 10_000 + i)
        sauc.update(batch["label"], np.asarray(logit_fn(params, batch)))
    return sauc.compute()
