"""Continual-training experiment driver (paper Sec. 5 protocol).

Inherit a base model, then for each day: train on day ``d`` under a given
training mode / cluster scenario, evaluate on day ``d+1``.  Mode switching
is expressed by just changing the mode between days — the whole point of
the paper is that GBA makes this tuning-free.

The mode hyper-parameters mirror Tab. 5.1's structure at laptop scale:
sync uses ``N_s`` workers with local batch ``B_s``; GBA uses ``M`` workers
with local batch ``B_a = B_s * N_s / M`` (same global batch); the baselines
use their own knobs (b1/b2/b3).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any

import jax
import numpy as np

from repro.configs.recsys import RecsysConfig
from repro.core.trainer import GBATrainer, ReplayStats, evaluate
from repro.data.clickstream import ClickStream
from repro.optim import get_optimizer
from repro.sim.cluster import ClusterSpec, Schedule, simulate


@dataclass(frozen=True)
class ModeSetup:
    """One training mode's worker/batch geometry (a row of Tab. 5.1)."""

    mode: str
    num_workers: int
    local_batch: int
    optimizer: str = "adam"
    learning_rate: float = 6e-4
    buffer_size: int = 0       # GBA M; defaults to num_workers
    iota: int = 4
    b1: int = 2                # Hop-BS bound
    b2: int = 8                # BSP aggregation count
    b3: int = 2                # Hop-BW backup count

    @property
    def global_batch(self) -> int:
        m = self.buffer_size or self.num_workers
        if self.mode in ("sync", "hop_bw"):
            return self.local_batch * self.num_workers
        if self.mode == "gba":
            return self.local_batch * m
        if self.mode == "bsp":
            return self.local_batch * self.b2
        return self.local_batch  # async / hop_bs apply per gradient


def default_setups(base_global: int = 4096) -> dict[str, ModeSetup]:
    """Scaled-down analogue of Tab. 5.1: sync 8x512; GBA 16 workers x256
    with M=16 (same global batch); async/hop_bs per-gradient; BSP b2=8
    (mismatched global batch, as in the paper); Hop-BW drops 2/16."""
    return {
        "sync": ModeSetup("sync", 8, base_global // 8),
        # set-A hyper-params (tuned async, Tab. 5.1: Adagrad, higher lr)
        "async": ModeSetup("async", 16, 256, optimizer="adagrad",
                           learning_rate=1e-3),
        # Fig. 2's failure mode: async with the SYNC hyper-parameter set —
        # per-small-batch Adam steps at a large-batch learning rate
        "async_setS": ModeSetup("async", 16, 256),
        "hop_bs": ModeSetup("hop_bs", 16, 256, b1=2),
        # BSP's b2 mismatches the sync global batch, as in Tab. 5.1
        # (800K vs 1.28M on Criteo)
        "bsp": ModeSetup("bsp", 16, 256, b2=max(2, base_global // 512)),
        # paper proportion: b3/N = 100/400 = 25% of gradients discarded
        "hop_bw": ModeSetup("hop_bw", 16, base_global // 16, b3=4),
        "gba": ModeSetup("gba", 16, base_global // 16, buffer_size=16,
                         iota=4),
    }


def schedule_for_day(setup: ModeSetup, spec: ClusterSpec, num_batches: int
                     ) -> Schedule:
    spec = replace(spec, num_workers=setup.num_workers)
    return simulate(spec, setup.mode, num_batches, setup.local_batch,
                    buffer_size=setup.buffer_size or setup.num_workers,
                    iota=setup.iota, b1=setup.b1, b2=setup.b2, b3=setup.b3)


@dataclass
class ContinualResult:
    mode_per_day: list[str]
    auc_per_day: list[float]
    qps_per_day: list[float]
    stats: ReplayStats


def run_continual(params: Any, cfg: RecsysConfig, stream: ClickStream,
                  day_modes: list[str], setups: dict[str, ModeSetup],
                  spec: ClusterSpec, *, batches_per_day: int | None = None,
                  eval_batches: int = 16, start_day: int = 0,
                  seed: int = 0) -> tuple[Any, ContinualResult]:
    """Train day-by-day with per-day training mode; evaluate on day d+1."""
    stats = ReplayStats()
    result = ContinualResult([], [], [], stats)
    opt_state = None
    trainer = None
    last_update = None
    current_opt_key = None

    for i, mode in enumerate(day_modes):
        day = start_day + i
        setup = setups[mode]
        nb = batches_per_day or stream.batches_per_day
        # number of raw batches scales with local batch so each mode sees the
        # same number of samples per day
        samples = nb * stream.batch_size
        num_batches = max(setup.num_workers, samples // setup.local_batch)
        sched = schedule_for_day(
            setup, replace(spec, seed=spec.seed + day), num_batches)
        opt_key = (setup.optimizer, setup.learning_rate)
        if trainer is None or opt_key != current_opt_key:
            # switching modes keeps hyper-params unless the experiment
            # explicitly assigns a different set (paper's set A vs set S)
            optimizer = get_optimizer(setup.optimizer, setup.learning_rate)
            trainer = GBATrainer(cfg, optimizer, iota=setup.iota)
            opt_state = optimizer.init(params)
            current_opt_key = opt_key
        day_stream = replace_stream_batch(stream, setup.local_batch)
        params, opt_state, last_update, stats = trainer.replay(
            params, opt_state, sched, day_stream, day,
            last_update=last_update, stats=stats)
        auc = evaluate(params, cfg, stream, day + 1, eval_batches)
        result.mode_per_day.append(mode)
        result.auc_per_day.append(auc)
        result.qps_per_day.append(sched.metrics.qps)
    return params, result


def replace_stream_batch(stream: ClickStream, batch_size: int) -> ClickStream:
    if stream.batch_size == batch_size:
        return stream
    return ClickStream(stream.cfg, stream.seed, stream.zipf_a,
                       stream.num_days, stream.batches_per_day, batch_size,
                       stream.drift)


def pretrain_sync(key, cfg: RecsysConfig, stream: ClickStream,
                  setups: dict[str, ModeSetup], spec: ClusterSpec,
                  num_days: int) -> Any:
    """Train the 'base model' the paper inherits from, in sync mode."""
    from repro.models.recsys import init_recsys
    params = init_recsys(key, cfg)
    params, _ = run_continual(params, cfg, stream, ["sync"] * num_days,
                              setups, spec)
    return params
