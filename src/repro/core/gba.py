"""GBA aggregation — the paper's core op, as jittable JAX functions.

Two entry points:

* :func:`aggregate_dense` — Algorithm 2 lines 20/22: decay each of the M
  buffered gradients by the token-control rule, weighted-sum, divide by
  ``N_a = M``.  Used for every dense parameter and, stacked per-leaf, for
  whole LM parameter pytrees.

* :func:`aggregate_embedding` — Algorithm 2 lines 21/23: per-ID treatment of
  the sparse module.  Each buffered sparse gradient arrives as (ids, rows);
  a row is decayed against the global step *its ID* last saw (the tagged
  ``last_update``), and the aggregate is divided by the number of buffer
  slots that actually touched the ID — not by M.

Both are pure functions usable inside pjit/shard_map; the Pallas kernel in
``repro.kernels.gba_aggregate`` is a drop-in replacement for the inner
weighted reduction of :func:`aggregate_dense`.

Flat-buffer layout (the PS hot path)
------------------------------------
``buffer_push_and_maybe_apply`` keeps the buffer as a pytree mirroring the
gradients — one XLA op chain per leaf on every push AND every apply.  The
fused path instead ravels all dense leaves into a single ``(M, N_total)``
f32 buffer using :class:`FlatLayout`: leaves are laid out back-to-back in
treedef order, each occupying ``[offsets[j], offsets[j] + sizes[j])`` of
the flat axis.  A push is then one ``dynamic_update_index_in_dim`` and an
apply is ONE launch of the fused ``repro.kernels.gba_apply`` kernel
(token-decay aggregation + Adagrad in a single VMEM pass), instead of a
per-leaf aggregate -> HBM -> per-leaf optimizer chain.  See
:func:`init_flat_buffer` / :func:`flat_buffer_push_and_maybe_apply`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.staleness import DECAY_FNS, threshold_decay

Params = Any


def decay_weights(tokens: jax.Array, global_step: jax.Array, iota: int,
                  strategy: str = "threshold") -> jax.Array:
    """(M,) aggregation weights from the token-control rule."""
    return DECAY_FNS[strategy](tokens, global_step, iota)


def aggregate_dense(grads_stacked: Params, tokens: jax.Array,
                    global_step: jax.Array, iota: int,
                    strategy: str = "threshold") -> Params:
    """grads_stacked: pytree with leading M axis -> decayed mean over M.

    Follows Alg. 2 line 22: weighted sum divided by N_a (= M), so dropped
    slots shrink the effective gradient rather than re-normalizing — the
    paper's choice, which keeps the update scale consistent with a full
    buffer."""
    w = decay_weights(tokens, global_step, iota, strategy)
    m = w.shape[0]

    def agg(g):
        wf = w.reshape((m,) + (1,) * (g.ndim - 1)).astype(jnp.float32)
        return (jnp.sum(g.astype(jnp.float32) * wf, axis=0) / m).astype(
            g.dtype)

    return jax.tree.map(agg, grads_stacked)


def aggregate_embedding(ids_stacked: jax.Array, rows_stacked: jax.Array,
                        tokens: jax.Array, last_update: jax.Array,
                        global_step: jax.Array, iota: int, capacity: int,
                        valid: jax.Array | None = None
                        ) -> tuple[jax.Array, jax.Array]:
    """Per-ID sparse aggregation (Alg. 2 lines 21/23).

    ids_stacked:  (M, n) int32 hashed IDs per buffer slot
    rows_stacked: (M, n, D) gradient rows aligned with ids
    tokens:       (M,) slot tokens
    last_update:  (capacity,) int32 global step each ID last saw
    valid:        optional (M, n) bool — explicit padding mask; False
                  slots are excluded outright

    A slot's row for an ID is kept iff the ID is *not* severely stale w.r.t.
    that slot's token: either the ID has not been updated since the token
    was issued (data unchanged -> gradient still valid, Insight 2), or the
    staleness k - token is within iota.  Kept rows are summed and divided by
    the number of slots that touched the ID.

    Padded batches: IDs outside ``[0, capacity)`` — the streamed kernels'
    sentinel convention (``repro.kernels.embedding_bag`` maps batch
    padding to an out-of-range sentinel) — are treated as padding and
    contribute to NEITHER the dense aggregate NOR the per-ID contributor
    counts (Alg. 2 line 23's divisor counts real contributors only).
    Without the mask a padded slot would inflate ``counts`` for whatever
    row its sentinel aliased (negative IDs wrap in XLA scatters) and
    scatter ghost gradient rows into the aggregate.

    Returns (dense_grad (capacity, D), counts (capacity,)).
    """
    M, n = ids_stacked.shape
    D = rows_stacked.shape[-1]
    # padding mask: the kernels' sentinel-ID convention, optionally ANDed
    # with an explicit caller mask
    in_range = (ids_stacked >= 0) & (ids_stacked < capacity)     # (M, n)
    if valid is not None:
        in_range = in_range & valid
    safe_ids = jnp.where(in_range, ids_stacked, 0)
    # slot-level hard threshold (same Eq. (1) clock)...
    slot_ok = (global_step - tokens) <= iota                     # (M,)
    # ...relaxed per-ID: if the ID was never updated after the token was
    # issued, its gradient is exact regardless of slot staleness.
    id_last = last_update[safe_ids]                              # (M, n)
    id_fresh = id_last <= tokens[:, None]
    keep = (slot_ok[:, None] | id_fresh) & in_range              # (M, n)

    flat_ids = safe_ids.reshape(-1)
    flat_keep = keep.reshape(-1).astype(jnp.float32)
    flat_rows = rows_stacked.reshape(-1, D).astype(jnp.float32)
    flat_rows = flat_rows * flat_keep[:, None]

    dense = jnp.zeros((capacity, D), jnp.float32).at[flat_ids].add(flat_rows)
    counts = jnp.zeros((capacity,), jnp.float32).at[flat_ids].add(flat_keep)
    dense = dense / jnp.maximum(counts, 1.0)[:, None]
    return dense, counts


# ---------------------------------------------------------------------------
# GBA as a first-class train-step transform (used by launch/train + dry-run)
# ---------------------------------------------------------------------------

def init_buffer(params: Params, buffer_size: int) -> dict:
    """M-slot gradient buffer living alongside the optimizer state.  Each
    leaf gets a leading M axis; sharded exactly like the gradient."""
    return {
        "grads": jax.tree.map(
            lambda p: jnp.zeros((buffer_size,) + p.shape, p.dtype), params),
        "tokens": jnp.zeros((buffer_size,), jnp.int32),
        "fill": jnp.zeros((), jnp.int32),
        "step": jnp.zeros((), jnp.int32),
    }


def buffer_push_and_maybe_apply(
        buffer: dict, grads: Params, token: jax.Array, iota: int,
        apply_fn: Callable[[Params], tuple], noop_fn: Callable[[], tuple],
        strategy: str = "threshold"):
    """Push one gradient into the buffer; when full, decay-aggregate and call
    ``apply_fn(agg_grads)``, else ``noop_fn()``.  Pure function of its
    inputs; lowers to a single ``lax.cond`` — this is the shape the sharded
    train step uses so that GBA is part of the compiled program."""
    m = buffer["tokens"].shape[0]
    slot = buffer["fill"] % m
    new_grads = jax.tree.map(
        lambda b, g: jax.lax.dynamic_update_index_in_dim(
            b, g.astype(b.dtype), slot, 0),
        buffer["grads"], grads)
    new_tokens = jax.lax.dynamic_update_index_in_dim(
        buffer["tokens"], token.astype(jnp.int32), slot, 0)
    fill = buffer["fill"] + 1
    is_full = (fill % m) == 0

    def do_apply(operands):
        bgrads, btokens, step = operands
        agg = aggregate_dense(bgrads, btokens, step, iota, strategy)
        return apply_fn(agg)

    def do_noop(operands):
        return noop_fn()

    out = jax.lax.cond(is_full, do_apply, do_noop,
                       (new_grads, new_tokens, buffer["step"]))
    new_buffer = {
        "grads": new_grads,
        "tokens": new_tokens,
        "fill": fill,
        "step": buffer["step"] + is_full.astype(jnp.int32),
    }
    return out, new_buffer


# ---------------------------------------------------------------------------
# flat buffer: one (M, N_total) array + offsets table -> one kernel launch
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FlatLayout:
    """Ravel/unravel a dense parameter pytree to one flat f32 vector.

    Leaves are concatenated in ``jax.tree`` (treedef) order; leaf ``j``
    lives at ``flat[offsets[j] : offsets[j] + sizes[j]]``.  The layout is a
    host-side object (hashable tuples only) so it can be closed over by
    jitted train steps.
    """

    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    sizes: tuple[int, ...]
    offsets: tuple[int, ...]
    total: int

    @classmethod
    def from_params(cls, params: Params) -> "FlatLayout":
        leaves, treedef = jax.tree.flatten(params)
        shapes = tuple(tuple(l.shape) for l in leaves)
        dtypes = tuple(l.dtype for l in leaves)
        sizes = tuple(math.prod(s) for s in shapes)
        offsets = []
        off = 0
        for s in sizes:
            offsets.append(off)
            off += s
        return cls(treedef, shapes, dtypes, sizes, tuple(offsets), off)

    def ravel(self, tree: Params) -> jax.Array:
        leaves = jax.tree.leaves(tree)
        return jnp.concatenate(
            [l.reshape(-1).astype(jnp.float32) for l in leaves])

    def unravel(self, flat: jax.Array) -> Params:
        leaves = [
            flat[o:o + n].reshape(s).astype(dt)
            for o, n, s, dt in zip(self.offsets, self.sizes, self.shapes,
                                   self.dtypes)
        ]
        return jax.tree.unflatten(self.treedef, leaves)


def init_flat_buffer(params: Params, buffer_size: int
                     ) -> tuple[FlatLayout, dict]:
    """Flat M-slot gradient buffer: one (M, N_total) array instead of a
    leading-M pytree.  Returns (layout, buffer)."""
    layout = FlatLayout.from_params(params)
    return layout, {
        "grads": jnp.zeros((buffer_size, layout.total), jnp.float32),
        "tokens": jnp.zeros((buffer_size,), jnp.int32),
        "fill": jnp.zeros((), jnp.int32),
        "step": jnp.zeros((), jnp.int32),
    }


def flat_buffer_push(buffer: dict, flat_grad: jax.Array, token: jax.Array
                     ) -> tuple[dict, jax.Array]:
    """Push one raveled gradient into the flat buffer.  Returns
    ``(new_buffer, is_full)``; ``new_buffer["step"]`` is already advanced
    when the push filled the buffer, but ``new_buffer["tokens"]`` /
    ``["grads"]`` still hold the slots for the apply that must follow
    (the single source of slot/fill/step arithmetic for the fused path).
    """
    m = buffer["tokens"].shape[0]
    slot = buffer["fill"] % m
    new_grads = jax.lax.dynamic_update_index_in_dim(
        buffer["grads"], flat_grad.astype(jnp.float32), slot, 0)
    new_tokens = jax.lax.dynamic_update_index_in_dim(
        buffer["tokens"], token.astype(jnp.int32), slot, 0)
    fill = buffer["fill"] + 1
    is_full = (fill % m) == 0
    new_buffer = {
        "grads": new_grads,
        "tokens": new_tokens,
        "fill": fill,
        "step": buffer["step"] + is_full.astype(jnp.int32),
    }
    return new_buffer, is_full


def flat_buffer_push_and_maybe_apply(
        buffer: dict, flat_grad: jax.Array, token: jax.Array,
        param_flat: jax.Array, accum_flat: jax.Array, lr, *, iota: int,
        eps: float = 1e-10, interpret: bool | None = None):
    """Fused-path counterpart of :func:`buffer_push_and_maybe_apply`.

    Pushes one raveled gradient; when the buffer fills, runs the fused
    ``gba_apply`` Pallas kernel (decay-aggregate + Adagrad, one launch for
    the whole dense module).  Returns
    ``(new_param_flat, new_accum_flat, applied, new_buffer)`` — on non-full
    pushes params/accum pass through unchanged.

    Callers that keep params as a pytree (``launch.steps``'s fused train
    step ravels/unravels inside the apply branch only) use
    :func:`flat_buffer_push` directly and wrap their own ``lax.cond``.
    """
    from repro.kernels import ops

    new_buffer, is_full = flat_buffer_push(buffer, flat_grad, token)

    def do_apply(operands):
        p, a, grads, tokens, step = operands
        return ops.gba_apply_flat(p, a, grads, tokens, step, lr,
                                  iota=iota, eps=eps, interpret=interpret)

    def do_noop(operands):
        p, a, *_ = operands
        return p, a

    new_param, new_accum = jax.lax.cond(
        is_full, do_apply, do_noop,
        (param_flat, accum_flat, new_buffer["grads"], new_buffer["tokens"],
         buffer["step"]))
    return new_param, new_accum, is_full, new_buffer
