"""Beyond-paper: adaptive sync/GBA switching (the paper's §6 future work).

The paper: "Currently, GBA requires the users to select the training mode
according to their own judgment on the cluster status.  In the future, we
will attempt to make GBA be adaptive to the cluster status."

GBA makes switching *free*; this controller decides *when*.  It uses only
PS-observable telemetry — per-worker completed-batch counts over the last
window — and estimates what each mode's throughput would be on the current
cluster:

  sync QPS  ~= N * B * min_w(rate_w)     (barrier: slowest worker paces all)
  GBA QPS   ~= B * sum_w(rate_w)         (no waiting)

It switches to GBA when the estimated speedup exceeds ``switch_up`` (with
hysteresis ``switch_down`` for the way back, to avoid flapping).  Because
GBA holds the global batch, switching costs no accuracy (C2) — so the
controller optimizes pure throughput.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class AutoSwitchController:
    switch_up: float = 1.5      # est. GBA/sync speedup to leave sync
    switch_down: float = 1.15   # est. speedup below which to return
    mode: str = "sync"
    max_history: int = 4096     # decisions kept; long runs stay bounded
    min_dwell: int = 0          # decisions to hold a mode after any switch
    history: list = field(default_factory=list)
    # optional per-mode wire cost, mode -> estimated bytes each worker
    # puts on the wire per global step (e.g. from
    # CompressionPolicy.wire_bytes / layout.padded_total * 4).  Telemetry
    # plumbing ONLY — the switching policy never reads it.
    wire_bytes_per_step: dict | None = None
    dead_workers: int = 0       # zero-rate workers in the last window
    # decisions since the last mode change (switch or force); starts past
    # any dwell so a fresh controller can move on its first decision
    _since_switch: int = field(default=1 << 30, repr=False)

    def estimate_speedup(self, worker_rates) -> float:
        """worker_rates: per-worker samples/s measured over the window
        (``SimMetrics.worker_rates``; on a real PS: completions / wall).

        An EMPTY window — every worker stalled, or the telemetry scrape
        raced the first completion — carries no signal: returns NaN
        rather than crashing on ``min()`` of nothing, and ``decide``
        keeps the current mode (NaN compares False against both
        thresholds).

        A rate of EXACTLY zero is a dead worker (crashed / stalled all
        window), not an infinitely slow one: it is excluded from the
        sync ``min()`` — a barrier would drop it rather than wait
        forever — and counted in :attr:`dead_workers` (``summary()``
        reports it).  All-dead degenerates to the empty window: NaN,
        mode held.  Previously a single zero rate returned ``inf``,
        which instantly forced mode="gba" and pinned it there."""
        rates = np.asarray(worker_rates, dtype=np.float64)
        if rates.size == 0:
            return float("nan")
        alive = rates[rates > 0]
        self.dead_workers = int(rates.size - alive.size)
        if alive.size == 0:
            return float("nan")
        sync_qps = len(alive) * alive.min()
        gba_qps = alive.sum()
        return float(gba_qps / sync_qps)

    def decide(self, worker_rates) -> str:
        """One telemetry decision.  A mode change is only allowed once
        ``min_dwell`` decisions have passed since the previous change
        (or :meth:`force`), so one noisy window cannot flap modes —
        each flap costs a drain + state carryover on the driver."""
        s = self.estimate_speedup(worker_rates)
        prev = self.mode
        if self._since_switch >= self.min_dwell:
            if self.mode == "sync" and s >= self.switch_up:
                self.mode = "gba"
            elif self.mode == "gba" and s <= self.switch_down:
                self.mode = "sync"
        self._since_switch = 0 if self.mode != prev \
            else self._since_switch + 1
        self.history.append((s, self.mode))
        if len(self.history) > self.max_history:
            del self.history[:len(self.history) - self.max_history]
        return self.mode

    def force(self, mode: str) -> str:
        """External override (the driver's fallback-to-sync circuit
        breaker): set the mode and restart the dwell window, so the next
        ``min_dwell`` decisions cannot immediately flip back."""
        if mode not in ("sync", "gba"):
            raise ValueError(f"unknown mode {mode!r}")
        self.mode = mode
        self._since_switch = 0
        return self.mode

    def summary(self) -> dict:
        """Telemetry snapshot: current mode, last estimated speedup
        (NaN before any decision — including one made on an empty
        window), decision count, zero-rate (dead) worker count of the
        last non-empty window, and — when ``wire_bytes_per_step`` was
        provided — the current mode's estimated ``bytes_on_wire`` per
        worker per global step plus the full per-mode map.  Read-only:
        never mutates controller state or the switching policy."""
        out = {
            "mode": self.mode,
            "last_speedup": (self.history[-1][0] if self.history
                             else float("nan")),
            "decisions": len(self.history),
            "dead_workers": self.dead_workers,
        }
        if self.wire_bytes_per_step is not None:
            out["bytes_on_wire"] = self.wire_bytes_per_step.get(self.mode)
            out["wire_bytes_per_step"] = dict(self.wire_bytes_per_step)
        return out
