"""GBA with explicit collectives via shard_map (one PS worker per device).

The pjit train step (launch.steps) treats the whole pod as ONE worker
filling the M-slot buffer over time.  This module expresses the orthogonal
mapping: every device group along the `data` axis is its own worker, each
carrying its OWN token, and one global step aggregates all M = |data|
worker gradients with the token-control decay — Algorithm 2 as a single
`lax.psum` of pre-decayed gradients:

    agg = psum_m( f(token_m, k) * grad_m / M )

which is exactly ``aggregate_dense`` (tested equivalent), but with the
collective schedule explicit — the form you deploy when worker batches
genuinely differ per device (e.g. heterogeneous data streams).

:func:`make_gba_fused_psum_step` is the fused rendering of the same
mapping: every device doubles as a PS shard owning a contiguous
tile-aligned slice of the flat parameter vector
(``core.flat_sharded.ShardedFlatLayout``).  Workers all-gather the flat
params for the forward, then an ``all_to_all`` routes each worker's
gradient slice to its owning shard — the PS "write", worker->shard only,
never shard<->shard — building the ``(M, shard_size)`` buffer on which
ONE ``gba_apply`` launch does the token-decay aggregation AND the Adagrad
update.  The only ``psum`` left is the scalar loss; the per-leaf
aggregate -> optimizer chain (and its per-leaf launches) is gone.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.flat_sharded import ShardedFlatLayout
from repro.core.staleness import threshold_decay


def make_gba_psum_step(mesh: Mesh, loss_fn: Callable, optimizer,
                       iota: int, axis: str = "data"):
    """Returns step(params, opt_state, batch, tokens, gstep) ->
    (params, opt_state, loss).

    batch: pytree with leading GLOBAL batch dim sharded over ``axis``;
    tokens: (M,) int32, one per worker (device group along ``axis``).
    """
    m = mesh.shape[axis]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P()),
        out_specs=(P(), P()),
        check_rep=False)
    def grad_agg(params, batch, token, gstep):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        w = threshold_decay(token.reshape(-1)[:1], gstep, iota)[0]
        g = jax.tree.map(lambda x: x * (w / m).astype(x.dtype), g)
        g = lax.psum(g, axis)              # decayed aggregate (Alg. 2 l.22)
        loss = lax.psum(loss * w, axis) / m
        return g, loss

    def step(params, opt_state, batch, tokens, gstep):
        agg, loss = grad_agg(params, batch, tokens, gstep)
        params, opt_state = optimizer.update(params, agg, opt_state)
        return params, opt_state, loss

    return step


def make_gba_fused_psum_step(mesh: Mesh, loss_fn: Callable,
                             layout: ShardedFlatLayout, *, iota: int,
                             lr: float, eps: float = 1e-10,
                             axis: str = "data",
                             interpret: bool | None = None):
    """Fused PS rendering of :func:`make_gba_psum_step` (Adagrad only).

    Returns ``step(param_flat, accum_flat, batch, tokens, gstep) ->
    (new_param_flat, new_accum_flat, loss)`` where ``param_flat`` /
    ``accum_flat`` are the layout's ``(padded_total,)`` vectors sharded
    ``P(axis)`` and ``tokens`` is (M,) — one per worker, M = mesh
    ``axis`` size.

    Collective schedule per global step (DCN/ICI traffic in parens):

    1. ``all_gather`` the flat param slices for the forward (the FSDP
       gather a sharded PS must pay anyway);
    2. each worker grads its OWN batch shard with its OWN token;
    3. ``all_to_all`` routes worker ``w``'s gradient slice ``s`` to shard
       ``s`` — building the ``(M, shard_size)`` buffer in place of a
       full-gradient ``psum`` (same bytes as a reduce-scatter, none of it
       shard<->shard);
    4. ONE ``gba_apply`` launch per shard fuses decay-aggregate + Adagrad
       on the local slice — the decay weights come from the broadcast
       ``(tokens, gstep)`` scalars, identically on every shard;
    5. ``psum`` of the decayed scalar loss — the only cross-shard
       reduction left.
    """
    m = mesh.shape[axis]
    if layout.num_shards != m:
        raise ValueError(
            f"layout has {layout.num_shards} shards but mesh axis "
            f"{axis!r} has {m} devices")
    shard_n = layout.shard_size
    from repro.kernels import ops

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P()),
        out_specs=(P(axis), P(axis), P()),
        check_rep=False)
    def step(param_flat, accum_flat, batch, token, gstep):
        param_full = lax.all_gather(param_flat, axis, axis=0, tiled=True)
        params = layout.unravel(param_full)
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        # worker w's flat gradient, rows = destination shards; all_to_all
        # leaves row w of shard s holding worker w's slice s: the (M,
        # shard_size) buffer gba_apply consumes, built without any
        # shard<->shard exchange
        gm = layout.ravel(g).reshape(m, shard_n)
        buf = lax.all_to_all(gm, axis, split_axis=0, concat_axis=0,
                             tiled=True)
        tokens_all = lax.all_gather(token.reshape(-1)[:1], axis, axis=0,
                                    tiled=True)
        new_p, new_a = ops.gba_apply_flat(
            param_flat, accum_flat, buf, tokens_all, gstep, lr, iota=iota,
            eps=eps, interpret=interpret)
        w = threshold_decay(token.reshape(-1)[:1], gstep, iota)[0]
        loss = lax.psum(loss * w, axis) / m
        return new_p, new_a, loss

    return step
