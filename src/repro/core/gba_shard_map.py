"""GBA with explicit collectives via shard_map (one PS worker per device).

The pjit train step (launch.steps) treats the whole pod as ONE worker
filling the M-slot buffer over time.  This module expresses the orthogonal
mapping: every device group along the `data` axis is its own worker, each
carrying its OWN token, and one global step aggregates all M = |data|
worker gradients with the token-control decay — Algorithm 2 as a single
`lax.psum` of pre-decayed gradients:

    agg = psum_m( f(token_m, k) * grad_m / M )

which is exactly ``aggregate_dense`` (tested equivalent), but with the
collective schedule explicit — the form you deploy when worker batches
genuinely differ per device (e.g. heterogeneous data streams).

:func:`make_gba_fused_psum_step` is the fused rendering of the same
mapping: every device doubles as a PS shard owning a contiguous
tile-aligned slice of the flat parameter vector
(``core.flat_sharded.ShardedFlatLayout``).  The collective schedule is
**layer-grouped**: parameters are gathered one layer group at a time for
the forward, and each group's gradient is routed to its owning shards by
its own ``all_to_all`` — issued as soon as the backward materializes that
group's gradient, so routing overlaps the remaining backward compute
instead of serializing one monolithic exchange after it.  Peak live
gathered bytes per device is the LARGEST group
(``layout.peak_gather_bytes``), not the whole parameter vector — the
property that lets a PS shard serve models larger than one device's
gather budget.  A single-group layout (``group_by=None``) degenerates to
the PR-4 full-vector schedule, which the parity tests use as the
bit-exactness oracle.  Either way the per-shard apply stays ONE
``gba_apply`` launch (token-decay aggregation + Adagrad in one VMEM pass)
on the contiguous ``(M, shard_size)`` slice; the only ``psum`` left is
the scalar loss.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.compression import CompressionPolicy
from repro.core.flat_sharded import ShardedFlatLayout
from repro.core.staleness import threshold_decay


def make_gba_psum_step(mesh: Mesh, loss_fn: Callable, optimizer,
                       iota: int, axis: str = "data"):
    """Returns step(params, opt_state, batch, tokens, gstep) ->
    (params, opt_state, loss).

    batch: pytree with leading GLOBAL batch dim sharded over ``axis``;
    tokens: (M,) int32, one per worker (device group along ``axis``).
    """
    m = mesh.shape[axis]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P()),
        out_specs=(P(), P()),
        check_rep=False)
    def grad_agg(params, batch, token, gstep):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        w = threshold_decay(token.reshape(-1)[:1], gstep, iota)[0]
        g = jax.tree.map(lambda x: x * (w / m).astype(x.dtype), g)
        g = lax.psum(g, axis)              # decayed aggregate (Alg. 2 l.22)
        loss = lax.psum(loss * w, axis) / m
        return g, loss

    def step(params, opt_state, batch, tokens, gstep):
        agg, loss = grad_agg(params, batch, tokens, gstep)
        params, opt_state = optimizer.update(params, agg, opt_state)
        return params, opt_state, loss

    return step


def make_gba_fused_psum_step(mesh: Mesh, loss_fn: Callable,
                             layout: ShardedFlatLayout, *, iota: int,
                             lr: float, eps: float = 1e-10,
                             axis: str = "data",
                             interpret: bool | None = None,
                             compress: CompressionPolicy | None = None,
                             warm: bool = False):
    """Layer-grouped fused PS rendering of :func:`make_gba_psum_step`
    (Adagrad only), with an optional quantized wire.

    Without compression (``compress=None`` or scheme ``"none"``) returns
    ``step(param_flat, accum_flat, batch, tokens, gstep) ->
    (new_param_flat, new_accum_flat, loss)`` — the PR-5 schedule,
    bit-identical.  With a lossy ``CompressionPolicy`` the step carries
    per-worker wire state and becomes ``step(param_flat, accum_flat,
    batch, tokens, gstep, wire) -> (new_param_flat, new_accum_flat, loss,
    new_wire)`` where ``wire`` holds ``(M, padded_total)`` f32 rows
    (``residual`` always; ``momentum`` for onebit), row ``w`` = worker
    ``w``'s state, sharded ``P(axis, None)``.  ``param_flat`` /
    ``accum_flat`` are the layout's ``(padded_total,)`` vectors sharded
    ``P(axis)`` and ``tokens`` is (M,) — one per worker, M = mesh
    ``axis`` size.

    Collective schedule per global step, with G = ``layout.num_groups``
    layer groups — **gather → grad → compress → route → dequant →
    apply**:

    1. per layer group ``g``: ``all_gather`` that group's param
       sub-slices just-in-time for the forward (``group_sizes[g]`` f32
       per device per group; params always travel full precision).  The
       gathers are G independent ops, each feeding only its group's
       layers, so peak LIVE gathered bytes is
       ``layout.peak_gather_bytes`` (the largest group), not the
       ``padded_total`` a monolithic gather pins;
    2. each worker grads its OWN batch shard with its OWN token, against
       the gathered (not the sharded) params — gradients stay per-worker,
       never summed;
    3. **compress** (lossy schemes, past warmup): worker ``w`` views its
       wire-state rows as ``(num_shards, shard_size)`` — the layout is
       shard-major, so group ``g``'s residual/momentum is the SAME
       ``group_shard_bounds`` column slice as its gradient block.  The
       payload is ``grad + residual`` (int8) or ``momentum + residual``
       after the EMA update (onebit); one ``quantize`` kernel launch per
       group emits the int8 codes, the per-tile f32 sideband
       (scale/zero-point for min-max, mean-|.| norm for sign), and the
       next residual ``payload - dequantize(codes)`` in the same VMEM
       pass (error feedback costs no extra launch);
    4. **route**: per group, ``all_to_all`` sends worker ``w``'s
       sub-slice ``s`` to shard ``s`` — the PS "write", worker->shard
       only.  On the compressed wire the payload operand is int8
       (``compress.route_bytes`` per group ≈ 0.25x of f32) plus the tiny
       f32 sideband exchange; warmup and ``none`` route one f32
       ``(M, group_shard)`` operand per group, bit-identical to PR-5.
       Each exchange issues as soon as the backward materializes its
       group, overlapping the remaining backward compute;
    5. **dequant**: the receiving shard reconstructs f32 with one
       ``dequantize`` launch per group; concatenating the G per-group
       ``(M, group_shard_sizes[g])`` blocks along columns yields the
       local ``(M, shard_size)`` buffer — contiguous because the layout
       is shard-major;
    6. **apply**: ONE ``gba_apply`` launch per shard fuses
       decay-aggregate + Adagrad on the local slice — quantization never
       touches Eq. (1) token-control semantics, which act on the
       reconstructed buffer;
    7. ``psum`` of the decayed scalar loss — the only cross-shard
       reduction left.

    ``warm=True`` builds the warmup-phase step of a lossy policy: f32
    routing exactly as PR-5 (params/accum/loss bit-exact with the
    uncompressed step), residuals untouched, but the onebit momentum EMA
    already accumulating — the Bagua onebit idiom (full-precision warmup
    for ``compress.warmup_steps`` global steps, then sign-compressed
    momentum).  The warmup→compressed switch is a re-jit by the driver
    (``launch.train``), so each phase's jaxpr carries exactly one wire
    dtype — what the GBA-COLL-005 census rule checks.

    With a single-group layout the per-group collectives collapse to one
    ``all_gather`` + one routing exchange: exactly the PR-4 full-vector
    schedule.
    """
    m = mesh.shape[axis]
    if layout.num_shards != m:
        raise ValueError(
            f"layout has {layout.num_shards} shards but mesh axis "
            f"{axis!r} has {m} devices")
    from repro.kernels import ops

    def gather_params(param_flat):
        # just-in-time per-group gathers: tiled all_gather of shard
        # sub-slices reconstructs each group's contiguous flat because
        # the layout is shard-major within a group
        gathered = []
        for g in range(layout.num_groups):
            lo, hi = layout.group_shard_bounds(g)
            gathered.append(
                lax.all_gather(param_flat[lo:hi], axis, axis=0, tiled=True))
        return layout.unravel_groups(gathered)

    def route(x):
        # worker w's rows = destination shards; all_to_all leaves row w of
        # shard s holding worker w's sub-slice s of THIS group
        return lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                              tiled=True)

    def apply_and_loss(param_flat, accum_flat, bufs, token, gstep, loss):
        buf = bufs[0] if len(bufs) == 1 else jnp.concatenate(bufs, axis=1)
        tokens_all = lax.all_gather(token.reshape(-1)[:1], axis, axis=0,
                                    tiled=True)
        new_p, new_a = ops.gba_apply_flat(
            param_flat, accum_flat, buf, tokens_all, gstep, lr, iota=iota,
            eps=eps, interpret=interpret)
        w = threshold_decay(token.reshape(-1)[:1], gstep, iota)[0]
        return new_p, new_a, lax.psum(loss * w, axis) / m

    if compress is None or not compress.stateful:
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P(axis), P()),
            out_specs=(P(axis), P(axis), P()),
            check_rep=False)
        def step(param_flat, accum_flat, batch, token, gstep):
            params = gather_params(param_flat)
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            bufs = [route(layout.ravel_group(g, grads).reshape(m, -1))
                    for g in range(layout.num_groups)]
            return apply_and_loss(param_flat, accum_flat, bufs, token,
                                  gstep, loss)

        return step

    scheme = compress.scheme
    mode = "minmax" if scheme == "int8" else "sign"
    beta = compress.momentum
    wire_spec = {name: P(axis, None) for name in compress.state_names()}

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(), wire_spec),
        out_specs=(P(axis), P(axis), P(), wire_spec),
        check_rep=False)
    def step(param_flat, accum_flat, batch, token, gstep, wire):
        params = gather_params(param_flat)
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        # this worker's wire-state rows, viewed shard-major so group g is
        # the same column slice as its gradient block
        res = wire["residual"].reshape(m, layout.shard_size)
        mom = (wire["momentum"].reshape(m, layout.shard_size)
               if scheme == "onebit" else None)
        bufs, new_res, new_mom = [], [], []
        for g in range(layout.num_groups):
            lo, hi = layout.group_shard_bounds(g)
            gm = layout.ravel_group(g, grads).reshape(m, -1)
            if scheme == "onebit":
                mom_g = beta * mom[:, lo:hi] + (1.0 - beta) * gm
                new_mom.append(mom_g)
                src = mom_g
            else:
                src = gm
            if warm:
                # full-precision warmup: route the raw gradient (PR-5
                # bit-exact); residual stays zero, momentum accumulates
                bufs.append(route(gm))
                new_res.append(res[:, lo:hi])
                continue
            payload = src + res[:, lo:hi]
            if mode == "minmax":
                q, sc, zp, r_g = ops.quantize_wire(
                    payload, tile=layout.tile, mode=mode,
                    interpret=interpret)
                deq = ops.dequantize_wire(
                    route(q), route(sc), route(zp), tile=layout.tile,
                    mode=mode, interpret=interpret)
            else:
                q, sc, r_g = ops.quantize_wire(
                    payload, tile=layout.tile, mode=mode,
                    interpret=interpret)
                deq = ops.dequantize_wire(
                    route(q), route(sc), tile=layout.tile, mode=mode,
                    interpret=interpret)
            bufs.append(deq)
            new_res.append(r_g)
        new_wire = {"residual": _recols(new_res, wire["residual"].shape)}
        if scheme == "onebit":
            new_wire["momentum"] = _recols(new_mom,
                                           wire["momentum"].shape)
        new_p, new_a, loss = apply_and_loss(param_flat, accum_flat, bufs,
                                            token, gstep, loss)
        return new_p, new_a, loss, new_wire

    return step


def _recols(cols: list, local_shape) -> jnp.ndarray:
    """Per-group column blocks -> the worker's local wire-state row(s)."""
    out = cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)
    return out.reshape(local_shape)
