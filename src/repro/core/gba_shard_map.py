"""GBA with explicit collectives via shard_map (one PS worker per device).

The pjit train step (launch.steps) treats the whole pod as ONE worker
filling the M-slot buffer over time.  This module expresses the orthogonal
mapping: every device group along the `data` axis is its own worker, each
carrying its OWN token, and one global step aggregates all M = |data|
worker gradients with the token-control decay — Algorithm 2 as a single
`lax.psum` of pre-decayed gradients:

    agg = psum_m( f(token_m, k) * grad_m / M )

which is exactly ``aggregate_dense`` (tested equivalent), but with the
collective schedule explicit — the form you deploy when worker batches
genuinely differ per device (e.g. heterogeneous data streams).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.staleness import threshold_decay


def make_gba_psum_step(mesh: Mesh, loss_fn: Callable, optimizer,
                       iota: int, axis: str = "data"):
    """Returns step(params, opt_state, batch, tokens, gstep) ->
    (params, opt_state, loss).

    batch: pytree with leading GLOBAL batch dim sharded over ``axis``;
    tokens: (M,) int32, one per worker (device group along ``axis``).
    """
    m = mesh.shape[axis]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P()),
        out_specs=(P(), P()),
        check_rep=False)
    def grad_agg(params, batch, token, gstep):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        w = threshold_decay(token.reshape(-1)[:1], gstep, iota)[0]
        g = jax.tree.map(lambda x: x * (w / m).astype(x.dtype), g)
        g = lax.psum(g, axis)              # decayed aggregate (Alg. 2 l.22)
        loss = lax.psum(loss * w, axis) / m
        return g, loss

    def step(params, opt_state, batch, tokens, gstep):
        agg, loss = grad_agg(params, batch, tokens, gstep)
        params, opt_state = optimizer.update(params, agg, opt_state)
        return params, opt_state, loss

    return step
