"""GBA with explicit collectives via shard_map (one PS worker per device).

The pjit train step (launch.steps) treats the whole pod as ONE worker
filling the M-slot buffer over time.  This module expresses the orthogonal
mapping: every device group along the `data` axis is its own worker, each
carrying its OWN token, and one global step aggregates all M = |data|
worker gradients with the token-control decay — Algorithm 2 as a single
`lax.psum` of pre-decayed gradients:

    agg = psum_m( f(token_m, k) * grad_m / M )

which is exactly ``aggregate_dense`` (tested equivalent), but with the
collective schedule explicit — the form you deploy when worker batches
genuinely differ per device (e.g. heterogeneous data streams).

:func:`make_gba_fused_psum_step` is the fused rendering of the same
mapping: every device doubles as a PS shard owning a contiguous
tile-aligned slice of the flat parameter vector
(``core.flat_sharded.ShardedFlatLayout``).  The collective schedule is
**layer-grouped**: parameters are gathered one layer group at a time for
the forward, and each group's gradient is routed to its owning shards by
its own ``all_to_all`` — issued as soon as the backward materializes that
group's gradient, so routing overlaps the remaining backward compute
instead of serializing one monolithic exchange after it.  Peak live
gathered bytes per device is the LARGEST group
(``layout.peak_gather_bytes``), not the whole parameter vector — the
property that lets a PS shard serve models larger than one device's
gather budget.  A single-group layout (``group_by=None``) degenerates to
the PR-4 full-vector schedule, which the parity tests use as the
bit-exactness oracle.  Either way the per-shard apply stays ONE
``gba_apply`` launch (token-decay aggregation + Adagrad in one VMEM pass)
on the contiguous ``(M, shard_size)`` slice; the only ``psum`` left is
the scalar loss.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.flat_sharded import ShardedFlatLayout
from repro.core.staleness import threshold_decay


def make_gba_psum_step(mesh: Mesh, loss_fn: Callable, optimizer,
                       iota: int, axis: str = "data"):
    """Returns step(params, opt_state, batch, tokens, gstep) ->
    (params, opt_state, loss).

    batch: pytree with leading GLOBAL batch dim sharded over ``axis``;
    tokens: (M,) int32, one per worker (device group along ``axis``).
    """
    m = mesh.shape[axis]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P()),
        out_specs=(P(), P()),
        check_rep=False)
    def grad_agg(params, batch, token, gstep):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        w = threshold_decay(token.reshape(-1)[:1], gstep, iota)[0]
        g = jax.tree.map(lambda x: x * (w / m).astype(x.dtype), g)
        g = lax.psum(g, axis)              # decayed aggregate (Alg. 2 l.22)
        loss = lax.psum(loss * w, axis) / m
        return g, loss

    def step(params, opt_state, batch, tokens, gstep):
        agg, loss = grad_agg(params, batch, tokens, gstep)
        params, opt_state = optimizer.update(params, agg, opt_state)
        return params, opt_state, loss

    return step


def make_gba_fused_psum_step(mesh: Mesh, loss_fn: Callable,
                             layout: ShardedFlatLayout, *, iota: int,
                             lr: float, eps: float = 1e-10,
                             axis: str = "data",
                             interpret: bool | None = None):
    """Layer-grouped fused PS rendering of :func:`make_gba_psum_step`
    (Adagrad only).

    Returns ``step(param_flat, accum_flat, batch, tokens, gstep) ->
    (new_param_flat, new_accum_flat, loss)`` where ``param_flat`` /
    ``accum_flat`` are the layout's ``(padded_total,)`` vectors sharded
    ``P(axis)`` and ``tokens`` is (M,) — one per worker, M = mesh
    ``axis`` size.

    Collective schedule per global step (DCN/ICI traffic in parens), with
    G = ``layout.num_groups`` layer groups:

    1. per layer group ``g``: ``all_gather`` that group's param
       sub-slices just-in-time for the forward (``group_sizes[g]`` f32
       per device per group).  The gathers are G independent ops, each
       feeding only its group's layers, so the scheduler can free a
       group's gathered copy once its last consumer runs — peak LIVE
       gathered bytes is ``layout.peak_gather_bytes`` (the largest
       group), not the ``padded_total`` a monolithic gather pins;
    2. each worker grads its OWN batch shard with its OWN token, against
       the gathered (not the sharded) params — gradients stay per-worker,
       never summed;
    3. per layer group ``g``: ``all_to_all`` routes worker ``w``'s
       sub-slice ``s`` of that group's gradient to shard ``s`` — the PS
       "write", worker->shard only, never shard<->shard.  Each exchange
       depends only on ITS group's gradient, so it issues as soon as the
       backward materializes that group and overlaps the backward compute
       of the groups still in flight (same total bytes as one
       reduce-scatter, pipelined instead of serialized after the
       backward).  Concatenating the G per-group ``(M,
       group_shard_sizes[g])`` blocks along columns yields the local
       ``(M, shard_size)`` buffer — contiguous because the layout is
       shard-major (see ``ShardedFlatLayout``);
    4. ONE ``gba_apply`` launch per shard fuses decay-aggregate + Adagrad
       on the local slice — the decay weights come from the broadcast
       ``(tokens, gstep)`` scalars, identically on every shard;
    5. ``psum`` of the decayed scalar loss — the only cross-shard
       reduction left.

    With a single-group layout steps 1 and 3 collapse to one
    ``all_gather`` + one ``all_to_all``: exactly the PR-4 full-vector
    schedule, bit-exact with this one (the kernel arithmetic is
    per-element and column order within a shard is irrelevant to it).
    """
    m = mesh.shape[axis]
    if layout.num_shards != m:
        raise ValueError(
            f"layout has {layout.num_shards} shards but mesh axis "
            f"{axis!r} has {m} devices")
    from repro.kernels import ops

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P()),
        out_specs=(P(axis), P(axis), P()),
        check_rep=False)
    def step(param_flat, accum_flat, batch, token, gstep):
        # 1. just-in-time per-group gathers: tiled all_gather of shard
        # sub-slices reconstructs each group's contiguous flat because
        # the layout is shard-major within a group
        gathered = []
        for g in range(layout.num_groups):
            lo, hi = layout.group_shard_bounds(g)
            gathered.append(
                lax.all_gather(param_flat[lo:hi], axis, axis=0, tiled=True))
        params = layout.unravel_groups(gathered)
        # 2. per-worker gradient against the gathered params
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        # 3. per-group routing: worker w's rows = destination shards;
        # all_to_all leaves row w of shard s holding worker w's sub-slice
        # s of THIS group — issued per group as the backward yields it
        bufs = []
        for g in range(layout.num_groups):
            gm = layout.ravel_group(g, grads).reshape(m, -1)
            bufs.append(lax.all_to_all(gm, axis, split_axis=0,
                                       concat_axis=0, tiled=True))
        buf = bufs[0] if len(bufs) == 1 else jnp.concatenate(bufs, axis=1)
        # 4. one fused apply launch on the contiguous local slice
        tokens_all = lax.all_gather(token.reshape(-1)[:1], axis, axis=0,
                                    tiled=True)
        new_p, new_a = ops.gba_apply_flat(
            param_flat, accum_flat, buf, tokens_all, gstep, lr, iota=iota,
            eps=eps, interpret=interpret)
        # 5. scalar-loss psum — the only cross-shard reduction
        w = threshold_decay(token.reshape(-1)[:1], gstep, iota)[0]
        loss = lax.psum(loss * w, axis) / m
        return new_p, new_a, loss

    return step
