"""The paper's token list (Sec. 4.1).

Given a dataset of Q batches and buffer size M, the token list holds Q
tokens in ascending order with each value repeated M times, so the i-th
dispatched batch carries ``t_i = floor(i / M)`` — the global step it is
*scheduled* to be aggregated at, and the reference point for data-staleness.

Note: the paper's text writes ``t_i = floor(i / K)`` with ``K = ceil(Q/M)``;
that formula contradicts its own constraints ("each token value repeats M
times", "yields in ascending order", values in 0..K-1) — ``floor(i / M)`` is
the unique assignment satisfying them, so we implement that and record the
discrepancy here.
"""
from __future__ import annotations

import math

import jax.numpy as jnp


def num_global_steps(num_batches: int, buffer_size: int) -> int:
    """K = ceil(Q / M)."""
    return math.ceil(num_batches / buffer_size)


def token_for_batch(batch_index, buffer_size: int):
    """t_i = floor(i / M); works on ints and arrays."""
    return batch_index // buffer_size


def token_list(num_batches: int, buffer_size: int) -> jnp.ndarray:
    return jnp.arange(num_batches, dtype=jnp.int32) // buffer_size


class TokenListExhausted(IndexError):
    """Raised by :meth:`TokenList.fetch` past the last token.

    Deliberately NOT ``StopIteration``: PEP 479 makes a ``StopIteration``
    escaping a generator frame mutate into ``RuntimeError``, so a
    generator-based dispatch loop draining a TokenList could never catch
    the exhaustion signal under its real name.  Subclasses ``IndexError``
    (fetch-past-the-end is an out-of-range access), so ``except
    IndexError`` works too."""


class TokenList:
    """Stateful FIFO view used by the PS-side of the simulator/trainer.

    Mirrors Algorithm 2's token-generation thread: tokens are yielded in
    ascending order, one per (pull) request."""

    def __init__(self, num_batches: int, buffer_size: int):
        self._next = 0
        self._num_batches = num_batches
        self._m = buffer_size

    def fetch(self) -> int:
        if self._next >= self._num_batches:
            raise TokenListExhausted(
                f"token list exhausted after {self._num_batches} fetches")
        tok = self._next // self._m
        self._next += 1
        return tok

    @property
    def remaining(self) -> int:
        return self._num_batches - self._next
