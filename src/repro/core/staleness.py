"""Staleness decay strategies over the token index (paper Eq. (1)).

The paper's strategy is the hard threshold; it notes "GBA could employ
different staleness decay strategies", so we also provide smooth variants
(exponential / linear) as beyond-paper extension hooks — all jittable and
usable inside the sharded train step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def threshold_decay(tokens: jax.Array, global_step: jax.Array,
                    iota: int) -> jax.Array:
    """Eq. (1): weight 0 if k - token > iota else 1.  tokens: (M,) int32."""
    stale = global_step - tokens
    return (stale <= iota).astype(jnp.float32)


def exponential_decay(tokens: jax.Array, global_step: jax.Array,
                      iota: int, alpha: float = 0.5) -> jax.Array:
    """Beyond-paper: alpha^max(stale,0), hard zero past iota."""
    stale = jnp.maximum(global_step - tokens, 0).astype(jnp.float32)
    w = jnp.power(alpha, stale)
    return jnp.where(global_step - tokens > iota, 0.0, w)


def linear_decay(tokens: jax.Array, global_step: jax.Array,
                 iota: int) -> jax.Array:
    """Beyond-paper: 1 - stale/(iota+1), clipped at 0."""
    stale = jnp.maximum(global_step - tokens, 0).astype(jnp.float32)
    return jnp.clip(1.0 - stale / (iota + 1.0), 0.0, 1.0)


DECAY_FNS = {
    "threshold": threshold_decay,
    "exponential": exponential_decay,
    "linear": linear_decay,
}
