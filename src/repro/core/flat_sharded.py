"""Sharding-aware flat-buffer GBA: the fused one-launch apply per PS shard.

``core.gba.FlatLayout`` ravels the dense module into one ``(M, N_total)``
buffer so a full-buffer apply is ONE ``repro.kernels.gba_apply`` launch —
but only on a single host: the flat axis carries no sharding, so the
sharded production path kept the per-leaf ``buffer_push_and_maybe_apply``
chain (one aggregate + one optimizer launch per leaf, dozens per global
step).  This module closes that gap:

:class:`ShardedFlatLayout`
    Lays leaves back-to-back like ``FlatLayout`` but pads every leaf to a
    ``tile`` multiple (leaf boundaries coincide with tile boundaries) and
    pads the total so it splits into ``num_shards`` equal, tile-aligned,
    contiguous slices.  Shard ``s`` owns ``flat[s*shard_size :
    (s+1)*shard_size]`` — whole kernel blocks when ``tile`` is the
    ``gba_apply`` block size (the default), so a PS shard's apply never
    straddles a partial tile.

    With ``group_by`` the layout is additionally **layer-grouped**: every
    leaf is assigned to a layer group derived from its pytree path, each
    group's flat extent is contiguous and splits into ``num_shards`` equal
    tile-aligned sub-slices, and the GLOBAL flat ordering is shard-major —
    shard ``s``'s contiguous slice is the concatenation of every group's
    ``s``-th sub-slice.  A layer-grouped collective schedule
    (``core.gba_shard_map.make_gba_fused_psum_step``) can then
    ``all_gather`` one group at a time (peak live gathered bytes =
    :attr:`peak_gather_bytes` = the largest group, not ``N_total``) and
    route each group's gradient with its own ``all_to_all`` while the
    backward still computes the remaining groups — yet the per-shard slice
    stays ONE contiguous run, so the fused apply is still a single
    ``gba_apply`` launch.  ``group_by=None`` (the default) is exactly the
    ungrouped PR-4 layout: one group covering everything, shard-major
    ordering degenerating to plain concatenation.

:func:`make_sharded_apply`
    ``shard_map`` wrapper that runs the single-launch ``gba_apply``
    (token-decay aggregate + Adagrad, one VMEM pass) on each shard's
    slice.  Tokens / global step are replicated, so every shard derives
    the same (M,) decay weights from the broadcast scalars on its scalar
    core; the gradient columns never cross shards — no collective touches
    the buffer at apply time.  Grouping-agnostic: the kernel only sees the
    contiguous local slice.

:func:`sharded_flat_push_and_maybe_apply`
    Drop-in sharded counterpart of
    ``core.gba.flat_buffer_push_and_maybe_apply``: the push is
    elementwise along the flat axis (XLA keeps it local under a
    ``P(None, axis)`` buffer sharding); the apply branch launches the
    shard-mapped kernel.  Bit-exact with the single-host flat path and
    with a per-leaf ``gba_apply`` launch chain (same kernel arithmetic
    per element; see :func:`per_leaf_kernel_apply`).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.gba import flat_buffer_push
from repro.kernels.gba_apply import BLOCK_N

Params = Any
GroupBy = Callable[[tuple[str, ...]], str]


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def path_names(path) -> tuple[str, ...]:
    """Pytree key path -> name tuple (dict keys, ``#i`` sequence indices,
    attribute names) — the canonical helper behind both the layer
    grouping here and the sharding rules in ``distributed.sharding``."""
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "idx"):
            names.append(f"#{e.idx}")
        elif hasattr(e, "name"):
            names.append(str(e.name))
    return tuple(names)


@dataclass(frozen=True)
class ShardedFlatLayout:
    """Leaf-aligned, tile-aligned flat layout split into PS shard slices.

    ``offsets[j]`` (a ``tile`` multiple) is where leaf ``j``'s data starts
    *within its layer group's contiguous flat*; ``padded_sizes[j]`` is its
    tile-rounded extent, zero-filled past ``sizes[j]``.  Group ``g``
    occupies ``group_sizes[g]`` flat elements (a ``num_shards * tile``
    multiple), of which shard ``s`` owns the ``s``-th
    ``group_shard_sizes[g]``-wide sub-slice at local column
    ``group_local_offsets[g]`` of its slice.  ``padded_total ==
    num_shards * shard_size`` and ``shard_size % tile == 0``, so every
    shard's slice starts and ends on a tile boundary regardless of leaf
    shapes.  For the default single-group layout (``group_by=None``) the
    group-local offsets ARE global flat offsets — the PR-4 layout,
    bit-identical.  Host-side object (hashable tuples only) — closable
    over by jitted train steps.
    """

    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    sizes: tuple[int, ...]
    padded_sizes: tuple[int, ...]
    offsets: tuple[int, ...]
    total: int            # sum of true leaf sizes (FlatLayout's N_total)
    padded_total: int     # num_shards * shard_size
    num_shards: int
    shard_size: int
    tile: int
    group_keys: tuple[str, ...]         # group names, in layout order
    leaf_group: tuple[int, ...]         # group index per leaf
    group_sizes: tuple[int, ...]        # padded flat extent per group
    group_shard_sizes: tuple[int, ...]  # = group_sizes[g] // num_shards
    group_local_offsets: tuple[int, ...]  # column of group g in a shard

    @classmethod
    def from_params(cls, params: Params, num_shards: int,
                    tile: int = BLOCK_N,
                    group_by: GroupBy | None = None) -> "ShardedFlatLayout":
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        if tile < 1:
            raise ValueError(f"tile must be >= 1, got {tile}")
        path_leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
        paths = tuple(path_names(p) for p, _ in path_leaves)
        leaves = [l for _, l in path_leaves]
        shapes = tuple(tuple(l.shape) for l in leaves)
        dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
        sizes = tuple(math.prod(s) for s in shapes)
        padded_sizes = tuple(_round_up(s, tile) for s in sizes)
        keys = (["all"] * len(leaves) if group_by is None
                else [str(group_by(p)) for p in paths])
        group_keys: list[str] = []
        leaf_group: list[int] = []
        for k in keys:                       # group order = first appearance
            if k not in group_keys:
                group_keys.append(k)
            leaf_group.append(group_keys.index(k))
        if not group_keys:
            group_keys = ["all"]             # empty-params edge case
        # group-local leaf offsets (treedef order within each group)
        offsets, cursor = [], [0] * len(group_keys)
        for j, g in enumerate(leaf_group):
            offsets.append(cursor[g])
            cursor[g] += padded_sizes[j]
        chunk = num_shards * tile
        group_sizes = tuple(_round_up(max(c, tile), chunk) for c in cursor)
        group_shard_sizes = tuple(gs // num_shards for gs in group_sizes)
        group_local_offsets, col = [], 0
        for gsn in group_shard_sizes:
            group_local_offsets.append(col)
            col += gsn
        shard_size = col
        return cls(treedef, shapes, dtypes, sizes, padded_sizes,
                   tuple(offsets), sum(sizes), num_shards * shard_size,
                   num_shards, shard_size, tile, tuple(group_keys),
                   tuple(leaf_group), group_sizes, group_shard_sizes,
                   tuple(group_local_offsets))

    # -- group geometry -----------------------------------------------------
    @property
    def num_groups(self) -> int:
        return len(self.group_keys)

    @property
    def peak_gather_bytes(self) -> int:
        """Per-device peak live gathered bytes of the layer-grouped
        schedule: the largest single group's f32 extent (vs
        :attr:`full_gather_bytes` for the ungrouped full-vector gather)."""
        return max(self.group_sizes) * 4

    @property
    def full_gather_bytes(self) -> int:
        """Per-device gathered bytes of the full-vector (PR-4) schedule."""
        return self.padded_total * 4

    def group_shard_bounds(self, g: int) -> tuple[int, int]:
        """[start, stop) columns of group ``g`` within one shard's local
        ``(shard_size,)`` slice (host ints)."""
        if not 0 <= g < self.num_groups:
            raise IndexError(g)
        lo = self.group_local_offsets[g]
        return lo, lo + self.group_shard_sizes[g]

    def group_leaves(self, g: int) -> tuple[int, ...]:
        """Leaf indices belonging to group ``g``, in treedef order."""
        return tuple(j for j, lg in enumerate(self.leaf_group) if lg == g)

    def group_table(self, compress=None) -> list[dict]:
        """Host-side summary, one entry per group (for logs / benches).

        With a ``CompressionPolicy`` (``core.compression``), each entry
        additionally reports the group's routed ``wire_bytes`` (payload +
        per-tile sideband) and ``wire_dtype`` under that policy; without
        one the wire is the full-precision f32 routing (``wire_bytes ==
        bytes``)."""
        rows = []
        for g, k in enumerate(self.group_keys):
            row = {"key": k,
                   "elements": self.group_sizes[g],
                   "bytes": self.group_sizes[g] * 4,
                   "leaves": len(self.group_leaves(g))}
            if compress is None:
                row["wire_bytes"] = row["bytes"]
                row["wire_dtype"] = "float32"
            else:
                row["wire_bytes"] = compress.route_bytes(
                    self.group_sizes[g], self.tile)
                row["wire_dtype"] = compress.wire_dtype()
            rows.append(row)
        return rows

    def wire_state_shapes(self, m: int, scheme: str) -> dict:
        """Shapes of the per-worker wire-compression state (error-feedback
        residual, onebit momentum): one ``(m, padded_total)`` f32 row per
        worker, columns in this layout's shard-major order so per-group
        views are the :meth:`group_shard_bounds` column slices the routing
        stage already uses."""
        names = {"none": (), "int8": ("residual",),
                 "onebit": ("residual", "momentum")}
        if scheme not in names:
            raise ValueError(f"unknown compression scheme {scheme!r}")
        return {name: (m, self.padded_total) for name in names[scheme]}

    # -- ravel / unravel ----------------------------------------------------
    def ravel_group(self, g: int, tree: Params) -> jax.Array:
        """Group ``g``'s leaves of ``tree`` -> contiguous
        ``(group_sizes[g],)`` f32; per-leaf tail padding is zero so padding
        columns never contribute gradient (Adagrad on a zero grad is the
        identity)."""
        leaves = jax.tree.leaves(tree)
        parts, used = [], 0
        for j in self.group_leaves(g):
            flat = leaves[j].reshape(-1).astype(jnp.float32)
            if self.padded_sizes[j] > self.sizes[j]:
                flat = jnp.pad(flat, (0, self.padded_sizes[j]
                                      - self.sizes[j]))
            parts.append(flat)
            used += self.padded_sizes[j]
        tail = self.group_sizes[g] - used
        if tail:
            parts.append(jnp.zeros((tail,), jnp.float32))
        return jnp.concatenate(parts) if len(parts) > 1 else parts[0]

    def unravel_group(self, g: int, group_flat: jax.Array,
                      dtype=None) -> list:
        """Contiguous group flat -> that group's leaves (treedef order).
        ``dtype`` overrides the per-leaf cast — e.g. ``jnp.float32`` when
        unraveling an OPTIMIZER vector (Adagrad accum) whose leaves must
        stay f32 even for a bf16-param model."""
        return [
            group_flat[self.offsets[j]:self.offsets[j] + self.sizes[j]]
            .reshape(self.shapes[j])
            .astype(self.dtypes[j] if dtype is None else dtype)
            for j in self.group_leaves(g)]

    def unravel_groups(self, group_flats: list[jax.Array],
                       dtype=None) -> Params:
        """Per-group contiguous flats -> the full pytree."""
        leaves: list = [None] * len(self.sizes)
        for g, gflat in enumerate(group_flats):
            for j, leaf in zip(self.group_leaves(g),
                               self.unravel_group(g, gflat, dtype)):
                leaves[j] = leaf
        return jax.tree.unflatten(self.treedef, leaves)

    def ravel(self, tree: Params) -> jax.Array:
        """Pytree -> (padded_total,) f32 in shard-major group order: shard
        ``s``'s slice is the concatenation of every group's ``s``-th
        sub-slice.  Single-group layouts reduce to plain concatenation
        (the PR-4 ordering, bit-identical)."""
        gfs = [self.ravel_group(g, tree).reshape(self.num_shards, -1)
               for g in range(self.num_groups)]
        if len(gfs) == 1:
            return gfs[0].reshape(-1)
        return jnp.concatenate(gfs, axis=1).reshape(-1)

    def unravel(self, flat: jax.Array, dtype=None) -> Params:
        rows = flat.reshape(self.num_shards, self.shard_size)
        gfs = [rows[:, lo:lo + gsn].reshape(-1)
               for lo, gsn in zip(self.group_local_offsets,
                                  self.group_shard_sizes)]
        return self.unravel_groups(gfs, dtype)

    # -- shard geometry -----------------------------------------------------
    def shard_bounds(self, s: int) -> tuple[int, int]:
        """[start, stop) of shard ``s``'s flat slice (host ints)."""
        if not 0 <= s < self.num_shards:
            raise IndexError(s)
        return s * self.shard_size, (s + 1) * self.shard_size

    def leaves_in_shard(self, s: int) -> tuple[int, ...]:
        """Leaf indices whose (padded) extent overlaps shard ``s`` — what
        a per-leaf chain would have to launch on this shard."""
        lo, hi = self.shard_bounds(s)
        out = []
        for j, (off, n) in enumerate(zip(self.offsets, self.padded_sizes)):
            gsn = self.group_shard_sizes[self.leaf_group[j]]
            # leaf j spans [off, off+n) of its group flat; shard s owns
            # [s*gsn, (s+1)*gsn) of that group
            if off < (s + 1) * gsn and off + n > s * gsn:
                out.append(j)
        return tuple(out)


def init_sharded_flat_buffer(params: Params, buffer_size: int,
                             num_shards: int, tile: int = BLOCK_N,
                             group_by: GroupBy | None = None
                             ) -> tuple[ShardedFlatLayout, dict]:
    """Sharded flat M-slot buffer: ``grads`` is ``(M, padded_total)`` and
    meant to live under a ``P(None, axis)`` sharding (columns split across
    PS shards, slots replicated).  ``group_by`` opts into the layer-grouped
    layout (see :class:`ShardedFlatLayout`)."""
    layout = ShardedFlatLayout.from_params(params, num_shards, tile,
                                           group_by=group_by)
    return layout, {
        "grads": jnp.zeros((buffer_size, layout.padded_total), jnp.float32),
        "tokens": jnp.zeros((buffer_size,), jnp.int32),
        "fill": jnp.zeros((), jnp.int32),
        "step": jnp.zeros((), jnp.int32),
    }


def make_sharded_apply(mesh: Mesh, layout: ShardedFlatLayout, *,
                       axis: str = "data", iota: int, eps: float = 1e-10,
                       interpret: bool | None = None):
    """shard_map'd single-launch apply: each PS shard runs ``gba_apply``
    on its contiguous ``(M, shard_size)`` buffer slice.

    Returns ``apply(param_flat, accum_flat, grads, tokens, step, lr) ->
    (new_param_flat, new_accum_flat)`` over GLOBAL ``(padded_total,)`` /
    ``(M, padded_total)`` arrays.  Tokens/step/lr are broadcast (``P()``)
    — the decay weights are computed once from them on every shard's
    scalar core; no collective touches the gradient columns.
    """
    if layout.num_shards != mesh.shape[axis]:
        raise ValueError(
            f"layout has {layout.num_shards} shards but mesh axis "
            f"{axis!r} has {mesh.shape[axis]} devices")
    from repro.kernels import ops

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axis), P(axis), P(None, axis), P(), P(), P()),
        out_specs=(P(axis), P(axis)),
        check_rep=False)
    def apply_shards(param_flat, accum_flat, grads, tokens, step, lr):
        return ops.gba_apply_flat(param_flat, accum_flat, grads, tokens,
                                  step, lr, iota=iota, eps=eps,
                                  interpret=interpret)

    return apply_shards


def sharded_flat_push_and_maybe_apply(
        buffer: dict, flat_grad: jax.Array, token: jax.Array,
        param_flat: jax.Array, accum_flat: jax.Array, lr, *, mesh: Mesh,
        layout: ShardedFlatLayout, axis: str = "data", iota: int,
        eps: float = 1e-10, interpret: bool | None = None):
    """Sharded counterpart of ``core.gba.flat_buffer_push_and_maybe_apply``.

    The push is elementwise along the flat axis, so under a
    ``P(None, axis)`` buffer sharding XLA keeps it communication-free; the
    apply branch is one shard-mapped ``gba_apply`` launch per PS shard.
    Returns ``(new_param_flat, new_accum_flat, applied, new_buffer)`` —
    the partial-buffer branch passes params/accum through untouched.
    """
    new_buffer, is_full = flat_buffer_push(buffer, flat_grad, token)
    apply_shards = make_sharded_apply(mesh, layout, axis=axis, iota=iota,
                                      eps=eps, interpret=interpret)

    def do_apply(operands):
        p, a, grads, tokens, step, lr_ = operands
        return apply_shards(p, a, grads, tokens, step, lr_)

    def do_noop(operands):
        p, a, *_ = operands
        return p, a

    new_param, new_accum = jax.lax.cond(
        is_full, do_apply, do_noop,
        (param_flat, accum_flat, new_buffer["grads"], new_buffer["tokens"],
         buffer["step"], jnp.asarray(lr, jnp.float32)))
    return new_param, new_accum, is_full, new_buffer


def per_leaf_kernel_apply(layout: ShardedFlatLayout, param_flat: jax.Array,
                          accum_flat: jax.Array, grads: jax.Array,
                          tokens: jax.Array, step: jax.Array, lr, *,
                          iota: int, eps: float = 1e-10,
                          interpret: bool | None = None
                          ) -> tuple[jax.Array, jax.Array]:
    """The per-leaf launch chain the sharded apply replaces: one
    ``gba_apply`` call per leaf slice (``len(layout.sizes)`` launches vs
    one per shard).  Kernel arithmetic is identical per element, so this
    is the bit-exactness oracle for the fused sharded path — and the
    launch-count baseline for ``benchmarks.bench_kernels``.  Single-group
    layouts only: a layer-grouped layout interleaves leaves shard-major,
    so no leaf is one contiguous global run."""
    if layout.num_groups > 1:
        raise ValueError(
            "per_leaf_kernel_apply requires a single-group layout; "
            f"got {layout.num_groups} groups {layout.group_keys}")
    from repro.kernels import ops
    new_p, new_a = param_flat, accum_flat
    for off, size in zip(layout.offsets, layout.sizes):
        lp, la = ops.gba_apply_flat(
            param_flat[off:off + size], accum_flat[off:off + size],
            grads[:, off:off + size], tokens, step, lr, iota=iota, eps=eps,
            interpret=interpret)
        new_p = jax.lax.dynamic_update_slice(new_p, lp, (off,))
        new_a = jax.lax.dynamic_update_slice(new_a, la, (off,))
    return new_p, new_a
