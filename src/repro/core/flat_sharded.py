"""Sharding-aware flat-buffer GBA: the fused one-launch apply per PS shard.

``core.gba.FlatLayout`` ravels the dense module into one ``(M, N_total)``
buffer so a full-buffer apply is ONE ``repro.kernels.gba_apply`` launch —
but only on a single host: the flat axis carries no sharding, so the
sharded production path kept the per-leaf ``buffer_push_and_maybe_apply``
chain (one aggregate + one optimizer launch per leaf, dozens per global
step).  This module closes that gap:

:class:`ShardedFlatLayout`
    Lays leaves back-to-back like ``FlatLayout`` but pads every leaf to a
    ``tile`` multiple (leaf boundaries coincide with tile boundaries) and
    pads the total so it splits into ``num_shards`` equal, tile-aligned,
    contiguous slices.  Shard ``s`` owns ``flat[s*shard_size :
    (s+1)*shard_size]`` — whole kernel blocks when ``tile`` is the
    ``gba_apply`` block size (the default), so a PS shard's apply never
    straddles a partial tile.

:func:`make_sharded_apply`
    ``shard_map`` wrapper that runs the single-launch ``gba_apply``
    (token-decay aggregate + Adagrad, one VMEM pass) on each shard's
    slice.  Tokens / global step are replicated, so every shard derives
    the same (M,) decay weights from the broadcast scalars on its scalar
    core; the gradient columns never cross shards — no collective touches
    the buffer at apply time.

:func:`sharded_flat_push_and_maybe_apply`
    Drop-in sharded counterpart of
    ``core.gba.flat_buffer_push_and_maybe_apply``: the push is
    elementwise along the flat axis (XLA keeps it local under a
    ``P(None, axis)`` buffer sharding); the apply branch launches the
    shard-mapped kernel.  Bit-exact with the single-host flat path and
    with a per-leaf ``gba_apply`` launch chain (same kernel arithmetic
    per element; see :func:`per_leaf_kernel_apply`).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.gba import flat_buffer_push
from repro.kernels.gba_apply import BLOCK_N

Params = Any


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclass(frozen=True)
class ShardedFlatLayout:
    """Leaf-aligned, tile-aligned flat layout split into PS shard slices.

    ``offsets[j]`` (a ``tile`` multiple) is where leaf ``j``'s data starts;
    ``padded_sizes[j]`` is its tile-rounded extent, zero-filled past
    ``sizes[j]``.  ``padded_total == num_shards * shard_size`` and
    ``shard_size % tile == 0``, so every shard's slice starts and ends on
    a tile boundary regardless of leaf shapes.  Host-side object
    (hashable tuples only) — closable over by jitted train steps.
    """

    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    sizes: tuple[int, ...]
    padded_sizes: tuple[int, ...]
    offsets: tuple[int, ...]
    total: int            # sum of true leaf sizes (FlatLayout's N_total)
    padded_total: int     # num_shards * shard_size
    num_shards: int
    shard_size: int
    tile: int

    @classmethod
    def from_params(cls, params: Params, num_shards: int,
                    tile: int = BLOCK_N) -> "ShardedFlatLayout":
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        if tile < 1:
            raise ValueError(f"tile must be >= 1, got {tile}")
        leaves, treedef = jax.tree.flatten(params)
        shapes = tuple(tuple(l.shape) for l in leaves)
        dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
        sizes = tuple(math.prod(s) for s in shapes)
        padded_sizes = tuple(_round_up(s, tile) for s in sizes)
        offsets, off = [], 0
        for ps in padded_sizes:
            offsets.append(off)
            off += ps
        padded_total = _round_up(max(off, tile), num_shards * tile)
        return cls(treedef, shapes, dtypes, sizes, padded_sizes,
                   tuple(offsets), sum(sizes), padded_total, num_shards,
                   padded_total // num_shards, tile)

    # -- ravel / unravel ----------------------------------------------------
    def ravel(self, tree: Params) -> jax.Array:
        """Pytree -> (padded_total,) f32; per-leaf tail padding is zero so
        padding columns never contribute gradient (Adagrad on a zero grad
        is the identity)."""
        leaves = jax.tree.leaves(tree)
        parts = []
        for l, size, padded in zip(leaves, self.sizes, self.padded_sizes):
            flat = l.reshape(-1).astype(jnp.float32)
            if padded > size:
                flat = jnp.pad(flat, (0, padded - size))
            parts.append(flat)
        tail = self.padded_total - (self.offsets[-1] + self.padded_sizes[-1]
                                    if self.offsets else 0)
        if tail:
            parts.append(jnp.zeros((tail,), jnp.float32))
        return jnp.concatenate(parts)

    def unravel(self, flat: jax.Array) -> Params:
        leaves = [
            flat[o:o + n].reshape(s).astype(dt)
            for o, n, s, dt in zip(self.offsets, self.sizes, self.shapes,
                                   self.dtypes)
        ]
        return jax.tree.unflatten(self.treedef, leaves)

    # -- shard geometry -----------------------------------------------------
    def shard_bounds(self, s: int) -> tuple[int, int]:
        """[start, stop) of shard ``s``'s flat slice (host ints)."""
        if not 0 <= s < self.num_shards:
            raise IndexError(s)
        return s * self.shard_size, (s + 1) * self.shard_size

    def leaves_in_shard(self, s: int) -> tuple[int, ...]:
        """Leaf indices whose (padded) extent overlaps shard ``s`` — what
        a per-leaf chain would have to launch on this shard."""
        lo, hi = self.shard_bounds(s)
        return tuple(
            j for j, (o, n) in enumerate(zip(self.offsets,
                                             self.padded_sizes))
            if o < hi and o + n > lo)


def init_sharded_flat_buffer(params: Params, buffer_size: int,
                             num_shards: int, tile: int = BLOCK_N
                             ) -> tuple[ShardedFlatLayout, dict]:
    """Sharded flat M-slot buffer: ``grads`` is ``(M, padded_total)`` and
    meant to live under a ``P(None, axis)`` sharding (columns split across
    PS shards, slots replicated)."""
    layout = ShardedFlatLayout.from_params(params, num_shards, tile)
    return layout, {
        "grads": jnp.zeros((buffer_size, layout.padded_total), jnp.float32),
        "tokens": jnp.zeros((buffer_size,), jnp.int32),
        "fill": jnp.zeros((), jnp.int32),
        "step": jnp.zeros((), jnp.int32),
    }


def make_sharded_apply(mesh: Mesh, layout: ShardedFlatLayout, *,
                       axis: str = "data", iota: int, eps: float = 1e-10,
                       interpret: bool | None = None):
    """shard_map'd single-launch apply: each PS shard runs ``gba_apply``
    on its contiguous ``(M, shard_size)`` buffer slice.

    Returns ``apply(param_flat, accum_flat, grads, tokens, step, lr) ->
    (new_param_flat, new_accum_flat)`` over GLOBAL ``(padded_total,)`` /
    ``(M, padded_total)`` arrays.  Tokens/step/lr are broadcast (``P()``)
    — the decay weights are computed once from them on every shard's
    scalar core; no collective touches the gradient columns.
    """
    if layout.num_shards != mesh.shape[axis]:
        raise ValueError(
            f"layout has {layout.num_shards} shards but mesh axis "
            f"{axis!r} has {mesh.shape[axis]} devices")
    from repro.kernels import ops

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axis), P(axis), P(None, axis), P(), P(), P()),
        out_specs=(P(axis), P(axis)),
        check_rep=False)
    def apply_shards(param_flat, accum_flat, grads, tokens, step, lr):
        return ops.gba_apply_flat(param_flat, accum_flat, grads, tokens,
                                  step, lr, iota=iota, eps=eps,
                                  interpret=interpret)

    return apply_shards


def sharded_flat_push_and_maybe_apply(
        buffer: dict, flat_grad: jax.Array, token: jax.Array,
        param_flat: jax.Array, accum_flat: jax.Array, lr, *, mesh: Mesh,
        layout: ShardedFlatLayout, axis: str = "data", iota: int,
        eps: float = 1e-10, interpret: bool | None = None):
    """Sharded counterpart of ``core.gba.flat_buffer_push_and_maybe_apply``.

    The push is elementwise along the flat axis, so under a
    ``P(None, axis)`` buffer sharding XLA keeps it communication-free; the
    apply branch is one shard-mapped ``gba_apply`` launch per PS shard.
    Returns ``(new_param_flat, new_accum_flat, applied, new_buffer)`` —
    the partial-buffer branch passes params/accum through untouched.
    """
    new_buffer, is_full = flat_buffer_push(buffer, flat_grad, token)
    apply_shards = make_sharded_apply(mesh, layout, axis=axis, iota=iota,
                                      eps=eps, interpret=interpret)

    def do_apply(operands):
        p, a, grads, tokens, step, lr_ = operands
        return apply_shards(p, a, grads, tokens, step, lr_)

    def do_noop(operands):
        p, a, *_ = operands
        return p, a

    new_param, new_accum = jax.lax.cond(
        is_full, do_apply, do_noop,
        (param_flat, accum_flat, new_buffer["grads"], new_buffer["tokens"],
         buffer["step"], jnp.asarray(lr, jnp.float32)))
    return new_param, new_accum, is_full, new_buffer


def per_leaf_kernel_apply(layout: ShardedFlatLayout, param_flat: jax.Array,
                          accum_flat: jax.Array, grads: jax.Array,
                          tokens: jax.Array, step: jax.Array, lr, *,
                          iota: int, eps: float = 1e-10,
                          interpret: bool | None = None
                          ) -> tuple[jax.Array, jax.Array]:
    """The per-leaf launch chain the sharded apply replaces: one
    ``gba_apply`` call per leaf slice (``len(layout.sizes)`` launches vs
    one per shard).  Kernel arithmetic is identical per element, so this
    is the bit-exactness oracle for the fused sharded path — and the
    launch-count baseline for ``benchmarks.bench_kernels``."""
    from repro.kernels import ops
    new_p, new_a = param_flat, accum_flat
    for off, size in zip(layout.offsets, layout.sizes):
        lp, la = ops.gba_apply_flat(
            param_flat[off:off + size], accum_flat[off:off + size],
            grads[:, off:off + size], tokens, step, lr, iota=iota, eps=eps,
            interpret=interpret)
        new_p = jax.lax.dynamic_update_slice(new_p, lp, (off,))
        new_a = jax.lax.dynamic_update_slice(new_a, la, (off,))
    return new_p, new_a
