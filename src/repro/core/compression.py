"""Wire-compression policy for the layer-grouped fused-psum schedule.

At production model sizes the per-group ``all_to_all`` (gradient routing)
is the per-step byte bill of a PS global step — the ``gba_apply`` kernel
is µs of roofline while the wire moves 4 bytes per parameter per worker.
:class:`CompressionPolicy` declares how that routing stage is compressed:

``none``
    f32 gradients on the wire — the PR-5 schedule, bit-identical.
``int8``
    Min-max affine quantization (the Bagua ``MinMaxUInt8`` idiom): per
    tile-aligned slice of the shard-major flat, ``zero_point = min`` and
    ``scale = (max - min) / 255``; values travel as int8 (the uint8 code
    shifted by -128) plus two f32 sideband words per tile.  ~0.25x bytes.
``onebit``
    1-bit-with-momentum (the Bagua onebit idiom): full-precision routing
    for :attr:`warmup_steps` global steps while a per-worker momentum
    EMA accumulates, then each step routes ``sign(momentum + residual)``
    as int8 plus one f32 per-tile mean-|.| norm.  ~0.25x bytes here
    (int8-coded signs; true bit-packing is a TPU-side follow-up).

Both lossy schemes carry **per-worker error-feedback residuals**: the
worker adds its residual to the payload before quantizing and keeps
``payload - dequantize(quantize(payload))`` for the next step, so
quantization error is re-injected instead of lost (the EF-signSGD /
1-bit Adam convergence argument).  Residuals (and the onebit momentum)
live in ``(M, padded_total)`` flat arrays whose column order is the
layout's shard-major order, so per-group views are the same
``group_shard_bounds`` column slices the routing stage already uses —
the buffers ride the existing ``(M, shard)`` machinery and survive
``shard_map`` unchanged (row ``w`` is worker ``w``'s state, sharded
``P(axis, None)``).

The policy is also the auditor's ground truth: GBA-COLL-005
(``repro.analysis``) checks every ``all_to_all``/``all_gather`` operand
dtype in the traced compressed step against
:meth:`CompressionPolicy.wire_dtype` — full-precision leakage after
warmup is a CI failure, not a silent perf regression.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

SCHEMES = ("none", "int8", "onebit")


@dataclass(frozen=True)
class CompressionPolicy:
    """Declared compression of the gradient-routing wire.

    ``warmup_steps`` global steps route full precision (f32) before the
    compressed wire switches on; the step function is built per phase
    (``warm=True`` / ``False`` in ``make_gba_fused_psum_step``) so each
    phase's jaxpr has exactly one wire dtype for the census to check.
    ``momentum`` is the onebit EMA coefficient (ignored by int8).
    """

    scheme: str = "none"
    warmup_steps: int = 0
    momentum: float = 0.9

    def __post_init__(self):
        if self.scheme not in SCHEMES:
            raise ValueError(
                f"unknown compression scheme {self.scheme!r}; "
                f"expected one of {SCHEMES}")
        if self.warmup_steps < 0:
            raise ValueError(f"warmup_steps must be >= 0, "
                             f"got {self.warmup_steps}")
        if not 0.0 <= self.momentum < 1.0:
            raise ValueError(f"momentum must be in [0, 1), "
                             f"got {self.momentum}")

    # -- state ---------------------------------------------------------------
    @property
    def stateful(self) -> bool:
        """Whether the step carries wire state (residual/momentum)."""
        return self.scheme != "none"

    def state_names(self) -> tuple[str, ...]:
        if self.scheme == "int8":
            return ("residual",)
        if self.scheme == "onebit":
            return ("residual", "momentum")
        return ()

    def init_wire_state(self, layout, m: int) -> dict:
        """Zero wire state: one ``(m, padded_total)`` f32 row per worker,
        columns in the layout's shard-major order."""
        return {name: jnp.zeros((m, layout.padded_total), jnp.float32)
                for name in self.state_names()}

    # -- wire accounting -----------------------------------------------------
    def wire_dtype(self, warm: bool = False) -> str:
        """Dtype of the gradient payload on the ``all_to_all`` wire."""
        if warm or self.scheme == "none":
            return "float32"
        return "int8"

    def sideband_floats_per_tile(self) -> int:
        """f32 sideband words routed per quantization tile."""
        if self.scheme == "int8":
            return 2                    # scale + zero_point
        if self.scheme == "onebit":
            return 1                    # per-tile mean-|.| norm
        return 0

    def route_bytes(self, group_size: int, tile: int,
                    warm: bool = False) -> int:
        """Per-device bytes one group's routing stage puts on the
        ``all_to_all`` wire per global step (payload + sideband)."""
        if warm or self.scheme == "none":
            return group_size * 4
        if group_size % tile:
            raise ValueError(f"group_size {group_size} not a multiple of "
                             f"tile {tile}")
        n_tiles = group_size // tile
        return group_size + self.sideband_floats_per_tile() * n_tiles * 4

    def wire_bytes(self, layout, warm: bool = False) -> int:
        """Total per-device gradient bytes on the wire per global step."""
        return sum(self.route_bytes(gs, layout.tile, warm=warm)
                   for gs in layout.group_sizes)

    def compression_ratio(self, layout) -> float:
        """Compressed / full-precision routed bytes (1.0 for ``none``)."""
        return self.wire_bytes(layout) / (layout.padded_total * 4)
