"""GBA — the paper's primary contribution: token-controlled global-batch
gradient aggregation with staleness decay, plus the semi-synchronous
baselines and the continual-training driver (see DESIGN.md §1)."""
from repro.core.continual import (ContinualResult, ModeSetup, default_setups,
                                  pretrain_sync, run_continual,
                                  schedule_for_day)
from repro.core.flat_sharded import (ShardedFlatLayout,
                                     init_sharded_flat_buffer,
                                     make_sharded_apply,
                                     sharded_flat_push_and_maybe_apply)
from repro.core.gba import (FlatLayout, aggregate_dense, aggregate_embedding,
                            buffer_push_and_maybe_apply, decay_weights,
                            flat_buffer_push, flat_buffer_push_and_maybe_apply,
                            init_buffer, init_flat_buffer)
from repro.core.staleness import (DECAY_FNS, exponential_decay, linear_decay,
                                  threshold_decay)
from repro.core.tokens import (TokenList, TokenListExhausted,
                               num_global_steps, token_for_batch,
                               token_list)
from repro.core.trainer import GBATrainer, ReplayStats, evaluate

__all__ = [
    "ContinualResult", "DECAY_FNS", "FlatLayout", "GBATrainer", "ModeSetup",
    "ReplayStats", "ShardedFlatLayout", "TokenList", "TokenListExhausted",
    "aggregate_dense", "aggregate_embedding",
    "buffer_push_and_maybe_apply", "decay_weights", "default_setups",
    "evaluate", "exponential_decay", "flat_buffer_push",
    "flat_buffer_push_and_maybe_apply",
    "init_buffer", "init_flat_buffer", "init_sharded_flat_buffer",
    "linear_decay", "make_sharded_apply", "num_global_steps",
    "pretrain_sync", "run_continual", "schedule_for_day",
    "sharded_flat_push_and_maybe_apply", "threshold_decay",
    "token_for_batch", "token_list",
]
