"""Serving configuration — the knobs of the stable ``repro.serving`` API."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ServingConfig:
    """Knobs shared by the LM engine and the recsys scoring engine.

    ``num_slots`` / ``max_len`` shape the LM engine's continuous decode
    batch and per-slot cache; ``sync_interval`` is the LiveSource sync
    thread's period in seconds (how stale a snapshot may grow before the
    next swap attempt); ``cache_capacity`` sizes the hot-ID embedding
    cache in resident rows (0 disables it — every lookup streams)."""
    num_slots: int = 4
    max_len: int = 256
    sync_interval: float = 0.05
    cache_capacity: int = 4096

    def __post_init__(self):
        if self.num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {self.num_slots}")
        if self.max_len < 2:
            raise ValueError(f"max_len must be >= 2, got {self.max_len}")
        if self.sync_interval <= 0:
            raise ValueError(f"sync_interval must be > 0, "
                             f"got {self.sync_interval}")
        if self.cache_capacity < 0:
            raise ValueError(f"cache_capacity must be >= 0, "
                             f"got {self.cache_capacity}")
