"""Continuous-batching serving engine.

Serves an assigned-architecture LM with slot-based continuous batching:
a fixed decode batch of ``num_slots`` sequences; finished/empty slots are
refilled from the waiting queue each step (prefill-on-admit into the
slot's cache region), so decode throughput stays high under ragged request
lengths — the standard production serving shape (vLLM-style scheduling at
the granularity JAX's static shapes allow).

Static-shape strategy: the decode step is jitted ONCE for (num_slots, 1)
tokens against a (num_slots, max_len) cache.  Admission writes a new
request's prefilled KV into its slot via ``jax.lax.dynamic_update_slice``
on the cache pytree (slot axis), keeping everything jit-compatible.

Works with any decoder architecture in the registry (attention KV caches,
ring buffers, SSM states alike — the cache pytree is slot-indexed on its
batch axis).

Online learning: the engine is constructed from a :class:`ParamSource`
(``serving.sources``) rather than raw params.  It pins EXACTLY ONE
parameter snapshot per decode step — ``_sync`` adopts the newest
snapshot at the step boundary, so a live sync landing mid-step can never
mix versions inside one forward pass.  KV already in a slot's cache was
computed under the version current at its step; tokens after a swap are
decoded under the new version against that cache — the standard online
serving semantics (see serving/README.md for the freshness contract).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.serving.config import ServingConfig
from repro.serving.sources import ParamSource, StaticSource


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    eos_id: int = -1                   # -1: only length-terminated
    # filled by the engine:
    output: list = field(default_factory=list)
    admitted_at_step: int = -1
    finished: bool = False


def _slot_assign(cache_tree: Any, slot_cache: Any, slot: int) -> Any:
    """Write slot_cache (batch=1 pytree) into cache_tree at slot index.
    Leaves whose first dim is the slot axis get updated; scalars pass."""

    def upd(full, one):
        if full.ndim == 0 or one is None or one.ndim != full.ndim:
            return full  # engine-owned leaves (e.g. the pos vector)
        # stacked-block caches: (repeats, B, ...); plain: (B, ...)
        if full.ndim >= 2 and one.shape[0] == full.shape[0] \
                and full.shape[1] != one.shape[1]:
            # (repeats, B, ...) vs (repeats, 1, ...)
            start = (0, slot) + (0,) * (full.ndim - 2)
            return jax.lax.dynamic_update_slice(full, one.astype(full.dtype),
                                                start)
        start = (slot,) + (0,) * (full.ndim - 1)
        return jax.lax.dynamic_update_slice(full, one.astype(full.dtype),
                                            start)

    return jax.tree.map(upd, cache_tree, slot_cache)


class ServingEngine:
    """Greedy-decoding continuous-batching engine.

    ``source`` is a :class:`~repro.serving.sources.ParamSource`; a raw
    params pytree is also accepted (wrapped in a StaticSource) so frozen
    checkpoint serving needs no ceremony.  ``config`` supplies the
    engine knobs; the ``num_slots``/``max_len`` kwargs override it."""

    def __init__(self, source: ParamSource | Any, cfg: ModelConfig, *,
                 config: ServingConfig | None = None,
                 num_slots: int | None = None,
                 max_len: int | None = None,
                 sampler: Callable | None = None):
        if not isinstance(source, ParamSource):
            source = StaticSource(source)
        self.source = source
        self.config = config or ServingConfig()
        self.cfg = cfg
        num_slots = num_slots if num_slots is not None \
            else self.config.num_slots
        max_len = max_len if max_len is not None else self.config.max_len
        self.num_slots = num_slots
        self.max_len = max_len
        snap = source.snapshot()
        self.params = snap.params
        self.param_version = snap.version
        self.param_step = snap.step
        self.syncs_adopted = 0
        self.clamped_requests = 0
        self.queue: list[Request] = []
        self.active: list[Request | None] = [None] * num_slots
        self.completed: list[Request] = []
        self.steps = 0
        self.decode_tokens = 0

        self._decode = jax.jit(
            lambda p, t, c: T.decode_step(p, cfg, t, c))
        self._prefill = jax.jit(
            lambda p, t: T.prefill(p, cfg, t, cache_len=max_len))
        self.cache = T.init_cache(cfg, num_slots, max_len)
        # per-slot positions (the global cache['pos'] is replaced by these)
        self.slot_pos = np.zeros(num_slots, np.int64)
        self.slot_remaining = np.zeros(num_slots, np.int64)
        self.tokens = jnp.zeros((num_slots, 1), jnp.int32)

    # -- param sync --------------------------------------------------------

    def _sync(self) -> None:
        """Adopt the newest snapshot at a step boundary.  ``snapshot()``
        never blocks (atomic reference read), so the decode hot path is
        never stalled by the sync thread."""
        snap = self.source.snapshot()
        if snap.version != self.param_version:
            self.params = snap.params
            self.param_version = snap.version
            self.param_step = snap.step
            self.syncs_adopted += 1

    def close(self, grace: float = 1.0) -> None:
        self.source.close(grace)

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        assert len(req.prompt) < self.max_len, "prompt exceeds cache"
        # admission bound: the slot writes cache position
        # len(prompt) + k on decode step k, so the generated budget must
        # keep every write inside the (num_slots, max_len) cache —
        # without this clamp slot_pos runs PAST the cache whenever
        # prompt_len + max_new_tokens > max_len
        budget = self.max_len - len(req.prompt)
        if req.max_new_tokens > budget:
            req.max_new_tokens = budget
            self.clamped_requests += 1
        self.queue.append(req)

    def _admit(self, slot: int, req: Request) -> None:
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, slot_cache = self._prefill(self.params, prompt)
        self.cache = _slot_assign(self.cache, slot_cache, slot)
        first = int(jnp.argmax(logits[0]))
        req.output.append(first)
        req.admitted_at_step = self.steps
        self.active[slot] = req
        self.slot_pos[slot] = len(req.prompt)
        self.slot_remaining[slot] = req.max_new_tokens - 1
        self.tokens = self.tokens.at[slot, 0].set(first)

    # -- stepping ----------------------------------------------------------

    def _refill(self) -> None:
        for slot in range(self.num_slots):
            if self.active[slot] is None and self.queue:
                self._admit(slot, self.queue.pop(0))

    def step(self) -> int:
        """One decode step over all occupied slots; returns #active."""
        self._sync()        # pin ONE snapshot version for this whole step
        self._refill()      # prefills run under the same pinned version
        occupied = [s for s in range(self.num_slots)
                    if self.active[s] is not None]
        if not occupied:
            return 0
        # per-slot (ragged) positions: attention_decode accepts a (B,)
        # position vector; the engine owns the authoritative slot_pos
        cache = dict(self.cache)
        cache["pos"] = jnp.asarray(self.slot_pos, jnp.int32)
        logits, new_cache = self._decode(self.params, self.tokens, cache)
        self.cache = new_cache
        self.steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int64)
        for slot in occupied:
            req = self.active[slot]
            tok = int(nxt[slot])
            req.output.append(tok)
            self.decode_tokens += 1
            self.slot_pos[slot] += 1
            self.slot_remaining[slot] -= 1
            if (self.slot_remaining[slot] <= 0
                    or (req.eos_id >= 0 and tok == req.eos_id)):
                req.finished = True
                self.completed.append(req)
                self.active[slot] = None
            else:
                self.tokens = self.tokens.at[slot, 0].set(tok)
        return len([s for s in self.active if s is not None])

    def run(self, max_steps: int = 10_000) -> dict:
        t0 = time.perf_counter()
        while (self.queue or any(self.active)) and self.steps < max_steps:
            self.step()
        dt = time.perf_counter() - t0
        return {
            "completed": len(self.completed),
            "decode_steps": self.steps,
            "decode_tokens": self.decode_tokens,
            "tokens_per_s": self.decode_tokens / dt if dt else 0.0,
            "slot_utilization": (self.decode_tokens
                                 / max(1, self.steps * self.num_slots)),
            "param_version": self.param_version,
            "param_step": self.param_step,
            "syncs_adopted": self.syncs_adopted,
            "clamped_requests": self.clamped_requests,
        }
