"""Stable public serving API.

Two engines over one parameter-source abstraction:

* :class:`ServingEngine` — continuous-batching LM decode
  (``serving.engine``);
* :class:`RecsysScoringEngine` — batched ID-list scoring with the hot-ID
  embedding cache (``serving.recsys``);
* :class:`StaticSource` / :class:`LiveSource` + :class:`UpdateChannel` —
  frozen-checkpoint vs streaming-from-the-trainer params
  (``serving.sources``);
* :class:`ServingConfig` — the shared knob dataclass.

See serving/README.md for the param-sync protocol and the
freshness/staleness contract.
"""
from repro.serving.config import ServingConfig
from repro.serving.engine import Request, ServingEngine
from repro.serving.recsys import RecsysScoringEngine, init_scoring_params
from repro.serving.sources import (LiveSource, ParamSource, Snapshot,
                                   StaticSource, UpdateChannel)

__all__ = [
    "LiveSource",
    "ParamSource",
    "RecsysScoringEngine",
    "Request",
    "ServingConfig",
    "ServingEngine",
    "Snapshot",
    "StaticSource",
    "UpdateChannel",
    "init_scoring_params",
]
