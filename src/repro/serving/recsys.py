"""Recsys scoring path: batched ID-list requests -> cached/streamed
embedding lookup -> dense tower.

This is the serving shape of the paper's workload (industrial CTR
models): a request carries ``(B, F)`` categorical ID lists; the engine
hashes them into the HBM-resident embedding table, sum-pools the rows —
through the :class:`~repro.embeddings.hot_cache.HotIDCache`, so the
Zipf-hot head of the ID distribution never touches the DMA-streamed
kernel — and scores the pooled vector with a jitted dense tower.

Live params: the engine subscribes to its :class:`ParamSource`.  On each
version swap the listener invalidates the cache entries for the rows the
update TOUCHED (the rest stay bit-valid) and adopts the new version.
Scoring pins one snapshot per call, so every score in a batch comes from
a single parameter version.

Bit-exactness: the pooled vector is produced by
:func:`~repro.embeddings.hot_cache.cached_pooled_lookup` (f32 numpy
pooling over per-unique-ID rows; see its module docstring), so a
live-synced engine and a fresh engine rebuilt from a checkpoint of the
same state return bit-identical scores — the acceptance property
``tests/test_serving_live.py`` pins at every sync boundary.
"""
from __future__ import annotations

import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.embeddings.hot_cache import HotIDCache, cached_pooled_lookup
from repro.embeddings.table import EmbeddingTable, StreamConfig, hash_ids
from repro.models.recsys import _mlp_fwd, _mlp_init
from repro.serving.config import ServingConfig
from repro.serving.sources import ParamSource, Snapshot, StaticSource


def init_scoring_params(key, capacity: int, dim: int,
                        mlp_dims: tuple[int, ...] = (64, 32)) -> dict:
    """Fresh serving params: an (capacity, dim) embedding table + a
    (dim, *mlp_dims, 1) dense tower — the pytree a GBA trainer owns and
    a checkpoint stores."""
    from repro.embeddings.table import init_table
    k1, k2 = jax.random.split(key)
    return {
        "table": init_table(k1, capacity, dim),
        "mlp": _mlp_init(k2, (dim, *mlp_dims, 1)),
    }


def _as_table(t: Any) -> EmbeddingTable:
    """Checkpoint round-trips turn the EmbeddingTable NamedTuple into a
    plain tuple — normalize back."""
    if isinstance(t, EmbeddingTable):
        return t
    if isinstance(t, (tuple, list)):
        return EmbeddingTable(jnp.asarray(t[0]), jnp.asarray(t[1]))
    raise TypeError(f"expected EmbeddingTable, got {type(t)!r}")


class RecsysScoringEngine:
    """Batched ID-list scoring with a hot-ID cache and live param sync.

    ``source`` snapshots carry ``{"table": EmbeddingTable,
    "mlp": params}`` (see :func:`init_scoring_params`); a raw params dict
    is wrapped in a StaticSource.  ``config.cache_capacity`` sizes the
    hot-ID cache (0 = no cache, every lookup streams)."""

    def __init__(self, source: ParamSource | dict, *,
                 config: ServingConfig | None = None,
                 stream: StreamConfig | None = None):
        if not isinstance(source, ParamSource):
            source = StaticSource(source)
        self.source = source
        self.config = config or ServingConfig()
        self.stream = stream
        snap = source.snapshot()
        self._table = _as_table(snap.params["table"])
        self._mlp = snap.params["mlp"]
        self._version = snap.version
        self.param_step = snap.step
        self._n_mlp = sum(1 for k in self._mlp if k.startswith("w"))
        dim = self._table.table.shape[1]
        self.cache = (HotIDCache(self.config.cache_capacity, dim)
                      if self.config.cache_capacity else None)
        if self.cache is not None:
            self.cache.bump_version(snap.version)
        self._sync_lock = threading.Lock()
        self.requests = 0
        self.scored = 0
        self.syncs_adopted = 0
        self.latencies_us: list[float] = []
        # the dense tower is jitted once; (B, D) -> (B,) score
        n_layers = self._n_mlp
        self._tower = jax.jit(
            lambda p, x: jax.nn.sigmoid(_mlp_fwd(p, x, n_layers)[:, 0]))
        source.add_listener(self._on_sync)

    # -- live sync ---------------------------------------------------------
    def _on_sync(self, snap: Snapshot, touched: Any) -> None:
        """Runs on the SYNC thread after each version swap: adopt the new
        table/tower and drop exactly the cache rows the update touched.
        The lock only guards the (table, mlp, version) triple becoming
        visible together — the scoring hot path holds it for a reference
        copy, never across a kernel call."""
        table = _as_table(snap.params["table"])
        with self._sync_lock:
            self._table = table
            self._mlp = snap.params["mlp"]
            self._version = snap.version
            self.param_step = snap.step
            self.syncs_adopted += 1
        if self.cache is not None:
            self.cache.bump_version(snap.version, touched)

    def _pin(self) -> tuple[EmbeddingTable, Any, int]:
        with self._sync_lock:
            return self._table, self._mlp, self._version

    # -- scoring hot path --------------------------------------------------
    def score(self, raw_ids: np.ndarray) -> np.ndarray:
        """(B, F) raw categorical IDs -> (B,) f32 scores, all under ONE
        pinned parameter version."""
        t0 = time.perf_counter()
        table, mlp, version = self._pin()
        hashed = np.asarray(hash_ids(jnp.asarray(raw_ids, jnp.int32),
                                     table.table.shape[0]))
        pooled = cached_pooled_lookup(self.cache, table, hashed,
                                      version=version, stream=self.stream)
        out = np.asarray(self._tower(mlp, jnp.asarray(pooled)))
        self.requests += 1
        self.scored += out.shape[0]
        self.latencies_us.append((time.perf_counter() - t0) * 1e6)
        return out

    def close(self, grace: float = 1.0) -> None:
        self.source.close(grace)

    def stats(self) -> dict:
        lat = np.asarray(self.latencies_us, np.float64)
        with self._sync_lock:
            # one consistent view: a sync between these reads could
            # otherwise pair the new version with the old step
            version, step, adopted = (self._version, self.param_step,
                                      self.syncs_adopted)
        out = {
            "requests": self.requests,
            "scored": self.scored,
            "param_version": version,
            "param_step": step,
            "syncs_adopted": adopted,
            "hit_rate": self.cache.hit_rate if self.cache else 0.0,
            "cache_rows": len(self.cache) if self.cache else 0,
            "cache_bytes": self.cache.nbytes if self.cache else 0,
        }
        if lat.size:
            out["p50_us"] = float(np.percentile(lat, 50))
            out["p99_us"] = float(np.percentile(lat, 99))
        return out
