"""Parameter sources: where a serving engine's weights come from.

The paper's setting is *online* learning — the model a request hits is
continuously trained (GBA Sec. 5).  This module closes that train→serve
loop with three pieces:

* :class:`Snapshot` — an immutable ``(version, step, params)`` triple.
  Engines pin ONE snapshot per decode/score step, so a sync landing
  mid-step can never mix two parameter versions inside one output.
* :class:`StaticSource` — the frozen-checkpoint degenerate case (version
  never moves past 1).  ``StaticSource.from_checkpoint`` restores the
  params pytree via :func:`repro.checkpoint.load_pytree`.
* :class:`UpdateChannel` + :class:`LiveSource` — the online path.  The
  trainer *publishes* parameter states into the channel (coalescing: only
  the newest pending state is kept, touched-ID sets are unioned); a
  LiveSource daemon thread *consumes* them at a configurable interval and
  atomically swaps in a fresh immutable Snapshot.  This is the Bagua
  async-model-average shape: the sync thread is fully decoupled from the
  serving hot path — ``snapshot()`` is a plain attribute read, it never
  takes the channel lock, never copies, never blocks — and shutdown is a
  stop/grace protocol (``close()`` sets a stop event and joins with a
  grace timeout).

Consistency contract
--------------------
Snapshots are immutable and versioned; version increases by exactly 1 per
applied sync.  Listeners (e.g. the hot-ID embedding cache) are notified
*after* the swap with ``(snapshot, touched_ids)``; ``touched_ids=None``
means "assume everything changed".  A reader holding snapshot v keeps a
consistent view forever — syncs swap the reference, never mutate arrays.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, NamedTuple

import numpy as np


class Snapshot(NamedTuple):
    """One immutable parameter state.  ``version`` is the source-local
    sync counter (monotone, +1 per applied sync); ``step`` is the
    TRAINER's global step this state came from — the freshness clock
    (``freshness lag = trainer_step_now - snapshot.step``)."""
    version: int
    step: int
    params: Any


class ParamSource:
    """Protocol: anything with ``snapshot() -> Snapshot``, listener
    registration, and ``close()``.  Base class provides the listener
    plumbing and a no-op close."""

    def snapshot(self) -> Snapshot:
        raise NotImplementedError

    def add_listener(self, fn: Callable[[Snapshot, Any], None]) -> None:
        """``fn(snapshot, touched_ids)`` is called after every version
        swap.  ``touched_ids`` is a 1-D int array of embedding rows the
        update touched, or None for "invalidate everything"."""
        self._listeners = getattr(self, "_listeners", [])
        self._listeners.append(fn)

    def _notify(self, snap: Snapshot, touched: Any) -> None:
        for fn in getattr(self, "_listeners", []):
            fn(snap, touched)

    def close(self, grace: float = 1.0) -> None:  # noqa: ARG002
        return None


class StaticSource(ParamSource):
    """Frozen params (the pre-online-learning serving shape): one
    Snapshot, version 1, forever."""

    def __init__(self, params: Any, step: int = 0):
        self._snap = Snapshot(version=1, step=int(step), params=params)

    @classmethod
    def from_checkpoint(cls, path: str, step: int = 0,
                        select: str | None = None) -> "StaticSource":
        """Restore from an npz checkpoint file, or from a
        :class:`~repro.checkpoint.manager.CheckpointManager` directory
        (newest step wins and stamps the snapshot's ``step``).
        ``select`` picks one subtree of the stored state — e.g.
        ``"params"`` when the checkpoint holds a full train state."""
        import os

        from repro.checkpoint import load_pytree
        if os.path.isdir(path):
            from repro.checkpoint.manager import CheckpointManager
            step, path = CheckpointManager(path).latest_path()
        tree = load_pytree(path)
        if select is not None:
            tree = tree[select]
        return cls(tree, step=step)

    def snapshot(self) -> Snapshot:
        return self._snap


class UpdateChannel:
    """The trainer-side mailbox of the live sync channel.

    ``publish`` never blocks the trainer beyond a short lock: it replaces
    the pending state (coalescing — if the serving side is slower than
    the trainer, intermediate states are skipped, which is exactly the
    async-model-average semantics) and unions the touched-ID sets so a
    consumer that skips states still invalidates every row any skipped
    state touched."""

    def __init__(self):
        self._lock = threading.Lock()
        self._pending: tuple[Any, int] | None = None   # (params, step)
        self._touched: np.ndarray | None = None
        self._touched_valid = True   # False once any publish omitted ids
        self.published = 0
        self.coalesced = 0
        self.last_step = -1

    def publish(self, params: Any, step: int,
                touched_ids: Any | None = None) -> None:
        """Offer a new parameter state.  ``params`` must be safe to hand
        off (immutable jax arrays, or arrays the trainer will not mutate
        in place).  ``touched_ids``: embedding rows this state changed
        relative to the previously published one."""
        with self._lock:
            if self._pending is not None:
                self.coalesced += 1
            self._pending = (params, int(step))
            self.last_step = int(step)
            if touched_ids is None:
                self._touched_valid = False
                self._touched = None
            elif self._touched_valid:
                t = np.asarray(touched_ids).reshape(-1)
                self._touched = (t if self._touched is None
                                 else np.union1d(self._touched, t))
            self.published += 1

    def newest_step(self) -> int:
        """Newest published trainer step (-1 before any publish), read
        under the channel lock for a consistent freshness view."""
        with self._lock:
            return self.last_step

    def take(self) -> tuple[Any, int, np.ndarray | None] | None:
        """Consumer side: pop the newest pending state (or None)."""
        with self._lock:
            if self._pending is None:
                return None
            params, step = self._pending
            touched = self._touched if self._touched_valid else None
            self._pending = None
            self._touched = None
            self._touched_valid = True
            return params, step, touched


class LiveSource(ParamSource):
    """Streaming params from an :class:`UpdateChannel`, applied by a
    daemon sync thread every ``sync_interval`` seconds.

    * ``snapshot()`` is the hot path: one attribute read, no lock.
    * ``unravel`` adapts the trainer's native state to serving params —
      e.g. ``layout.unravel`` for the GBA trainer's flat vector.  It runs
      on the SYNC thread, so even an expensive unravel never stalls a
      decode step.
    * ``sync_now()`` applies any pending state synchronously — the
      deterministic path tests and benches drive (the thread is optional:
      ``start=False`` gives a purely pull-based source).
    * ``close(grace)`` is the stop/grace protocol: set the stop event,
      join the thread up to ``grace`` seconds.  A closed source keeps
      serving its last snapshot; it just stops syncing.
    """

    def __init__(self, channel: UpdateChannel, init_params: Any, *,
                 init_step: int = 0, sync_interval: float = 0.05,
                 unravel: Callable[[Any], Any] | None = None,
                 start: bool = True):
        self.channel = channel
        self.sync_interval = float(sync_interval)
        self._unravel = unravel
        self._snap = Snapshot(version=1, step=int(init_step),
                              params=init_params)
        self._swap_lock = threading.Lock()   # serializes appliers only
        self._stop = threading.Event()
        self.syncs = 0
        self._thread: threading.Thread | None = None
        if start:
            self._thread = threading.Thread(
                target=self._loop, name="live-param-sync", daemon=True)
            self._thread.start()

    # -- hot path ----------------------------------------------------------
    def snapshot(self) -> Snapshot:
        return self._snap          # atomic reference read; never blocks

    # -- sync side ---------------------------------------------------------
    def _apply(self, raw: Any, step: int, touched) -> Snapshot:
        params = self._unravel(raw) if self._unravel is not None else raw
        with self._swap_lock:
            old = self._snap
            snap = Snapshot(version=old.version + 1, step=int(step),
                            params=params)
            self._snap = snap      # THE atomic swap
            self.syncs += 1
        self._notify(snap, touched)
        return snap

    def sync_now(self) -> Snapshot | None:
        """Apply the newest pending update, if any.  Returns the new
        snapshot or None when nothing was pending."""
        item = self.channel.take()
        if item is None:
            return None
        return self._apply(*item)

    def _loop(self) -> None:
        while not self._stop.wait(self.sync_interval):
            try:
                self.sync_now()
            except Exception:      # never kill serving over one bad sync
                continue

    def close(self, grace: float = 1.0) -> None:
        """Stop/grace shutdown: signal the sync thread, join up to
        ``grace`` seconds.  Idempotent."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=grace)
            if self._thread.is_alive():   # pragma: no cover - grace blown
                raise RuntimeError(
                    "live-param-sync thread did not stop within grace")
            self._thread = None

    @property
    def closed(self) -> bool:
        return self._stop.is_set()

    def freshness_lag_steps(self) -> int:
        """Trainer steps the CURRENT snapshot is behind the newest
        published state (0 when fully caught up or nothing published).

        Read order matters for a consistent view: grab the snapshot
        FIRST, then the newest published step under the channel lock.
        A sync between the two reads can only make the snapshot newer
        than ``last`` (clamped to 0) — reading in the other order could
        report a phantom lag for a state the snapshot already includes.
        """
        snap = self._snap
        last = self.channel.newest_step()
        return max(0, last - snap.step) if last >= 0 else 0
