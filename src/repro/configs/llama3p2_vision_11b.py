"""Llama 3.2 Vision 11B [hf:meta-llama/Llama-3.2-11B-Vision].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256 — cross-attention
image layers every 5th layer.  Vision encoder is a stub per the carve-out:
input_specs() provides projected patch embeddings (B, num_image_tokens,
d_model); we implement the language decoder with interleaved cross-attn.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128_256,
    block_pattern=("global", "global", "global", "global", "cross"),
    num_image_tokens=1601,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
