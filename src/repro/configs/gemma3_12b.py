"""Gemma 3 12B [hf:google/gemma-3-1b-pt family].

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144 — 5 local : 1 global
attention, 128k context, sliding window 1024.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_ff=15360,
    vocab_size=262_144,
    head_dim=256,
    block_pattern=("local", "local", "local", "local", "local", "global"),
    sliding_window=1024,
    norm="rmsnorm",
    source="hf:google/gemma-3-1b-pt",
)
