"""StarCoder2-3B [arXiv:2402.19173].

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152 — GQA + RoPE, native
sliding-window attention (4096) -> qualifies for long_500k decode.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49_152,
    block_pattern=("local",),
    sliding_window=4096,
    norm="layernorm",
    source="arXiv:2402.19173",
)
