"""Kimi K2 — trillion-parameter MoE (paper-table) [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) d_ff=2048/expert vocab=163840, MoE 384
experts top-8.  First layer uses a dense FFN (as in the model card); the
remaining 60 MoE layers are scanned.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=163_840,
    head_dim=112,
    prefix_layers=("global",),
    block_pattern=("moe",),
    num_experts=384,
    experts_per_token=8,
    source="arXiv:2501.kimi2",
)
