"""Mamba2-780m — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1536 attention-free, vocab=50280, ssm_state=128.  d_ff=0: Mamba2
blocks subsume the FFN (expand factor 2).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=1,          # unused by mamba mixer
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50_280,
    block_pattern=("mamba",),
    ssm_state=128,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
