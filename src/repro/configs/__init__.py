"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.configs.base import (GBAConfig, InputShape, INPUT_SHAPES,
                                ModelConfig, TrainConfig)
from repro.configs.recsys import RECSYS_CONFIGS, RecsysConfig

_ARCH_MODULES = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "granite-8b": "granite_8b",
    "zamba2-2.7b": "zamba2_2p7b",
    "gemma3-12b": "gemma3_12b",
    "mamba2-780m": "mamba2_780m",
    "starcoder2-3b": "starcoder2_3b",
    "phi3.5-moe-42b-a6.6b": "phi3p5_moe_42b_a6p6b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llama-3.2-vision-11b": "llama3p2_vision_11b",
    "gemma2-27b": "gemma2_27b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS", "GBAConfig", "INPUT_SHAPES", "InputShape", "ModelConfig",
    "RECSYS_CONFIGS", "RecsysConfig", "TrainConfig", "all_configs",
    "get_config",
]
