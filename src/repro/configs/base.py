"""Config system for the GBA reproduction framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; the four
assigned input shapes live in :data:`INPUT_SHAPES`.  Configs are plain frozen
dataclasses so they can be hashed into jit static args and printed into
EXPERIMENTS.md verbatim.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Sequence

LayerKind = Literal[
    "global",       # full causal self-attention
    "local",        # sliding-window causal self-attention
    "mamba",        # Mamba2/SSD mixer (attention-free)
    "mamba_attn",   # Mamba2 mixer followed by a (shared) attention block
    "cross",        # self-attention + cross-attention (VLM / enc-dec decoder)
    "moe",          # full attention + MoE FFN
    "local_moe",    # sliding-window attention + MoE FFN
]


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.

    ``block_pattern`` is the repeating unit of the layer stack; the stack is
    ``block_pattern * num_repeats`` (+ ``prefix_layers`` un-scanned layers in
    front, e.g. kimi-k2's single dense layer).  The repeated part is executed
    with ``lax.scan`` over stacked params to keep HLO compact.
    """

    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio", "recsys"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 -> d_model // num_heads
    block_pattern: Sequence[LayerKind] = ("global",)
    prefix_layers: Sequence[LayerKind] = ()
    sliding_window: int = 0                # >0 for "local" layers
    logit_softcap: float = 0.0             # gemma2-style final-logit softcap
    attn_softcap: float = 0.0              # gemma2-style attention softcap
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_loss_weight: float = 0.01
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_head_dim: int = 64
    # VLM / audio frontend stubs
    num_image_tokens: int = 0              # patch embeddings per image
    encoder_layers: int = 0                # enc-dec: encoder depth
    encoder_frames: int = 0                # stub audio frame count
    # perf knobs (hillclimb variants — see EXPERIMENTS.md §Perf)
    attn_q_chunk: int = 0        # >0: chunk queries, remat body (flash-like)
    remat_blocks: bool = False   # checkpoint each scanned block (train)
    loss_seq_chunk: int = 0      # >0: seq-chunked CE loss (no full logits)
    mamba_split_proj: bool = False  # split fused in_proj (shard-aligned)
    # misc
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    dtype: str = "bfloat16"
    source: str = ""                       # citation for the config

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_repeats(self) -> int:
        n_scanned = self.num_layers - len(self.prefix_layers)
        assert n_scanned % len(self.block_pattern) == 0, (
            f"{self.name}: {n_scanned} scanned layers not divisible by "
            f"pattern of {len(self.block_pattern)}")
        return n_scanned // len(self.block_pattern)

    @property
    def is_attention_free(self) -> bool:
        kinds = set(self.block_pattern) | set(self.prefix_layers)
        return kinds <= {"mamba"}

    @property
    def supports_long_context(self) -> bool:
        """True if a 500k-token decode is in-regime (see DESIGN.md table)."""
        kinds = set(self.block_pattern) | set(self.prefix_layers)
        if kinds & {"mamba", "mamba_attn"}:
            return True
        # dense archs qualify only via a native sliding-window variant
        return "local" in kinds and self.sliding_window > 0

    @property
    def has_decode(self) -> bool:
        """Encoder-only models have no decode step (none assigned here)."""
        return True

    def reduced(self) -> "ModelConfig":
        """A smoke-test variant of the same family (<=2 pattern repeats,
        d_model<=256, <=4 experts) that runs a real step on CPU."""
        pat = tuple(self.block_pattern)
        d_model = min(self.d_model, 256)
        n_heads = min(self.num_heads, 4)
        n_kv = max(1, min(self.num_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=len(self.prefix_layers) + len(pat),
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.experts_per_token else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_chunk=16 if self.ssm_state else self.ssm_chunk,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window else 0,
            num_image_tokens=16 if self.num_image_tokens else 0,
            encoder_layers=min(self.encoder_layers, 2)
            if self.encoder_layers else 0,
            encoder_frames=min(self.encoder_frames, 32)
            if self.encoder_frames else 0,
        )


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class GBAConfig:
    """Hyper-parameters of the paper's technique (Sec. 4.1)."""

    local_batch: int = 1_024            # B_a
    buffer_size: int = 8                # M (gradients aggregated per step)
    staleness_tolerance: int = 4        # iota in Eq. (1)
    num_workers: int = 0                # N_a; 0 -> M (paper sets N_a = M)

    @property
    def global_batch(self) -> int:
        return self.local_batch * self.buffer_size

    @property
    def resolved_num_workers(self) -> int:
        return self.num_workers or self.buffer_size


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    optimizer: str = "adam"
    learning_rate: float = 6e-4
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    gba: GBAConfig = field(default_factory=GBAConfig)
    seed: int = 0
