"""Configs for the paper's own recommendation models (Tab. 5.1).

These are the models the GBA paper actually trains: DeepFM on Criteo, DIEN
on Alimama, YouTubeDNN on the Private dataset.  They run for real in this
container on synthetic skewed click streams (repro.data), at laptop scale.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    model: str                          # deepfm | youtubednn | dien
    num_fields: int                     # categorical feature fields
    hash_capacity: int                  # rows in the hashed embedding table
    embed_dim: int
    mlp_dims: Sequence[int]
    behavior_len: int = 0               # DIEN / YouTubeDNN behavior sequence
    source: str = ""


# Laptop-scale versions of the paper's three tasks.  Field counts follow the
# datasets (Criteo: 26 categorical fields; Alimama/Private: user-behavior
# sequence models); capacities are scaled down from the paper's 45B/160B/1.9T
# parameters to fit a CPU container while keeping the Zipf ID skew of Fig. 4.
CRITEO_DEEPFM = RecsysConfig(
    name="criteo-deepfm",
    model="deepfm",
    num_fields=26,
    hash_capacity=100_003,
    embed_dim=16,
    mlp_dims=(256, 128, 64),
    source="GBA paper Tab. 5.1 (Criteo-1TB / DeepFM), scaled",
)

ALIMAMA_DIEN = RecsysConfig(
    name="alimama-dien",
    model="dien",
    num_fields=8,
    hash_capacity=50_021,
    embed_dim=19,
    mlp_dims=(128, 64),
    behavior_len=16,
    source="GBA paper Tab. 5.1 (Alimama / DIEN), scaled",
)

PRIVATE_YOUTUBEDNN = RecsysConfig(
    name="private-youtubednn",
    model="youtubednn",
    num_fields=12,
    hash_capacity=100_003,
    embed_dim=24,
    mlp_dims=(256, 128, 64),
    behavior_len=32,
    source="GBA paper Tab. 5.1 (Private / YouTubeDNN), scaled",
)

RECSYS_CONFIGS = {
    c.name: c for c in (CRITEO_DEEPFM, ALIMAMA_DIEN, PRIVATE_YOUTUBEDNN)
}
