"""SeamlessM4T-medium [arXiv:2308.11596].

12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206 — encoder-decoder,
multimodal.  Per the carve-out the speech frontend is a stub: input_specs()
provides precomputed frame embeddings (B, encoder_frames, d_model); we
implement the 12-layer self-attn encoder + 12-layer cross-attn decoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,
    block_pattern=("cross",),
    encoder_layers=12,
    encoder_frames=1024,
    norm="layernorm",
    source="arXiv:2308.11596",
)
