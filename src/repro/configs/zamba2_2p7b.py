"""Zamba2-2.7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000 ssm_state=64.
Pattern: 5 Mamba2 blocks then 1 Mamba2+shared-attention block, repeated 9x.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32_000,
    block_pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "mamba_attn"),
    ssm_state=64,
    source="arXiv:2411.15242",
)
