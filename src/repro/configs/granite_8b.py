"""IBM Granite 8B code model [arXiv:2405.04324].

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152 — llama-arch, full
causal attention (no sliding window -> long_500k skipped, see DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49_152,
    block_pattern=("global",),
    source="arXiv:2405.04324",
)
