"""Gemma 2 27B [arXiv:2408.00118].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000 — alternating
local/global attention (window 4096), attention + final-logit softcaps.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    d_ff=36864,
    vocab_size=256_000,
    head_dim=128,
    block_pattern=("local", "global"),
    sliding_window=4096,
    logit_softcap=30.0,
    attn_softcap=50.0,
    source="arXiv:2408.00118",
)
