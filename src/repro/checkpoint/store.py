"""npz-based pytree checkpointing for continual-training inheritance.

The paper's protocol inherits a pre-trained checkpoint and keeps training
under a different mode; ``save_pytree``/``load_pytree`` round-trip arbitrary
params/optimizer-state pytrees (dicts/lists/tuples of arrays + scalars).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/#{i}"))
    elif tree is None:
        out[prefix + "/@none"] = np.zeros(0)
    else:
        arr = np.asarray(tree)
        if arr.dtype.kind == "V":  # bfloat16 etc.: stage losslessly as f32
            arr = np.asarray(jnp.asarray(tree, jnp.float32))
        out[prefix] = arr
    return out


def save_pytree(path: str, tree: Any) -> None:
    flat = _flatten(tree)
    spec = jax.tree.map(lambda x: None, tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, __spec__=np.frombuffer(
        json.dumps(_spec_of(tree)).encode(), dtype=np.uint8), **flat)


def _spec_of(tree: Any) -> Any:
    if isinstance(tree, dict):
        return {"t": "d", "k": {k: _spec_of(v) for k, v in tree.items()}}
    if isinstance(tree, tuple):
        return {"t": "t", "k": [_spec_of(v) for v in tree]}
    if isinstance(tree, list):
        return {"t": "l", "k": [_spec_of(v) for v in tree]}
    if tree is None:
        return {"t": "n"}
    return {"t": "a", "d": str(jnp.asarray(tree).dtype)}


def _rebuild(spec: Any, flat: dict[str, np.ndarray], prefix: str = "") -> Any:
    t = spec["t"]
    if t == "d":
        return {k: _rebuild(v, flat, f"{prefix}/{k}")
                for k, v in spec["k"].items()}
    if t in ("t", "l"):
        seq = [_rebuild(v, flat, f"{prefix}/#{i}")
               for i, v in enumerate(spec["k"])]
        return tuple(seq) if t == "t" else seq
    if t == "n":
        return None
    arr = jnp.asarray(flat[prefix])
    dt = spec.get("d")
    return arr.astype(dt) if dt and str(arr.dtype) != dt else arr


def load_pytree(path: str) -> Any:
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files if k != "__spec__"}
        spec = json.loads(bytes(data["__spec__"]).decode())
    return _rebuild(spec, flat)
