"""Step-numbered checkpoint manager with retention, for continual training.

    mgr = CheckpointManager(dir, keep=3)
    mgr.save(step, {"params": ..., "opt": ..., "last_update": ...})
    step, state = mgr.restore_latest()

The paper's continual protocol (inherit yesterday's checkpoint, train
today under whichever mode the cluster favours) maps onto save/restore of
the full train state including the per-ID ``last_update`` staleness tags.
"""
from __future__ import annotations

import os
import re
from typing import Any

from repro.checkpoint.store import load_pytree, save_pytree

_PAT = re.compile(r"ckpt_(\d+)\.npz$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.npz")

    def steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.dir):
            m = _PAT.match(f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def save(self, step: int, state: Any) -> str:
        path = self._path(step)
        save_pytree(path, state)
        for old in self.steps()[:-self.keep]:
            os.remove(self._path(old))
        return path

    def restore(self, step: int) -> Any:
        return load_pytree(self._path(step))

    def restore_latest(self) -> tuple[int, Any]:
        steps = self.steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return steps[-1], self.restore(steps[-1])

    def latest_path(self) -> tuple[int, str]:
        """(step, path) of the newest checkpoint — what a serving
        ``StaticSource.from_checkpoint`` resolves a directory to."""
        steps = self.steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return steps[-1], self._path(steps[-1])
