"""Deterministic fault plans for the end-to-end switching harness.

`sim.cluster` draws crashes from ``failure_rate`` *inside* its own event
loop — fine for schedule statistics, but the switching driver
(``launch.switch_driver``) needs the SAME faults to hit two runs (auto
vs forced-sync) at the same sim-clock times so speedup and recovery
claims compare like with like.  A :class:`FaultPlan` is that fixed
script: per-worker straggler windows (multiplicative slowdowns over a
time interval), transient crashes (Alg. 1 semantics — the in-flight
token is lost, the worker rejoins after its recovery time), telemetry
scrape dropouts (a window during which the controller sees no rates),
and async apply failures (global steps whose PS write is dropped, the
circuit-breaker trigger).

Plans are pure frozen data.  :class:`FaultInjector` is the runtime that
consumes a plan: it draws per-batch jitter from ``ClusterSpec``, tracks
which crash events have fired and until when each worker is down, and
counts what was lost — the driver asks it questions, it never touches
driver state.

``FaultPlan.strained`` builds the acceptance scenario (25% stragglers at
4x + one transient crash); ``FaultPlan.from_cluster_spec`` derives a plan
from an existing :class:`~repro.sim.cluster.ClusterSpec` so sim studies
and driver runs share one vocabulary.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.sim.cluster import ClusterSpec

INF = float("inf")


@dataclass(frozen=True)
class StragglerWindow:
    """Worker ``worker`` computes ``slowdown``x slower during
    [``start``, ``end``).  Overlapping windows on one worker multiply."""
    worker: int
    slowdown: float = 4.0
    start: float = 0.0
    end: float = INF

    def __post_init__(self):
        if self.worker < 0:
            raise ValueError(f"worker must be >= 0, got {self.worker}")
        if self.slowdown <= 0:
            raise ValueError(f"slowdown must be > 0, got {self.slowdown}")
        if self.end < self.start:
            raise ValueError(f"window ends ({self.end}) before it starts "
                             f"({self.start})")

    def active(self, t: float) -> bool:
        return self.start <= t < self.end


@dataclass(frozen=True)
class CrashEvent:
    """Worker ``worker`` dies at sim time ``at``: the batch it is
    computing (and its token) is lost — Alg. 1 — and it rejoins at
    ``at + recovery``."""
    worker: int
    at: float
    recovery: float = 5.0

    def __post_init__(self):
        if self.worker < 0:
            raise ValueError(f"worker must be >= 0, got {self.worker}")
        if self.recovery < 0:
            raise ValueError(f"recovery must be >= 0, got {self.recovery}")


@dataclass(frozen=True)
class ScrapeDropout:
    """Telemetry scrapes inside [``start``, ``end``) return nothing —
    the controller must hold its mode on the empty window."""
    start: float
    end: float

    def __post_init__(self):
        if self.end < self.start:
            raise ValueError(f"dropout ends ({self.end}) before it starts "
                             f"({self.start})")


@dataclass(frozen=True)
class FaultPlan:
    """A fixed, replayable script of faults for ``num_workers`` workers.

    ``apply_failures`` lists global steps whose async PS apply fails
    (gradients lost, params not committed) — repeated failures trip the
    driver's fallback-to-sync circuit breaker.
    """
    num_workers: int
    stragglers: tuple[StragglerWindow, ...] = ()
    crashes: tuple[CrashEvent, ...] = ()
    dropouts: tuple[ScrapeDropout, ...] = ()
    apply_failures: tuple[int, ...] = ()

    def __post_init__(self):
        if self.num_workers < 1:
            raise ValueError(
                f"num_workers must be >= 1, got {self.num_workers}")
        for s in self.stragglers:
            if s.worker >= self.num_workers:
                raise ValueError(
                    f"straggler worker {s.worker} out of range "
                    f"[0, {self.num_workers})")
        for c in self.crashes:
            if c.worker >= self.num_workers:
                raise ValueError(
                    f"crash worker {c.worker} out of range "
                    f"[0, {self.num_workers})")
        # crashes sorted by time makes the injector's scan deterministic
        object.__setattr__(self, "crashes",
                           tuple(sorted(self.crashes,
                                        key=lambda c: (c.at, c.worker))))

    # -- queries ------------------------------------------------------------
    def slowdown(self, worker: int, t: float) -> float:
        """Multiplicative slowdown of ``worker`` at sim time ``t``."""
        s = 1.0
        for w in self.stragglers:
            if w.worker == worker and w.active(t):
                s *= w.slowdown
        return s

    def scrape_lost(self, t: float) -> bool:
        return any(d.start <= t < d.end for d in self.dropouts)

    def straggler_workers(self) -> tuple[int, ...]:
        return tuple(sorted({w.worker for w in self.stragglers}))

    # -- constructors -------------------------------------------------------
    @classmethod
    def quiet(cls, num_workers: int) -> "FaultPlan":
        """No faults — the vacant-cluster baseline."""
        return cls(num_workers)

    @classmethod
    def strained(cls, num_workers: int, *, straggler_frac: float = 0.25,
                 slowdown: float = 4.0, crash_at: float | None = None,
                 recovery: float = 5.0, seed: int = 0) -> "FaultPlan":
        """The acceptance scenario: ``straggler_frac`` of the workers run
        ``slowdown``x slower for the whole run, plus ONE transient crash
        of a healthy worker at ``crash_at`` (default: 2 recovery periods
        in, so the run both loses the token and sees the rejoin)."""
        rng = np.random.default_rng(seed)
        n_slow = int(round(straggler_frac * num_workers))
        slow = sorted(rng.choice(num_workers, n_slow, replace=False))
        healthy = [w for w in range(num_workers) if w not in slow]
        victim = int(healthy[0] if healthy else 0)
        at = 2.0 * recovery if crash_at is None else crash_at
        return cls(
            num_workers,
            stragglers=tuple(StragglerWindow(int(w), slowdown)
                             for w in slow),
            crashes=(CrashEvent(victim, at, recovery),))

    @classmethod
    def from_cluster_spec(cls, spec: ClusterSpec, horizon: float,
                          local_batch: int = 256) -> "FaultPlan":
        """Derive a replayable plan from a :class:`ClusterSpec`:
        stragglers from ``straggler_frac``/``straggler_slowdown`` (same
        rng stream as ``worker_speeds``, so the SAME workers straggle),
        crashes sampled over [0, ``horizon``) from ``failure_rate`` (a
        per-batch probability, converted through the healthy batch
        duration) with ``recovery_time`` recoveries."""
        rng = np.random.default_rng(spec.seed)
        speeds = spec.worker_speeds(rng)
        stragglers = tuple(
            StragglerWindow(w, float(spec.base_speed / speeds[w]))
            for w in range(spec.num_workers)
            if speeds[w] < spec.base_speed)
        crashes = []
        if spec.failure_rate:
            batch_dur = local_batch / spec.base_speed
            # per-batch crash probability -> Poisson rate per second
            rate = -math.log(max(1.0 - spec.failure_rate, 1e-12)) / batch_dur
            for w in range(spec.num_workers):
                t = float(rng.exponential(1.0 / rate))
                while t < horizon:
                    crashes.append(CrashEvent(w, t, spec.recovery_time))
                    t += spec.recovery_time + float(
                        rng.exponential(1.0 / rate))
        return cls(spec.num_workers, stragglers=stragglers,
                   crashes=tuple(crashes))


class FaultInjector:
    """Runtime over one (:class:`FaultPlan`, :class:`ClusterSpec`) pair.

    Owns the jitter rng and all fault bookkeeping: which crash events
    have fired, until when each worker is down, and the loss counters.
    The driver asks (``duration``, ``crash_between``, ``is_down``,
    ``scrape``, ``apply_fails``); the injector never reaches into driver
    state, so two drivers replaying the same plan/spec/seed see
    identical faults.
    """

    def __init__(self, plan: FaultPlan, spec: ClusterSpec, seed: int = 0):
        if spec.num_workers != plan.num_workers:
            raise ValueError(
                f"spec has {spec.num_workers} workers, plan has "
                f"{plan.num_workers}")
        self.plan = plan
        self.spec = spec
        self.rng = np.random.default_rng(seed)
        self._base = np.full(spec.num_workers, spec.base_speed)
        self.down_until = np.zeros(spec.num_workers)
        self._fired: set[int] = set()       # indices into plan.crashes
        self.crash_log: list[CrashEvent] = []
        self.lost_tokens = 0
        self.dropped_scrapes = 0

    # -- timing -------------------------------------------------------------
    def duration(self, worker: int, t: float, local_batch: int) -> float:
        """Compute time of one local batch on ``worker`` starting at
        ``t``: spec jitter/contention on the healthy base speed, times
        the plan's straggler slowdown."""
        s = self.spec.speed_at(self._base, worker, t, self.rng)
        s = s / self.plan.slowdown(worker, t)
        return local_batch / max(s, 1e-3)

    # -- crashes ------------------------------------------------------------
    def crash_between(self, worker: int, t0: float,
                      t1: float) -> CrashEvent | None:
        """First unfired crash of ``worker`` in (``t0``, ``t1``]; firing
        it marks the worker down until ``at + recovery`` and counts the
        lost token (Alg. 1: the in-flight gradient disappears)."""
        for i, ev in enumerate(self.plan.crashes):
            if i in self._fired or ev.worker != worker:
                continue
            if t0 < ev.at <= t1:
                self._fired.add(i)
                self.down_until[worker] = max(self.down_until[worker],
                                              ev.at + ev.recovery)
                self.crash_log.append(ev)
                self.lost_tokens += 1
                return ev
        return None

    def is_down(self, worker: int, t: float) -> bool:
        return t < self.down_until[worker]

    # -- telemetry / PS -----------------------------------------------------
    def scrape(self, t: float, rates):
        """Rates as the controller sees them: ``None`` (counted) when the
        scrape falls in a dropout window."""
        if self.plan.scrape_lost(t):
            self.dropped_scrapes += 1
            return None
        return rates

    def apply_fails(self, gstep: int) -> bool:
        return gstep in self.plan.apply_failures
