"""Event-driven shared-cluster simulator.

Models the paper's Section 3.2 environment: N workers with heterogeneous,
time-varying speeds pull (params, batch, token) from the PS, compute, and
push gradients.  Six training modes are simulated:

  sync    AR barrier: step time = slowest worker (+ all-reduce latency)
  async   every gradient applied immediately (global step per gradient)
  bsp     aggregate ``b2`` gradients per apply, regardless of version
  hop_bs  bounded staleness: a worker blocks when it is more than ``b1``
          completed-batches ahead of the slowest worker
  hop_bw  backup workers: per synchronized round, the ``b3`` slowest
          gradients are dropped
  gba     token-control: async pulls; buffer of M; Eq.(1) decay with
          tolerance iota drops severely-stale gradients

Outputs a :class:`Schedule` — for every global step, the slots that were
aggregated, each slot carrying (batch index, token, dispatch step) — plus
:class:`SimMetrics` (QPS, staleness, drops).  ``repro.core.trainer`` replays
the schedule with real JAX gradients, so accuracy experiments inherit
realistic staleness patterns while staying deterministic.

Timing units are seconds; worker speed is samples/second.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class ClusterSpec:
    """A shared-cluster scenario (Fig. 1 abstraction)."""

    num_workers: int
    base_speed: float = 10_000.0       # samples/s of a healthy worker
    straggler_frac: float = 0.0        # fraction of workers that are slow
    straggler_slowdown: float = 4.0    # slow worker = base/slowdown
    jitter: float = 0.1                # lognormal sigma on per-batch time
    time_varying: bool = False         # sinusoidal contention (Fig. 1 day)
    contention_period: float = 200.0
    contention_depth: float = 0.6      # max fractional slowdown at peak
    allreduce_latency: float = 0.05    # sync-mode collective cost (s)
    ps_roundtrip: float = 0.01         # PS pull+push latency (s)
    ps_throughput: float = 0.0         # PS service rate (pushes/s); 0 = inf.
                                       # With a finite PS, high-concurrency
                                       # modes cap out — this is what makes
                                       # sync WIN on a vacant cluster (Fig. 1)
    failure_rate: float = 0.0          # P(worker crashes during a batch)
    recovery_time: float = 5.0         # seconds before a crashed worker
                                       # rejoins (its token is lost, Alg. 1)
    seed: int = 0

    def worker_speeds(self, rng: np.random.Generator) -> np.ndarray:
        speeds = np.full(self.num_workers, self.base_speed)
        n_slow = int(round(self.straggler_frac * self.num_workers))
        if n_slow:
            slow = rng.choice(self.num_workers, n_slow, replace=False)
            speeds[slow] = self.base_speed / self.straggler_slowdown
        return speeds

    def speed_at(self, speeds: np.ndarray, worker: int, t: float,
                 rng: np.random.Generator) -> float:
        s = speeds[worker]
        if self.time_varying:
            phase = 2 * math.pi * (t / self.contention_period
                                   + worker / self.num_workers)
            s = s * (1.0 - self.contention_depth
                     * 0.5 * (1 + math.sin(phase)))
        if self.jitter:
            s = s / rng.lognormal(0.0, self.jitter)
        return max(s, 1e-3)


@dataclass(frozen=True)
class Slot:
    batch_index: int
    token: int            # GBA token (= scheduled step); == dispatch for others
    dispatch_step: int    # global step whose params the gradient was taken at
    weight: float = 1.0   # aggregation weight after decay (0 = dropped)


@dataclass
class SimMetrics:
    mode: str
    wall_time: float = 0.0
    samples: int = 0
    num_global_steps: int = 0
    dropped_batches: int = 0
    lost_batches: int = 0              # worker failures (token disappeared)
    staleness_sum: float = 0.0
    staleness_max: int = 0
    staleness_count: int = 0
    worker_rates: list = field(default_factory=list)  # samples/s per worker

    @property
    def qps(self) -> float:
        return self.samples / self.wall_time if self.wall_time else 0.0

    @property
    def avg_staleness(self) -> float:
        return (self.staleness_sum / self.staleness_count
                if self.staleness_count else 0.0)


@dataclass
class Schedule:
    mode: str
    local_batch: int
    steps: list[list[Slot]] = field(default_factory=list)
    metrics: SimMetrics | None = None

    @property
    def max_dispatch_lag(self) -> int:
        lag = 0
        for k, slots in enumerate(self.steps):
            for s in slots:
                lag = max(lag, k - s.dispatch_step)
        return lag


def _sync_schedule(spec: ClusterSpec, num_batches: int, local_batch: int,
                   rng: np.random.Generator) -> Schedule:
    """AR synchronous training: N workers, barrier per step."""
    N = spec.num_workers
    speeds = spec.worker_speeds(rng)
    sched = Schedule("sync", local_batch)
    m = SimMetrics("sync")
    t = 0.0
    b = 0
    k = 0
    per_worker_time = np.zeros(N)
    while b + N <= num_batches:
        durs = [local_batch / spec.speed_at(speeds, w, t, rng)
                for w in range(N)]
        per_worker_time += np.asarray(durs)
        step_time = max(durs) + spec.allreduce_latency
        t += step_time
        sched.steps.append(
            [Slot(b + w, k, k) for w in range(N)])
        b += N
        k += 1
        m.samples += N * local_batch
        m.staleness_count += N
    m.wall_time = t
    m.num_global_steps = k
    if k:
        m.worker_rates = list(local_batch * k / np.maximum(per_worker_time,
                                                           1e-9))
    sched.metrics = m
    return sched


def _ps_schedule(spec: ClusterSpec, mode: str, num_batches: int,
                 local_batch: int, rng: np.random.Generator, *,
                 buffer_size: int = 1, iota: int = 0, b1: int = 0,
                 b3: int = 0) -> Schedule:
    """Event-driven PS modes: async / bsp / hop_bs / gba."""
    N = spec.num_workers
    speeds = spec.worker_speeds(rng)
    sched = Schedule(mode, local_batch)
    m = SimMetrics(mode)
    # (finish_time, worker, batch_index, token, dispatch_step)
    events: list[tuple[float, int, int, int, int]] = []
    next_batch = 0
    k = 0                       # global step (number of applies)
    buffer: list[tuple[int, int, int]] = []   # (batch, token, dispatch)
    completed = np.zeros(N, dtype=np.int64)   # per-worker finished batches
    blocked: list[int] = []
    t = 0.0
    ps_free = 0.0   # serialized PS service (finite ps_throughput)

    def dispatch(w: int, now: float):
        nonlocal next_batch
        if next_batch >= num_batches:
            return
        token = next_batch // buffer_size if mode == "gba" else k
        dur = (local_batch / spec.speed_at(speeds, w, now, rng)
               + spec.ps_roundtrip)
        heapq.heappush(events, (now + dur, w, next_batch, token, k))
        next_batch += 1

    for w in range(N):
        dispatch(w, 0.0)

    while events:
        t, w, batch, token, disp = heapq.heappop(events)
        # worker failure: the gradient (and its token) simply disappears;
        # Alg. 1 — the worker drops its state and rejoins after recovery
        if spec.failure_rate and rng.uniform() < spec.failure_rate:
            m.lost_batches += 1
            dispatch(w, t + spec.recovery_time)
            continue
        if spec.ps_throughput:
            # push is serviced by the PS serially; the worker itself is
            # not blocked (non-blocking push, Alg. 1)
            ps_free = max(t, ps_free) + 1.0 / spec.ps_throughput
            t_apply = ps_free
        else:
            t_apply = t
        completed[w] += 1
        buffer.append((batch, token, disp))
        if len(buffer) >= buffer_size:
            slots = []
            for (bi, tok, dp) in buffer:
                # Hop-BS's staleness is the worker-version gap its bound b1
                # controls (that is what the paper's Tab. 5.3 reports); the
                # token modes measure global-step data staleness.
                stale = (int(completed.max() - completed[w]) if mode ==
                         "hop_bs" else k - tok)
                if mode == "gba" and stale > iota:
                    slots.append(Slot(bi, tok, dp, weight=0.0))
                    m.dropped_batches += 1
                else:
                    slots.append(Slot(bi, tok, dp, weight=1.0))
                    m.staleness_sum += max(stale, 0)
                    m.staleness_max = max(m.staleness_max, max(stale, 0))
                    m.staleness_count += 1
                m.samples += local_batch
            sched.steps.append(slots)
            buffer.clear()
            k += 1
            # hop_bs: unblock workers now within the staleness bound
            if mode == "hop_bs":
                still: list[int] = []
                for bw in blocked:
                    if completed[bw] - completed.min() <= b1:
                        dispatch(bw, t)
                    else:
                        still.append(bw)
                blocked = still
        # re-dispatch this worker
        if mode == "hop_bs" and completed[w] - completed.min() > b1:
            blocked.append(w)
        else:
            dispatch(w, t)

    m.wall_time = max(t, ps_free)
    m.num_global_steps = k
    if m.wall_time > 0:
        m.worker_rates = list(completed * local_batch / m.wall_time)
    sched.metrics = m
    return sched


def _hop_bw_schedule(spec: ClusterSpec, num_batches: int, local_batch: int,
                     rng: np.random.Generator, b3: int) -> Schedule:
    """Backup workers: synchronized rounds of N, slowest b3 dropped."""
    N = spec.num_workers
    speeds = spec.worker_speeds(rng)
    sched = Schedule("hop_bw", local_batch)
    m = SimMetrics("hop_bw")
    t = 0.0
    b = 0
    k = 0
    while b + N <= num_batches:
        durs = np.array([local_batch / spec.speed_at(speeds, w, t, rng)
                         for w in range(N)])
        cutoff = np.partition(durs, N - b3 - 1)[N - b3 - 1] if b3 else durs.max()
        t += cutoff + spec.ps_roundtrip
        slots = []
        order = np.argsort(durs)
        for rank, w in enumerate(order):
            kept = rank < N - b3
            slots.append(Slot(b + int(w), k, k, weight=1.0 if kept else 0.0))
            if kept:
                m.samples += local_batch
                m.staleness_count += 1
            else:
                m.dropped_batches += 1
        sched.steps.append(slots)
        b += N
        k += 1
    m.wall_time = t
    m.num_global_steps = k
    sched.metrics = m
    return sched


def simulate(spec: ClusterSpec, mode: str, num_batches: int,
             local_batch: int, *, buffer_size: int = 1, iota: int = 4,
             b1: int = 2, b2: int = 20, b3: int = 0) -> Schedule:
    """Run one scenario.  ``buffer_size`` is GBA's M; ``b2`` is BSP's
    aggregation count; hyper-parameter names follow Tab. 5.1."""
    rng = np.random.default_rng(spec.seed)
    if mode == "sync":
        return _sync_schedule(spec, num_batches, local_batch, rng)
    if mode == "hop_bw":
        return _hop_bw_schedule(spec, num_batches, local_batch, rng, b3)
    if mode == "async":
        return _ps_schedule(spec, "async", num_batches, local_batch, rng,
                            buffer_size=1)
    if mode == "bsp":
        return _ps_schedule(spec, "bsp", num_batches, local_batch, rng,
                            buffer_size=b2)
    if mode == "hop_bs":
        return _ps_schedule(spec, "hop_bs", num_batches, local_batch, rng,
                            buffer_size=1, b1=b1)
    if mode == "gba":
        return _ps_schedule(spec, "gba", num_batches, local_batch, rng,
                            buffer_size=buffer_size, iota=iota)
    raise ValueError(f"unknown mode {mode!r}")
