from repro.sim.cluster import (ClusterSpec, Schedule, SimMetrics, Slot,
                               simulate)
from repro.sim.faults import (CrashEvent, FaultInjector, FaultPlan,
                              ScrapeDropout, StragglerWindow)

__all__ = ["ClusterSpec", "CrashEvent", "FaultInjector", "FaultPlan",
           "Schedule", "ScrapeDropout", "SimMetrics", "Slot",
           "StragglerWindow", "simulate"]
