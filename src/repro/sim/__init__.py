from repro.sim.cluster import (ClusterSpec, Schedule, SimMetrics, Slot,
                               simulate)

__all__ = ["ClusterSpec", "Schedule", "SimMetrics", "Slot", "simulate"]
