"""ROC-AUC — the paper's accuracy metric for all three tasks."""
from __future__ import annotations

import numpy as np


def auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Rank-based AUC (handles ties by average rank)."""
    labels = np.asarray(labels).reshape(-1)
    scores = np.asarray(scores).reshape(-1)
    n_pos = float(labels.sum())
    n_neg = float(len(labels) - n_pos)
    if n_pos == 0 or n_neg == 0:
        return 0.5
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    sorted_scores = scores[order]
    i = 0
    while i < len(sorted_scores):
        j = i
        while (j + 1 < len(sorted_scores)
               and sorted_scores[j + 1] == sorted_scores[i]):
            j += 1
        ranks[order[i:j + 1]] = 0.5 * (i + j) + 1.0
        i = j + 1
    sum_pos_ranks = ranks[labels > 0.5].sum()
    return float((sum_pos_ranks - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))


class StreamingAUC:
    """Accumulate (label, score) pairs across eval batches."""

    def __init__(self):
        self._labels: list[np.ndarray] = []
        self._scores: list[np.ndarray] = []

    def update(self, labels, scores):
        self._labels.append(np.asarray(labels).reshape(-1))
        self._scores.append(np.asarray(scores).reshape(-1))

    def compute(self) -> float:
        if not self._labels:
            return 0.5
        return auc(np.concatenate(self._labels), np.concatenate(self._scores))

    def reset(self):
        self._labels.clear()
        self._scores.clear()
