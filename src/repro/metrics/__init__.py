from repro.metrics.auc import auc, StreamingAUC

__all__ = ["auc", "StreamingAUC"]
