from repro.embeddings.hot_cache import (HotIDCache, cached_pooled_lookup,
                                        fetch_rows)
from repro.embeddings.table import (EmbeddingTable, StreamConfig,
                                    apply_sparse_grads, hash_ids, init_table,
                                    lookup, pooled_lookup, presence_counts,
                                    sparse_grads_to_dense)

__all__ = ["EmbeddingTable", "HotIDCache", "StreamConfig",
           "apply_sparse_grads", "cached_pooled_lookup", "fetch_rows",
           "hash_ids", "init_table", "lookup", "pooled_lookup",
           "presence_counts", "sparse_grads_to_dense"]
