from repro.embeddings.table import (EmbeddingTable, apply_sparse_grads,
                                    hash_ids, init_table, lookup,
                                    sparse_grads_to_dense)

__all__ = ["EmbeddingTable", "apply_sparse_grads", "hash_ids", "init_table",
           "lookup", "sparse_grads_to_dense"]
