from repro.embeddings.table import (EmbeddingTable, StreamConfig,
                                    apply_sparse_grads, hash_ids, init_table,
                                    lookup, pooled_lookup, presence_counts,
                                    sparse_grads_to_dense)

__all__ = ["EmbeddingTable", "StreamConfig", "apply_sparse_grads",
           "hash_ids", "init_table", "lookup", "pooled_lookup",
           "presence_counts", "sparse_grads_to_dense"]
