"""Hashing-trick embedding tables with per-ID update-step tracking.

This is the JAX stand-in for DeepRec's expandable HashTables (DESIGN.md §2):
IDs are hashed into a fixed-capacity table; each row carries the global step
of its last update (``last_update``), which implements Algorithm 2's per-ID
staleness decay — the embedding gradient of an ID is decayed against the
step *that ID* last saw, not the dense-parameter step.

``pooled_lookup`` is the kernel-backed sparse module: a differentiable
sum-pooled gather whose forward streams (BLOCK_V, BLOCK_D) table tiles out
of HBM and whose backward streams the sorted gradient rows — VMEM stays
O(block) at any capacity (``repro.kernels.embedding_bag``).  The capacity
knobs travel as a :class:`StreamConfig` so trainers and launch scripts can
size the blocks for their vocabulary.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops

Params = dict[str, Any]

# Knuth multiplicative hashing: spreads raw categorical IDs over the table.
_HASH_MULT = jnp.uint32(2654435761)


class EmbeddingTable(NamedTuple):
    table: jax.Array        # (capacity, dim)
    last_update: jax.Array  # (capacity,) int32 — global step of last update


def init_table(key, capacity: int, dim: int, scale: float = 0.01
               ) -> EmbeddingTable:
    return EmbeddingTable(
        table=jax.random.normal(key, (capacity, dim), jnp.float32) * scale,
        last_update=jnp.zeros((capacity,), jnp.int32),
    )


def hash_ids(raw_ids: jax.Array, capacity: int) -> jax.Array:
    h = (raw_ids.astype(jnp.uint32) * _HASH_MULT) >> jnp.uint32(8)
    return (h % jnp.uint32(capacity)).astype(jnp.int32)


def lookup(tbl: EmbeddingTable, hashed_ids: jax.Array) -> jax.Array:
    """hashed_ids: (...,) int32 -> (..., dim)."""
    return tbl.table[hashed_ids]


class StreamConfig(NamedTuple):
    """Capacity knobs for the DMA-streamed embedding kernels.

    ``None`` fields fall back to the kernel-module defaults (BLOCK_V /
    BLOCK_D / CHUNK_E).  Hashable on purpose: it rides through
    ``jax.custom_vjp`` as a nondiff argument and through jit static args.
    """
    block_v: int | None = None   # vocab rows per streamed table tile
    block_d: int | None = None   # embedding columns per output tile
    chunk_e: int | None = None   # sorted entries per pipeline step
    interpret: bool | None = None


class _BagMeta(NamedTuple):
    """Static (hashable) side-channel for the custom VJP: the backward
    kernel needs the table's capacity/dtype, which residuals can't carry."""
    stream: StreamConfig
    capacity: int
    dtype: str


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _pooled_bag(table: jax.Array, hashed_ids: jax.Array,
                meta: _BagMeta) -> jax.Array:
    s = meta.stream
    return ops.pooled_lookup(hashed_ids, table, block_v=s.block_v,
                             block_d=s.block_d, chunk_e=s.chunk_e,
                             interpret=s.interpret)


def _pooled_bag_fwd(table, hashed_ids, meta):
    return _pooled_bag(table, hashed_ids, meta), hashed_ids


def _pooled_bag_bwd(meta, hashed_ids, g):
    # VJP of sum-pool = unnormalized scatter-add of g rows; the per-ID
    # counts the kernel co-produces belong to Alg. 2's aggregation rule,
    # not to autodiff — they are recomputed where needed (presence_counts)
    s = meta.stream
    gtable, _ = ops.pooled_lookup_grad(
        hashed_ids, g.astype(jnp.float32), meta.capacity, block_v=s.block_v,
        block_d=s.block_d, chunk_e=s.chunk_e, interpret=s.interpret)
    return gtable.astype(meta.dtype), jnp.zeros(hashed_ids.shape,
                                                jax.dtypes.float0)


_pooled_bag.defvjp(_pooled_bag_fwd, _pooled_bag_bwd)


def pooled_lookup(tbl: EmbeddingTable, hashed_ids: jax.Array, *,
                  stream: StreamConfig | None = None) -> jax.Array:
    """Differentiable sum-pooled lookup: (B, F) int32 -> (B, dim).

    Forward and backward are the streamed Pallas kernels — the (capacity,
    dim) table never materializes a VMEM-resident block, so this is the
    production-vocabulary path (10^6+ rows)."""
    meta = _BagMeta(stream or StreamConfig(), tbl.table.shape[0],
                    str(tbl.table.dtype))
    return _pooled_bag(tbl.table, hashed_ids, meta)


def presence_counts(hashed_ids: jax.Array, capacity: int, *,
                    stream: StreamConfig | None = None) -> jax.Array:
    """Per-ID occurrence counts of a batch of hashed IDs: (...,) int32 ->
    (capacity,) float32, via the streamed sorted-scatter kernel's counts
    output — O(block) VMEM at any capacity, unlike an XLA one-hot
    scatter which materializes the (capacity,)-wide one-hot adds."""
    s = stream or StreamConfig()
    ids2d = hashed_ids.reshape(1, -1)
    zero_rows = jnp.zeros((1, 1), jnp.float32)
    _, counts = ops.pooled_lookup_grad(
        ids2d, zero_rows, capacity, block_v=s.block_v, block_d=s.block_d,
        chunk_e=s.chunk_e, interpret=s.interpret)
    return counts


def sparse_grads_to_dense(ids: jax.Array, rows: jax.Array, capacity: int
                          ) -> tuple[jax.Array, jax.Array]:
    """Scatter (ids (N,), rows (N,D)) into a dense (capacity, D) grad and a
    per-row occurrence count (capacity,)."""
    ids = ids.reshape(-1)
    rows = rows.reshape(ids.shape[0], -1)
    dense = jnp.zeros((capacity, rows.shape[-1]), rows.dtype)
    dense = dense.at[ids].add(rows)
    counts = jnp.zeros((capacity,), jnp.float32).at[ids].add(1.0)
    return dense, counts


def apply_sparse_grads(tbl: EmbeddingTable, dense_grad: jax.Array,
                       counts: jax.Array, lr: float, global_step: jax.Array
                       ) -> EmbeddingTable:
    """SGD apply of an aggregated sparse gradient; rows with counts>0 get
    their ``last_update`` stamped to ``global_step`` (Alg. 2 line 19)."""
    touched = counts > 0
    new_table = tbl.table - lr * dense_grad
    new_table = jnp.where(touched[:, None], new_table, tbl.table)
    new_last = jnp.where(touched, global_step, tbl.last_update)
    return EmbeddingTable(new_table, new_last.astype(jnp.int32))
