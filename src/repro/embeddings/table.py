"""Hashing-trick embedding tables with per-ID update-step tracking.

This is the JAX stand-in for DeepRec's expandable HashTables (DESIGN.md §2):
IDs are hashed into a fixed-capacity table; each row carries the global step
of its last update (``last_update``), which implements Algorithm 2's per-ID
staleness decay — the embedding gradient of an ID is decayed against the
step *that ID* last saw, not the dense-parameter step.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Params = dict[str, Any]

# Knuth multiplicative hashing: spreads raw categorical IDs over the table.
_HASH_MULT = jnp.uint32(2654435761)


class EmbeddingTable(NamedTuple):
    table: jax.Array        # (capacity, dim)
    last_update: jax.Array  # (capacity,) int32 — global step of last update


def init_table(key, capacity: int, dim: int, scale: float = 0.01
               ) -> EmbeddingTable:
    return EmbeddingTable(
        table=jax.random.normal(key, (capacity, dim), jnp.float32) * scale,
        last_update=jnp.zeros((capacity,), jnp.int32),
    )


def hash_ids(raw_ids: jax.Array, capacity: int) -> jax.Array:
    h = (raw_ids.astype(jnp.uint32) * _HASH_MULT) >> jnp.uint32(8)
    return (h % jnp.uint32(capacity)).astype(jnp.int32)


def lookup(tbl: EmbeddingTable, hashed_ids: jax.Array) -> jax.Array:
    """hashed_ids: (...,) int32 -> (..., dim)."""
    return tbl.table[hashed_ids]


def sparse_grads_to_dense(ids: jax.Array, rows: jax.Array, capacity: int
                          ) -> tuple[jax.Array, jax.Array]:
    """Scatter (ids (N,), rows (N,D)) into a dense (capacity, D) grad and a
    per-row occurrence count (capacity,)."""
    ids = ids.reshape(-1)
    rows = rows.reshape(ids.shape[0], -1)
    dense = jnp.zeros((capacity, rows.shape[-1]), rows.dtype)
    dense = dense.at[ids].add(rows)
    counts = jnp.zeros((capacity,), jnp.float32).at[ids].add(1.0)
    return dense, counts


def apply_sparse_grads(tbl: EmbeddingTable, dense_grad: jax.Array,
                       counts: jax.Array, lr: float, global_step: jax.Array
                       ) -> EmbeddingTable:
    """SGD apply of an aggregated sparse gradient; rows with counts>0 get
    their ``last_update`` stamped to ``global_step`` (Alg. 2 line 19)."""
    touched = counts > 0
    new_table = tbl.table - lr * dense_grad
    new_table = jnp.where(touched[:, None], new_table, tbl.table)
    new_last = jnp.where(touched, global_step, tbl.last_update)
    return EmbeddingTable(new_table, new_last.astype(jnp.int32))
