"""LRU hot-ID cache in front of the HBM-resident embedding tables.

Production recsys traffic is Zipf-skewed: a few thousand hot IDs cover
most lookups.  The DMA-streamed ``pooled_lookup`` kernel already makes
the cold path cheap (O(block) VMEM at any capacity); this cache makes the
hot path FREE — a batch whose unique IDs all hit is served from host
memory without invoking the streamed kernel at all (provable via the
``repro.kernels.ops.kernel_calls`` counter; the serving bench gates it as
``audit_hit_skips_kernel``).

Consistency with live param sync
--------------------------------
Every cached row is stamped with the snapshot version it was fetched
under.  On each sync the owner calls :meth:`bump_version` with the rows
the update touched: touched entries are dropped (they would be stale),
untouched entries survive (their table rows are bit-identical in the new
snapshot, so serving them stays bit-exact).  ``touched_ids=None`` means
"unknown what changed" and clears everything.  A ``put_many`` carrying a
version other than the cache's current one is IGNORED — the harmless
outcome of the benign race where a sync lands between a miss-fetch and
its insertion.

Bit-exactness of the cached read path
-------------------------------------
:func:`cached_pooled_lookup` always pools in float32 numpy over
*per-unique-ID rows*: hits come from the cache, misses are fetched
through the streamed kernel as pools-of-one (ids shaped ``(n, 1)`` — a
sum-pool over one element IS the row).  Hit or miss, the row values are
identical to the table's rows, and the pooling order is fixed by the
request (``rows[inverse].sum(axis=1)``), so ANY hit/miss mix produces
bit-identical pooled outputs — the property the live-vs-fresh serving
acceptance test pins.
"""
from __future__ import annotations

import threading
from collections import OrderedDict

import jax.numpy as jnp
import numpy as np

from repro.embeddings.table import EmbeddingTable, StreamConfig
from repro.kernels import ops


def _pad_pow2(n: int, floor: int = 8) -> int:
    """Pad miss-batch sizes to a power of two (>= floor) so the jitted
    streamed kernel sees a bounded set of shapes instead of retracing on
    every distinct miss count."""
    p = floor
    while p < n:
        p *= 2
    return p


class HotIDCache:
    """Thread-safe LRU of (hashed id -> f32 row) with version stamping.

    ``capacity`` is the max resident rows; ``dim`` the row width.  Reads
    and writes take a short lock around dict ops only — never around a
    kernel call."""

    def __init__(self, capacity: int, dim: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.dim = int(dim)
        self.version = 1
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0
        self._lock = threading.Lock()
        self._rows: OrderedDict[int, np.ndarray] = OrderedDict()

    # -- geometry (exact-gated in the serving bench) -----------------------
    @property
    def nbytes(self) -> int:
        """Worst-case resident bytes: capacity f32 rows."""
        return self.capacity * self.dim * 4

    def __len__(self) -> int:
        return len(self._rows)

    @property
    def hit_rate(self) -> float:
        with self._lock:   # hits/misses move together under the lock
            total = self.hits + self.misses
            return self.hits / total if total else 0.0

    # -- read/write --------------------------------------------------------
    def get_many(self, ids: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray]:
        """ids: (n,) unique int -> (rows (n, dim) f32, found (n,) bool).
        Rows for missing ids are zero-filled (caller overwrites them)."""
        ids = np.asarray(ids).reshape(-1)
        rows = np.zeros((ids.shape[0], self.dim), np.float32)
        found = np.zeros(ids.shape[0], bool)
        with self._lock:
            for i, raw in enumerate(ids):
                key = int(raw)
                row = self._rows.get(key)
                if row is not None:
                    self._rows.move_to_end(key)   # LRU touch
                    rows[i] = row
                    found[i] = True
            self.hits += int(found.sum())
            self.misses += int((~found).sum())
        return rows, found

    def put_many(self, ids: np.ndarray, rows: np.ndarray,
                 version: int) -> bool:
        """Insert freshly fetched rows.  Dropped (returns False) when
        ``version`` is not the cache's current version — the miss fetch
        raced a sync and its rows may be stale."""
        with self._lock:
            if int(version) != self.version:
                return False
            for raw, row in zip(np.asarray(ids).reshape(-1), rows):
                self._rows[int(raw)] = np.asarray(row, np.float32)
                self._rows.move_to_end(int(raw))
            while len(self._rows) > self.capacity:
                self._rows.popitem(last=False)
                self.evictions += 1
            return True

    # -- sync-side invalidation -------------------------------------------
    def bump_version(self, version: int,
                     touched_ids: np.ndarray | None = None) -> None:
        """Adopt a new snapshot version.  Entries for ``touched_ids`` are
        dropped; the rest stay valid (their rows did not change).  With
        ``touched_ids=None`` the whole cache is cleared."""
        with self._lock:
            if touched_ids is None:
                self.invalidations += len(self._rows)
                self._rows.clear()
            else:
                for raw in np.asarray(touched_ids).reshape(-1):
                    if self._rows.pop(int(raw), None) is not None:
                        self.invalidations += 1
            self.version = int(version)

    def clear(self) -> None:
        with self._lock:
            self._rows.clear()


def fetch_rows(table: jnp.ndarray, ids: np.ndarray, *,
               stream: StreamConfig | None = None) -> np.ndarray:
    """Fetch exact table rows through the DMA-streamed kernel: ids are
    shaped (n_pad, 1) so each output is a sum-pool over ONE element —
    i.e. the row itself.  The batch is padded to a power of two with the
    out-of-range sentinel id ``capacity`` (the kernel maps it to the
    no-DMA sentinel slot → zero row, sliced off here), bounding the set
    of shapes the jitted kernel ever traces."""
    ids = np.asarray(ids).reshape(-1)
    n = ids.shape[0]
    s = stream or StreamConfig()
    n_pad = _pad_pow2(n)
    padded = np.full((n_pad, 1), table.shape[0], np.int32)   # sentinel
    padded[:n, 0] = ids
    rows = ops.pooled_lookup(jnp.asarray(padded), table,
                             block_v=s.block_v, block_d=s.block_d,
                             chunk_e=s.chunk_e, interpret=s.interpret)
    return np.asarray(rows, np.float32)[:n]


def cached_pooled_lookup(cache: HotIDCache | None, tbl: EmbeddingTable,
                         hashed_ids: np.ndarray, *,
                         version: int = 1,
                         stream: StreamConfig | None = None) -> np.ndarray:
    """Sum-pooled lookup (B, F) -> (B, dim) through the hot-ID cache.

    Unique hit ids are served from the cache; misses fall through to
    :func:`fetch_rows` (the streamed kernel) and are inserted under
    ``version``.  A batch with zero unique misses performs ZERO kernel
    invocations.  Output is f32 numpy, bit-identical regardless of the
    hit/miss mix (see module docstring)."""
    ids = np.asarray(hashed_ids)
    B, F = ids.shape
    uniq, inv = np.unique(ids.reshape(-1), return_inverse=True)
    if cache is None:
        rows = fetch_rows(tbl.table, uniq, stream=stream)
    else:
        rows, found = cache.get_many(uniq)
        miss = ~found
        if miss.any():
            fetched = fetch_rows(tbl.table, uniq[miss], stream=stream)
            rows[miss] = fetched
            cache.put_many(uniq[miss], fetched, version)
    return rows[inv].reshape(B, F, rows.shape[-1]).sum(axis=1,
                                                       dtype=np.float32)
