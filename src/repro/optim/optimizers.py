"""Native JAX optimizers (optax-free): SGD, Adagrad, Adam.

The paper trains the async/GBA modes with Adagrad and the sync mode with
Adam (Tab. 5.1); both are first-class here.  Functional interface:

    opt = adam(lr=6e-4)
    state = opt.init(params)
    params, state = opt.update(params, grads, state)

``update`` is jittable; ``lr`` may be overridden per call for schedules.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any
State = Any


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Params], State]
    update: Callable[..., tuple[Params, State]]


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum:
            return {"mom": jax.tree.map(jnp.zeros_like, params)}
        return {}

    def update(params, grads, state, lr_override=None):
        step_lr = lr if lr_override is None else lr_override
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g,
                               state["mom"], grads)
            params = jax.tree.map(lambda p, m: p - step_lr * m, params, mom)
            return params, {"mom": mom}
        params = jax.tree.map(lambda p, g: (p - step_lr * g).astype(p.dtype),
                              params, grads)
        return params, state

    return Optimizer("sgd", init, update)


def adagrad(lr: float, eps: float = 1e-10, initial_accum: float = 0.1
            ) -> Optimizer:
    def init(params):
        return {"accum": jax.tree.map(
            lambda p: jnp.full(p.shape, initial_accum, jnp.float32), params)}

    def update(params, grads, state, lr_override=None):
        step_lr = lr if lr_override is None else lr_override

        def upd(p, g, a):
            gf = g.astype(jnp.float32)
            a = a + jnp.square(gf)
            new_p = p.astype(jnp.float32) - step_lr * gf / (jnp.sqrt(a) + eps)
            return new_p.astype(p.dtype), a

        flat = jax.tree.map(upd, params, grads, state["accum"])
        params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        accum = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        return params, {"accum": accum}

    return Optimizer("adagrad", init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(params, grads, state, lr_override=None):
        step_lr = lr if lr_override is None else lr_override
        count = state["count"] + 1
        bc1 = 1.0 - b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * jnp.square(gf)
            step = step_lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step = step + step_lr * weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        is3 = lambda t: isinstance(t, tuple)
        params = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
        m = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
        v = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
        return params, {"m": m, "v": v, "count": count}

    return Optimizer("adam", init, update)


def get_optimizer(name: str, lr: float, **kw) -> Optimizer:
    return {"sgd": sgd, "adagrad": adagrad, "adam": adam}[name](lr, **kw)
