from repro.optim.optimizers import (Optimizer, adagrad, adam, get_optimizer,
                                    sgd)

__all__ = ["Optimizer", "adagrad", "adam", "get_optimizer", "sgd"]
