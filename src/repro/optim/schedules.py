"""Learning-rate schedules (jittable step -> lr functions).

GBA's tuning-free contract means the schedule follows *global steps* —
which the buffer keeps aligned across modes (K = ceil(Q/M) steps per day
regardless of worker count), so a schedule tuned under sync stays valid
after switching.  ``Optimizer.update(..., lr_override=schedule(step))``.
"""
from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp

Schedule = Callable


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Schedule:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        progress = jnp.clip((step - warmup_steps)
                            / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(math.pi * progress)))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn


def inverse_sqrt(peak_lr: float, warmup_steps: int) -> Schedule:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        decay = peak_lr * jnp.sqrt(warmup_steps / jnp.maximum(
            step, warmup_steps))
        return jnp.where(step < warmup_steps, warm, decay)

    return fn


def step_decay(lr: float, boundaries: tuple[int, ...],
               factors: tuple[float, ...]) -> Schedule:
    def fn(step):
        out = jnp.asarray(lr, jnp.float32)
        for b, f in zip(boundaries, factors):
            out = jnp.where(jnp.asarray(step) >= b, lr * f, out)
        return out

    return fn
