"""Model assembly: embedding -> [prefix layers] -> scan(pattern blocks) ->
norm -> logits, for every assigned architecture family.

The repeated part of the stack runs under ``lax.scan`` over params stacked on
a leading ``num_repeats`` axis, which keeps the lowered HLO compact enough to
compile 80 (arch x shape x mesh) dry-run combinations on one CPU core.

Zamba2's *shared* attention block is faithful to the model card: a single
set of attention params applied inside every ``mamba_attn`` layer (passed to
the scan body by closure, not stacked).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.act_sharding import constrain
from repro.models import layers as L

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# per-kind layer init / apply
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, kind: str) -> Params:
    ks = jax.random.split(key, 6)
    if kind in ("global", "local"):
        return {"ln1": L.init_norm(cfg), "attn": L.init_attention(ks[0], cfg),
                "ln2": L.init_norm(cfg), "mlp": L.init_mlp(ks[1], cfg)}
    if kind in ("moe", "local_moe"):
        return {"ln1": L.init_norm(cfg), "attn": L.init_attention(ks[0], cfg),
                "ln2": L.init_norm(cfg), "moe": L.init_moe(ks[1], cfg)}
    if kind == "cross":
        return {"ln1": L.init_norm(cfg), "attn": L.init_attention(ks[0], cfg),
                "lnx": L.init_norm(cfg),
                "xattn": L.init_attention(ks[1], cfg, cross=True),
                "ln2": L.init_norm(cfg), "mlp": L.init_mlp(ks[2], cfg)}
    if kind == "mamba":
        return {"ln1": L.init_norm(cfg), "mixer": L.init_mamba(ks[0], cfg)}
    if kind == "mamba_attn":
        # shared-attention params are global (see init_model); the per-layer
        # part is just the mamba mixer + norms
        return {"ln1": L.init_norm(cfg), "mixer": L.init_mamba(ks[0], cfg),
                "ln_sh": L.init_norm(cfg)}
    raise ValueError(kind)


def _layer_fwd(p: Params, cfg: ModelConfig, kind: str, x, positions,
               *, memory=None, shared_attn=None, aux=0.0):
    window = cfg.sliding_window if kind in ("local", "local_moe") else 0
    if kind in ("global", "local"):
        x = x + L.attention_fwd(p["attn"], cfg, L.norm_fwd(p["ln1"], x),
                                positions, window=window)
        x = x + L.mlp_fwd(p["mlp"], L.norm_fwd(p["ln2"], x))
    elif kind in ("moe", "local_moe"):
        x = x + L.attention_fwd(p["attn"], cfg, L.norm_fwd(p["ln1"], x),
                                positions, window=window)
        h, a = L.moe_fwd(p["moe"], cfg, L.norm_fwd(p["ln2"], x))
        x, aux = x + h, aux + a
    elif kind == "cross":
        x = x + L.attention_fwd(p["attn"], cfg, L.norm_fwd(p["ln1"], x),
                                positions)
        x = x + L.attention_fwd(p["xattn"], cfg, L.norm_fwd(p["lnx"], x),
                                positions, kv_override=memory)
        x = x + L.mlp_fwd(p["mlp"], L.norm_fwd(p["ln2"], x))
    elif kind == "mamba":
        x = x + L.mamba_fwd(p["mixer"], cfg, L.norm_fwd(p["ln1"], x))
    elif kind == "mamba_attn":
        x = x + L.mamba_fwd(p["mixer"], cfg, L.norm_fwd(p["ln1"], x))
        x = x + L.attention_fwd(shared_attn["attn"], cfg,
                                L.norm_fwd(p["ln_sh"], x), positions)
    else:
        raise ValueError(kind)
    return x, aux


def _layer_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int
                 ) -> Params:
    window = cfg.sliding_window if kind in ("local", "local_moe") else 0
    if kind in ("global", "local", "moe", "local_moe"):
        return {"attn": L.init_attn_cache(cfg, batch, cache_len, window)}
    if kind == "cross":
        return {"attn": L.init_attn_cache(cfg, batch, cache_len)}
    if kind == "mamba":
        return {"ssm": L.init_mamba_cache(cfg, batch)}
    if kind == "mamba_attn":
        return {"ssm": L.init_mamba_cache(cfg, batch),
                "attn": L.init_attn_cache(cfg, batch, cache_len)}
    raise ValueError(kind)


def _layer_decode(p: Params, cfg: ModelConfig, kind: str, x, cache, pos,
                  *, memory=None, shared_attn=None):
    window = cfg.sliding_window if kind in ("local", "local_moe") else 0
    new = dict(cache)
    if kind in ("global", "local"):
        h, new["attn"] = L.attention_decode(
            p["attn"], cfg, L.norm_fwd(p["ln1"], x), cache["attn"], pos,
            window=window)
        x = x + h
        x = x + L.mlp_fwd(p["mlp"], L.norm_fwd(p["ln2"], x))
    elif kind in ("moe", "local_moe"):
        h, new["attn"] = L.attention_decode(
            p["attn"], cfg, L.norm_fwd(p["ln1"], x), cache["attn"], pos,
            window=window)
        x = x + h
        h, _ = L.moe_fwd(p["moe"], cfg, L.norm_fwd(p["ln2"], x))
        x = x + h
    elif kind == "cross":
        h, new["attn"] = L.attention_decode(
            p["attn"], cfg, L.norm_fwd(p["ln1"], x), cache["attn"], pos)
        x = x + h
        h, _ = L.attention_decode(p["xattn"], cfg, L.norm_fwd(p["lnx"], x),
                                  cache["attn"], pos, kv_override=memory)
        x = x + h
        x = x + L.mlp_fwd(p["mlp"], L.norm_fwd(p["ln2"], x))
    elif kind == "mamba":
        h, new["ssm"] = L.mamba_decode(p["mixer"], cfg,
                                       L.norm_fwd(p["ln1"], x), cache["ssm"])
        x = x + h
    elif kind == "mamba_attn":
        h, new["ssm"] = L.mamba_decode(p["mixer"], cfg,
                                       L.norm_fwd(p["ln1"], x), cache["ssm"])
        x = x + h
        h, new["attn"] = L.attention_decode(
            shared_attn["attn"], cfg, L.norm_fwd(p["ln_sh"], x),
            cache["attn"], pos)
        x = x + h
    else:
        raise ValueError(kind)
    return x, new


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init_model(key, cfg: ModelConfig) -> Params:
    dt = L.dtype_of(cfg)
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": L._dense_init(keys[0], (cfg.vocab_size, cfg.d_model), dt,
                               scale=0.02),
        "final_norm": L.init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._dense_init(
            keys[1], (cfg.d_model, cfg.vocab_size), dt)
    # un-scanned prefix layers
    if cfg.prefix_layers:
        pk = jax.random.split(keys[2], len(cfg.prefix_layers))
        params["prefix"] = [
            _init_layer(pk[i], cfg, kind)
            for i, kind in enumerate(cfg.prefix_layers)]
    # scanned pattern blocks: stack params over num_repeats
    n_rep = cfg.num_repeats

    def init_block(k):
        bk = jax.random.split(k, len(cfg.block_pattern))
        return {f"l{i}": _init_layer(bk[i], cfg, kind)
                for i, kind in enumerate(cfg.block_pattern)}

    params["blocks"] = jax.vmap(init_block)(jax.random.split(keys[3], n_rep))
    # shared attention (zamba2-style)
    if "mamba_attn" in cfg.block_pattern:
        params["shared_attn"] = {"attn": L.init_attention(keys[4], cfg)}
    # audio: encoder stack (self-attention only), scanned
    if cfg.encoder_layers:
        def init_enc(k):
            return _init_layer(k, cfg, "global")
        params["encoder"] = jax.vmap(init_enc)(
            jax.random.split(keys[5], cfg.encoder_layers))
        params["enc_norm"] = L.init_norm(cfg)
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def encode_audio(params: Params, cfg: ModelConfig, frames: jax.Array
                 ) -> jax.Array:
    """Run the (stub-fed) encoder: frames (B, T, D) -> memory (B, T, D)."""
    B, T, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def body(x, p):
        x, _ = _layer_fwd(p, cfg, "global", x, positions)
        return x, None

    x, _ = lax.scan(body, frames.astype(L.dtype_of(cfg)), params["encoder"])
    return L.norm_fwd(params["enc_norm"], x)


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
            memory: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  tokens: (B,S) int32.  memory: cross-attn
    context (image patch embeds / encoder output).  Returns (logits, aux)."""
    B, S = tokens.shape
    x = params["embed"][tokens] * (cfg.d_model ** 0.5 if cfg.tie_embeddings
                                   else 1.0)
    x = constrain(x.astype(L.dtype_of(cfg)))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    aux = jnp.zeros((), jnp.float32)
    shared = params.get("shared_attn")

    for i, kind in enumerate(cfg.prefix_layers):
        x, aux = _layer_fwd(params["prefix"][i], cfg, kind, x, positions,
                            memory=memory, shared_attn=shared, aux=aux)

    def body(carry, block_p):
        x, aux = carry
        for i, kind in enumerate(cfg.block_pattern):
            x, aux = _layer_fwd(block_p[f"l{i}"], cfg, kind, x, positions,
                                memory=memory, shared_attn=shared, aux=aux)
            x = constrain(x)
        return (x, aux), None

    if cfg.remat_blocks:
        # §Perf: save only the block boundary; recompute inside on backward
        body = jax.checkpoint(body)
    (x, aux), _ = lax.scan(body, (x, aux), params["blocks"])
    x = L.norm_fwd(params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, aux


# ---------------------------------------------------------------------------
# prefill: forward + cache construction (what prefill_32k lowers)
# ---------------------------------------------------------------------------

def _layer_prefill(p: Params, cfg: ModelConfig, kind: str, x, positions,
                   seq_len: int, cache_len: int, *, memory=None,
                   shared_attn=None):
    window = cfg.sliding_window if kind in ("local", "local_moe") else 0
    cache: Params = {}
    if kind in ("global", "local", "moe", "local_moe"):
        h, (k, v) = L.attention_fwd(p["attn"], cfg, L.norm_fwd(p["ln1"], x),
                                    positions, window=window, return_kv=True)
        cache["attn"] = L.kv_to_cache(cfg, k, v, seq_len, cache_len,
                                      window)
        x = x + h
        if kind in ("moe", "local_moe"):
            h, _ = L.moe_fwd(p["moe"], cfg, L.norm_fwd(p["ln2"], x))
        else:
            h = L.mlp_fwd(p["mlp"], L.norm_fwd(p["ln2"], x))
        x = x + h
    elif kind == "cross":
        h, (k, v) = L.attention_fwd(p["attn"], cfg, L.norm_fwd(p["ln1"], x),
                                    positions, return_kv=True)
        cache["attn"] = L.kv_to_cache(cfg, k, v, seq_len, cache_len)
        x = x + h
        x = x + L.attention_fwd(p["xattn"], cfg, L.norm_fwd(p["lnx"], x),
                                positions, kv_override=memory)
        x = x + L.mlp_fwd(p["mlp"], L.norm_fwd(p["ln2"], x))
    elif kind == "mamba":
        h, cache["ssm"] = L.mamba_fwd(p["mixer"], cfg,
                                      L.norm_fwd(p["ln1"], x),
                                      return_cache=True)
        x = x + h
    elif kind == "mamba_attn":
        h, cache["ssm"] = L.mamba_fwd(p["mixer"], cfg,
                                      L.norm_fwd(p["ln1"], x),
                                      return_cache=True)
        x = x + h
        h, (k, v) = L.attention_fwd(shared_attn["attn"], cfg,
                                    L.norm_fwd(p["ln_sh"], x), positions,
                                    return_kv=True)
        cache["attn"] = L.kv_to_cache(cfg, k, v, seq_len, cache_len)
        x = x + h
    else:
        raise ValueError(kind)
    return x, cache


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            memory: jax.Array | None = None,
            cache_len: int | None = None) -> tuple[jax.Array, Params]:
    """Score the prompt and build the decode cache.  Returns (last-position
    logits (B,V), cache ready for decode_step at pos=S)."""
    B, S = tokens.shape
    cache_len = cache_len or S
    x = params["embed"][tokens] * (cfg.d_model ** 0.5 if cfg.tie_embeddings
                                   else 1.0)
    x = x.astype(L.dtype_of(cfg))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    shared = params.get("shared_attn")
    cache: Params = {"pos": jnp.full((), S, jnp.int32)}
    if memory is not None:
        cache["memory"] = memory

    if cfg.prefix_layers:
        pc = []
        for i, kind in enumerate(cfg.prefix_layers):
            x, c = _layer_prefill(params["prefix"][i], cfg, kind, x,
                                  positions, S, cache_len, memory=memory,
                                  shared_attn=shared)
            pc.append(c)
        cache["prefix"] = pc

    def body(x, block_p):
        block_c = {}
        for i, kind in enumerate(cfg.block_pattern):
            x, block_c[f"l{i}"] = _layer_prefill(
                block_p[f"l{i}"], cfg, kind, x, positions, S, cache_len,
                memory=memory, shared_attn=shared)
            x = constrain(x)
        return x, block_c

    x, blocks_c = lax.scan(body, x, params["blocks"])
    cache["blocks"] = blocks_c
    x = L.norm_fwd(params["final_norm"], x[:, -1:, :])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=jnp.float32)[:, 0]
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               memory: jax.Array | None = None) -> Params:
    cache: Params = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.prefix_layers:
        cache["prefix"] = [
            _layer_cache(cfg, kind, batch, cache_len)
            for kind in cfg.prefix_layers]

    def one_block(_):
        return {f"l{i}": _layer_cache(cfg, kind, batch, cache_len)
                for i, kind in enumerate(cfg.block_pattern)}

    cache["blocks"] = jax.vmap(one_block)(jnp.arange(cfg.num_repeats))
    if memory is not None:
        cache["memory"] = memory
    return cache


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                cache: Params) -> tuple[jax.Array, Params]:
    """token: (B,1) int32 -> (logits (B,1,V), new cache)."""
    B = token.shape[0]
    pos = cache["pos"]
    memory = cache.get("memory")
    shared = params.get("shared_attn")
    x = params["embed"][token] * (cfg.d_model ** 0.5 if cfg.tie_embeddings
                                  else 1.0)
    x = x.astype(L.dtype_of(cfg))
    new_cache = dict(cache)
    new_cache["pos"] = pos + 1

    if cfg.prefix_layers:
        new_prefix = []
        for i, kind in enumerate(cfg.prefix_layers):
            x, c = _layer_decode(params["prefix"][i], cfg, kind, x,
                                 cache["prefix"][i], pos, memory=memory,
                                 shared_attn=shared)
            new_prefix.append(c)
        new_cache["prefix"] = new_prefix

    def body(x, xs):
        block_p, block_c = xs
        new_c = {}
        for i, kind in enumerate(cfg.block_pattern):
            x, new_c[f"l{i}"] = _layer_decode(
                block_p[f"l{i}"], cfg, kind, x, block_c[f"l{i}"], pos,
                memory=memory, shared_attn=shared)
        return x, new_c

    x, new_blocks = lax.scan(body, x, (params["blocks"], cache["blocks"]))
    new_cache["blocks"] = new_blocks
    x = L.norm_fwd(params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, new_cache


# ---------------------------------------------------------------------------
# losses / steps (undistributed reference; sharded versions in launch/)
# ---------------------------------------------------------------------------

def lm_loss(params: Params, cfg: ModelConfig, tokens: jax.Array,
            labels: jax.Array, memory: jax.Array | None = None) -> jax.Array:
    sc = cfg.loss_seq_chunk
    S = tokens.shape[1]
    if sc and S % sc == 0 and S > sc:
        # §Perf iteration 5: never materialize the full (B, S, V) logits —
        # scan the LM head + CE over sequence chunks with remat.
        hidden, aux = forward_hidden(params, cfg, tokens, memory=memory)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])

        def chunk_nll(h_blk, y_blk):
            logits = jnp.einsum("bsd,dv->bsv", h_blk, head,
                                preferred_element_type=jnp.float32)
            if cfg.logit_softcap:
                logits = jnp.tanh(logits / cfg.logit_softcap) \
                    * cfg.logit_softcap
            logp = jax.nn.log_softmax(logits, axis=-1)
            return -jnp.take_along_axis(
                logp, y_blk[..., None], axis=-1)[..., 0].sum()

        chunk_nll = jax.checkpoint(chunk_nll)

        def body(tot, start):
            h_blk = lax.dynamic_slice_in_dim(hidden, start, sc, axis=1)
            y_blk = lax.dynamic_slice_in_dim(labels, start, sc, axis=1)
            return tot + chunk_nll(h_blk, y_blk), None

        nq = S // sc
        total, _ = lax.scan(body, jnp.zeros((), jnp.float32),
                            jnp.arange(nq, dtype=jnp.int32) * sc)
        nll_mean = total / (tokens.shape[0] * S)
        return nll_mean + cfg.router_aux_loss_weight * aux
    logits, aux = forward(params, cfg, tokens, memory=memory)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll) + cfg.router_aux_loss_weight * aux


def forward_hidden(params: Params, cfg: ModelConfig, tokens: jax.Array,
                   memory: jax.Array | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """forward() up to the final norm (no logits)."""
    B, S = tokens.shape
    x = params["embed"][tokens] * (cfg.d_model ** 0.5 if cfg.tie_embeddings
                                   else 1.0)
    x = constrain(x.astype(L.dtype_of(cfg)))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    aux = jnp.zeros((), jnp.float32)
    shared = params.get("shared_attn")
    for i, kind in enumerate(cfg.prefix_layers):
        x, aux = _layer_fwd(params["prefix"][i], cfg, kind, x, positions,
                            memory=memory, shared_attn=shared, aux=aux)

    def body(carry, block_p):
        x, aux = carry
        for i, kind in enumerate(cfg.block_pattern):
            x, aux = _layer_fwd(block_p[f"l{i}"], cfg, kind, x, positions,
                                memory=memory, shared_attn=shared, aux=aux)
            x = constrain(x)
        return (x, aux), None

    if cfg.remat_blocks:
        body = jax.checkpoint(body)
    (x, aux), _ = lax.scan(body, (x, aux), params["blocks"])
    return L.norm_fwd(params["final_norm"], x), aux


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# layer groups: how the sharded fused-PS step consumes these params
# ---------------------------------------------------------------------------

def param_group_key(path_names: tuple[str, ...]) -> str:
    """Canonical layer-group for a param pytree path (the grouping the
    layer-grouped ``ShardedFlatLayout`` / ``make_gba_fused_psum_step``
    use).  Groups follow the order the forward consumes params — embed,
    then each prefix layer, then each scanned block-pattern position (one
    group per ``l{i}``, its leaves stacked over ``num_repeats``), then the
    tail norms/head — so a just-in-time per-group ``all_gather`` never
    holds more than one group's worth of gathered params live at once.

    Path grammar (see :func:`init_model`): top-level keys ``embed``,
    ``lm_head``, ``final_norm``, ``prefix`` (list), ``blocks`` (dict of
    ``l{i}``), ``shared_attn``, ``encoder``, ``enc_norm``.
    """
    if not path_names:
        return "misc"
    head = path_names[0]
    if head == "blocks" and len(path_names) > 1:
        return f"blocks.{path_names[1]}"       # one group per pattern slot
    if head == "prefix" and len(path_names) > 1:
        return f"prefix.{path_names[1]}"       # one group per prefix layer
    if head == "lm_head":
        return "head"
    # embed, final_norm, shared_attn, encoder, enc_norm, ...: one group per
    # top-level module (norms are tiny; their groups pad to one tile/shard)
    return head
