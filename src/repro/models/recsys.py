"""The paper's own recommendation models: DeepFM, YouTubeDNN, DIEN.

These are the models GBA actually trains (Tab. 5.1).  Each is a pure
function of ``(params, batch) -> logit`` where ``batch`` is a dict of hashed
categorical IDs (+ label).  The sparse module is the hashed embedding table
(``params["embed"]`` and, for DeepFM, ``params["linear"]``); everything else
is the dense module — exactly the paper's sparse/dense split, which GBA's
per-ID staleness decay relies on.

Batch layout (from repro.data.clickstream):
  fields:   (B, num_fields) int32   hashed categorical features
  behavior: (B, behavior_len) int32 hashed behavior-sequence IDs (DIEN/YTB)
  target:   (B,) int32              hashed target-item ID (DIEN/YTB)
  label:    (B,) float32            click label
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.recsys import RecsysConfig

Params = dict[str, Any]


def _mlp_init(key, dims: tuple[int, ...]) -> Params:
    ks = jax.random.split(key, len(dims) - 1)
    return {
        f"w{i}": jax.random.normal(ks[i], (dims[i], dims[i + 1]),
                                   jnp.float32) / math.sqrt(dims[i])
        for i in range(len(dims) - 1)
    } | {f"b{i}": jnp.zeros((dims[i + 1],), jnp.float32)
         for i in range(len(dims) - 1)}


def _mlp_fwd(p: Params, x: jax.Array, n: int, final_act: bool = False
             ) -> jax.Array:
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# DeepFM (Criteo task)
# ---------------------------------------------------------------------------

def init_deepfm(key, cfg: RecsysConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    deep_in = cfg.num_fields * cfg.embed_dim
    dims = (deep_in, *cfg.mlp_dims, 1)
    return {
        "embed": jax.random.normal(k1, (cfg.hash_capacity, cfg.embed_dim),
                                   jnp.float32) * 0.01,
        "linear": jax.random.normal(k2, (cfg.hash_capacity,),
                                    jnp.float32) * 0.01,
        "bias": jnp.zeros((), jnp.float32),
        "mlp": _mlp_init(k3, dims),
    }


def deepfm_logit(params: Params, cfg: RecsysConfig, batch: dict) -> jax.Array:
    ids = batch["fields"]                               # (B, F)
    e = params["embed"][ids]                            # (B, F, D)
    # first order
    first = params["linear"][ids].sum(axis=1)           # (B,)
    # FM second order: 0.5 * ((sum e)^2 - sum e^2)
    s = e.sum(axis=1)
    fm = 0.5 * (jnp.square(s) - jnp.square(e).sum(axis=1)).sum(axis=-1)
    # deep
    deep_in = e.reshape(e.shape[0], -1)
    n = len(cfg.mlp_dims) + 1
    deep = _mlp_fwd(params["mlp"], deep_in, n)[:, 0]
    return params["bias"] + first + fm + deep


# ---------------------------------------------------------------------------
# YouTubeDNN (Private task)
# ---------------------------------------------------------------------------

def init_youtubednn(key, cfg: RecsysConfig) -> Params:
    k1, k2 = jax.random.split(key)
    mlp_in = (cfg.num_fields + 2) * cfg.embed_dim  # fields + pooled + target
    dims = (mlp_in, *cfg.mlp_dims, 1)
    return {
        "embed": jax.random.normal(k1, (cfg.hash_capacity, cfg.embed_dim),
                                   jnp.float32) * 0.01,
        "mlp": _mlp_init(k2, dims),
    }


def youtubednn_logit(params: Params, cfg: RecsysConfig, batch: dict
                     ) -> jax.Array:
    e_fields = params["embed"][batch["fields"]]         # (B, F, D)
    e_beh = params["embed"][batch["behavior"]]          # (B, L, D)
    e_tgt = params["embed"][batch["target"]]            # (B, D)
    pooled = e_beh.mean(axis=1)
    x = jnp.concatenate(
        [e_fields.reshape(e_fields.shape[0], -1), pooled, e_tgt], axis=-1)
    n = len(cfg.mlp_dims) + 1
    return _mlp_fwd(params["mlp"], x, n)[:, 0]


# ---------------------------------------------------------------------------
# DIEN (Alimama task) — GRU interest extraction + attention evolution (lite)
# ---------------------------------------------------------------------------

def _gru_init(key, d_in: int, d_h: int) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "wx": jax.random.normal(k1, (d_in, 3 * d_h), jnp.float32)
        / math.sqrt(d_in),
        "wh": jax.random.normal(k2, (d_h, 3 * d_h), jnp.float32)
        / math.sqrt(d_h),
        "b": jnp.zeros((3 * d_h,), jnp.float32),
    }


def _gru_scan(p: Params, xs: jax.Array) -> jax.Array:
    """xs: (B, L, Din) -> hidden states (B, L, Dh)."""
    d_h = p["wh"].shape[0]
    B = xs.shape[0]

    def step(h, x):
        gx = x @ p["wx"] + p["b"]
        gh = h @ p["wh"]
        r = jax.nn.sigmoid(gx[:, :d_h] + gh[:, :d_h])
        z = jax.nn.sigmoid(gx[:, d_h:2 * d_h] + gh[:, d_h:2 * d_h])
        n = jnp.tanh(gx[:, 2 * d_h:] + r * gh[:, 2 * d_h:])
        h = (1 - z) * n + z * h
        return h, h

    _, hs = lax.scan(step, jnp.zeros((B, d_h), jnp.float32),
                     jnp.moveaxis(xs, 1, 0))
    return jnp.moveaxis(hs, 0, 1)


def init_dien(key, cfg: RecsysConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    D = cfg.embed_dim
    mlp_in = cfg.num_fields * D + D + D   # fields + final interest + target
    dims = (mlp_in, *cfg.mlp_dims, 1)
    return {
        "embed": jax.random.normal(k1, (cfg.hash_capacity, D),
                                   jnp.float32) * 0.01,
        "gru": _gru_init(k2, D, D),
        "att_w": jax.random.normal(k3, (D, D), jnp.float32) / math.sqrt(D),
        "mlp": _mlp_init(k4, dims),
    }


def dien_logit(params: Params, cfg: RecsysConfig, batch: dict) -> jax.Array:
    e_fields = params["embed"][batch["fields"]]
    e_beh = params["embed"][batch["behavior"]]          # (B, L, D)
    e_tgt = params["embed"][batch["target"]]            # (B, D)
    hs = _gru_scan(params["gru"], e_beh)                # (B, L, D)
    # target-conditioned attention over interest states
    att = jnp.einsum("bld,de,be->bl", hs, params["att_w"], e_tgt)
    att = jax.nn.softmax(att, axis=-1)
    interest = jnp.einsum("bl,bld->bd", att, hs)
    x = jnp.concatenate(
        [e_fields.reshape(e_fields.shape[0], -1), interest, e_tgt], axis=-1)
    n = len(cfg.mlp_dims) + 1
    return _mlp_fwd(params["mlp"], x, n)[:, 0]


# ---------------------------------------------------------------------------
# uniform interface
# ---------------------------------------------------------------------------

_INIT = {"deepfm": init_deepfm, "youtubednn": init_youtubednn,
         "dien": init_dien}
_LOGIT = {"deepfm": deepfm_logit, "youtubednn": youtubednn_logit,
          "dien": dien_logit}


def init_recsys(key, cfg: RecsysConfig) -> Params:
    return _INIT[cfg.model](key, cfg)


def recsys_logit(params: Params, cfg: RecsysConfig, batch: dict) -> jax.Array:
    return _LOGIT[cfg.model](params, cfg, batch)


def bce_loss(params: Params, cfg: RecsysConfig, batch: dict) -> jax.Array:
    logit = recsys_logit(params, cfg, batch)
    label = batch["label"]
    return jnp.mean(jnp.maximum(logit, 0) - logit * label
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def sparse_dense_split(params: Params) -> tuple[set[str], set[str]]:
    """Top-level param names belonging to the sparse vs dense module."""
    sparse = {k for k in params if k in ("embed", "linear")}
    dense = set(params) - sparse
    return sparse, dense
