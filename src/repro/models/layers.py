"""Layer zoo: norms, RoPE, GQA attention (global / sliding-window / cross),
gated MLP, MoE, and the Mamba2/SSD mixer.

Everything is pure-functional: ``init_*`` builds a params pytree (nested
dicts of jnp arrays), ``*_fwd`` applies it.  Attention layers support three
modes:

* full-sequence (training / prefill) — causal (+ optional window) mask;
* decode — one new token against a KV cache.  Global layers keep a full
  ``(B, cache_len, kv, hd)`` cache; local layers keep a **ring buffer** of
  ``window`` entries so a 500k-token context costs O(window) memory.

Keys are RoPE'd at insert time so ring-buffer rotation never needs to
re-rotate history.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.act_sharding import constrain_expert

Params = dict[str, Any]

_MASK_VALUE = -2.0e38


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: int | None = None) -> Params:
    dim = dim or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((dim,), jnp.float32)}
    return {"scale": jnp.zeros((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32)}


def norm_fwd(p: Params, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * lax.rsqrt(var + 1e-6)
        y = y * (1.0 + p["scale"]) + p["bias"]
    else:            # rmsnorm (gemma-style 1+scale)
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(ms + 1e-6) * (1.0 + p["scale"])
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA; global / local / cross)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    dt = dtype_of(cfg)
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    kv_src = cfg.d_model  # cross-attn keys come from encoder/vision states of d_model
    return {
        "wq": _dense_init(k1, (cfg.d_model, cfg.num_heads, hd), dt),
        "wk": _dense_init(k2, (kv_src, cfg.num_kv_heads, hd), dt),
        "wv": _dense_init(k3, (kv_src, cfg.num_kv_heads, hd), dt),
        "wo": _dense_init(k4, (cfg.num_heads, hd, cfg.d_model), dt),
    }


def _sdpa(q, k, v, mask, softcap: float) -> jax.Array:
    """q: (B,S,Hkv,G,hd)  k/v: (B,T,Hkv,hd)  mask: (B,S,T) bool or None."""
    hd = q.shape[-1]
    scores = jnp.einsum("bsngh,btnh->bnsgt", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    if mask is not None:
        # scores are (B,Hkv,S,G,T); expand mask to (B,1,S,1,T)
        scores = jnp.where(mask[:, None, :, None, :], scores, _MASK_VALUE)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnsgt,btnh->bsngh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out


def attention_fwd(p: Params, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, *, window: int = 0,
                  kv_override: jax.Array | None = None,
                  return_kv: bool = False):
    """Full-sequence attention. x: (B,S,D). kv_override: cross-attn memory.
    With ``return_kv`` also returns the (RoPE'd) k/v for cache prefill."""
    B, S, _ = x.shape
    G = cfg.num_heads // cfg.num_kv_heads
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    kv_in = x if kv_override is None else kv_override
    k = jnp.einsum("btd,dnh->btnh", kv_in, p["wk"])
    v = jnp.einsum("btd,dnh->btnh", kv_in, p["wv"])
    if kv_override is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        t_pos = positions
        q_pos = positions
        causal = q_pos[:, :, None] >= t_pos[:, None, :]
        if window:
            causal &= q_pos[:, :, None] - t_pos[:, None, :] < window
        mask = causal
    else:
        mask = None  # cross-attn: attend to all memory tokens
    q = q.reshape(B, S, cfg.num_kv_heads, G, cfg.resolved_head_dim)
    qc = cfg.attn_q_chunk
    if qc and S % qc == 0 and S > qc and kv_override is None:
        # §Perf hillclimb: chunk the queries and remat the chunk body so
        # neither forward nor backward ever materializes the (S, S) score
        # tensor.  The chunk is taken with dynamic_slice on the SEQ axis
        # (keeps the batch sharding intact — reshapes across batch made
        # GSPMD replicate, see EXPERIMENTS.md §Perf iter 3) and the causal
        # mask is a (qc, S) broadcast computed inside the body, never a
        # materialized (nq, B, qc, S) tensor.
        nq = S // qc
        col = jnp.arange(S)

        def chunk_body(q_blk, start):
            row = start + jnp.arange(qc)
            m2d = row[:, None] >= col[None, :]
            if window:
                m2d &= row[:, None] - col[None, :] < window
            return _sdpa(q_blk, k, v, m2d[None], cfg.attn_softcap)

        chunk_body = jax.checkpoint(chunk_body)

        def scan_body(_, start):
            q_blk = lax.dynamic_slice_in_dim(q, start, qc, axis=1)
            return None, chunk_body(q_blk, start)

        _, out_blocks = lax.scan(scan_body, None,
                                 jnp.arange(nq, dtype=jnp.int32) * qc)
        out = jnp.moveaxis(out_blocks, 0, 1).reshape(
            B, S, cfg.num_kv_heads, G, cfg.resolved_head_dim)
    else:
        out = _sdpa(q, k, v, mask, cfg.attn_softcap)
    out = out.reshape(B, S, cfg.num_heads, cfg.resolved_head_dim)
    y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"]).astype(x.dtype)
    if return_kv:
        return y, (k, v)
    return y


def kv_to_cache(cfg: ModelConfig, k: jax.Array, v: jax.Array, seq_len: int,
                cache_len: int, window: int = 0) -> Params:
    """Convert full-sequence k/v (B,S,kv,hd) into the decode cache layout of
    capacity ``cache_len``.  Local layers keep the last ``window`` entries
    ring-ordered so ``slot = pos % window`` holds position ``pos`` (matches
    attention_decode's ring addressing)."""
    dt = dtype_of(cfg)
    if window:
        L_cap = min(window, cache_len)
        if seq_len >= L_cap:
            k_last, v_last = k[:, -L_cap:], v[:, -L_cap:]
            shift = seq_len % L_cap
            return {"k": jnp.roll(k_last, shift, axis=1).astype(dt),
                    "v": jnp.roll(v_last, shift, axis=1).astype(dt)}
        pad = L_cap - seq_len
        # positions 0..S-1 land at slots 0..S-1 (pos % L_cap = pos)
        return {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))
                             ).astype(dt),
                "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))
                             ).astype(dt)}
    pad = cache_len - seq_len
    assert pad >= 0, f"cache_len {cache_len} < prompt {seq_len}"
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {"k": k.astype(dt), "v": v.astype(dt)}


def init_attn_cache(cfg: ModelConfig, batch: int, cache_len: int,
                    window: int = 0, dtype=None) -> Params:
    """KV cache for one attention layer.  Local layers ring-buffer to
    ``window`` entries; global layers keep ``cache_len``."""
    dt = dtype or dtype_of(cfg)
    L = min(window, cache_len) if window else cache_len
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, L, cfg.num_kv_heads, hd), dt),
        "v": jnp.zeros((batch, L, cfg.num_kv_heads, hd), dt),
    }


def attention_decode(p: Params, cfg: ModelConfig, x: jax.Array,
                     cache: Params, pos: jax.Array, *, window: int = 0,
                     kv_override: jax.Array | None = None
                     ) -> tuple[jax.Array, Params]:
    """One-token decode.  x: (B,1,D); pos: scalar int32 OR per-sequence
    (B,) vector (continuous batching: ragged slot positions)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    G = cfg.num_heads // cfg.num_kv_heads
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    if kv_override is not None:
        k = jnp.einsum("btd,dnh->btnh", kv_override, p["wk"])
        v = jnp.einsum("btd,dnh->btnh", kv_override, p["wv"])
        q = q.reshape(B, 1, cfg.num_kv_heads, G, hd)
        out = _sdpa(q, k, v, None, cfg.attn_softcap)
        out = out.reshape(B, 1, cfg.num_heads, hd)
        return jnp.einsum("bsnh,nhd->bsd", out, p["wo"]).astype(x.dtype), cache

    pos_vec = jnp.broadcast_to(pos, (B,)) if pos.ndim == 0 else pos
    posb = pos_vec[:, None]                                   # (B, 1)
    q = rope(q, posb, cfg.rope_theta)
    k_new = jnp.einsum("bsd,dnh->bsnh", x, p["wk"])
    v_new = jnp.einsum("bsd,dnh->bsnh", x, p["wv"])
    k_new = rope(k_new, posb, cfg.rope_theta)

    L = cache["k"].shape[1]
    slot_vec = (pos_vec % L) if window else pos_vec           # (B,)
    rows = jnp.arange(B)
    k_cache = cache["k"].at[rows, slot_vec].set(
        k_new[:, 0].astype(cache["k"].dtype), mode="drop")
    v_cache = cache["v"].at[rows, slot_vec].set(
        v_new[:, 0].astype(cache["v"].dtype), mode="drop")
    idx = jnp.arange(L)
    if window:
        # ring buffer: entry at idx holds absolute position p satisfying
        # p % L == idx and pos - L < p <= pos
        abs_pos = pos_vec[:, None] - ((pos_vec[:, None] - idx[None, :]) % L)
        valid = (abs_pos >= 0) & (abs_pos <= pos_vec[:, None])  # (B, L)
    else:
        valid = idx[None, :] <= pos_vec[:, None]                # (B, L)
    mask = valid[:, None, :]
    q = q.reshape(B, 1, cfg.num_kv_heads, G, hd)
    out = _sdpa(q, k_cache, v_cache, mask, cfg.attn_softcap)
    out = out.reshape(B, 1, cfg.num_heads, hd)
    y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"]).astype(x.dtype)
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    dt = dtype_of(cfg)
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": _dense_init(k1, (cfg.d_model, d_ff), dt),
        "wi_up": _dense_init(k2, (cfg.d_model, d_ff), dt),
        "wo": _dense_init(k3, (d_ff, cfg.d_model), dt),
    }


def mlp_fwd(p: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])
    return (h @ p["wo"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE (top-k router, capacity dispatch via scatter/gather)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig) -> Params:
    dt = dtype_of(cfg)
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": _dense_init(k1, (D, E), jnp.float32),
        "wi_gate": _dense_init(k2, (E, D, F), dt),
        "wi_up": _dense_init(k3, (E, D, F), dt),
        "wo": _dense_init(k4, (E, F, D), dt),
    }


def moe_fwd(p: Params, cfg: ModelConfig, x: jax.Array
            ) -> tuple[jax.Array, jax.Array]:
    """Returns (output, aux_load_balance_loss).  x: (B,S,D)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, D)
    logits = (xt.astype(jnp.float32) @ p["router"])            # (T, E)
    weights, sel = lax.top_k(jax.nn.softmax(logits, axis=-1), K)  # (T, K)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # load-balance aux loss (Switch-style)
    probs_mean = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)   # (E,)
    counts = jnp.zeros((E,), jnp.float32).at[sel.reshape(-1)].add(1.0)
    frac = counts / (T * K)
    aux = E * jnp.sum(frac * probs_mean)

    # capacity-based dispatch: slot index = rank of the token within its
    # expert's queue.  Computed by stable sort + histogram (§Perf H3 iter 2:
    # the textbook cumsum over the (T*K, E) one-hot costs O(T*K*E) — 1.7e15
    # FLOPs/device for kimi-k2, 586x the expert matmuls themselves; the
    # sort-based ranking is O(T*K log T*K) and numerically identical).
    cap = max(1, int(T * K / E * cfg.moe_capacity_factor))
    flat_sel = sel.reshape(-1)                                  # (T*K,)
    tk = flat_sel.shape[0]
    order = jnp.argsort(flat_sel, stable=True)
    counts_i = jnp.zeros((E,), jnp.int32).at[flat_sel].add(1)
    starts = jnp.cumsum(counts_i) - counts_i                    # (E,)
    ranks_sorted = jnp.arange(tk, dtype=jnp.int32) \
        - starts[flat_sel[order]]
    flat_slot = jnp.zeros((tk,), jnp.int32).at[order].set(ranks_sorted)
    keep = flat_slot < cap

    src = jnp.repeat(xt, K, axis=0)                             # (T*K, D)
    expert_in = jnp.zeros((E, cap, D), x.dtype)
    expert_in = expert_in.at[
        jnp.where(keep, flat_sel, E - 1),
        jnp.where(keep, flat_slot, cap - 1)].add(
            jnp.where(keep[:, None], src, 0).astype(x.dtype),
            mode="drop")
    expert_in = constrain_expert(expert_in)

    h = jnp.einsum("ecd,edf->ecf", expert_in, p["wi_gate"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", expert_in, p["wi_up"])
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["wo"])         # (E, cap, D)
    expert_out = constrain_expert(expert_out)

    gathered = expert_out[flat_sel, flat_slot]                  # (T*K, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    combined = (gathered.reshape(T, K, D)
                * weights[..., None].astype(x.dtype)).sum(axis=1)
    return combined.reshape(B, S, D).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Mamba2 / SSD mixer
# ---------------------------------------------------------------------------

def _ssm_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state


CONV_W = 4  # causal short-conv width


def init_mamba(key, cfg: ModelConfig) -> Params:
    dt = dtype_of(cfg)
    D = cfg.d_model
    d_inner, H, N = _ssm_dims(cfg)
    conv_dim = d_inner + 2 * N
    ks = jax.random.split(key, 8)
    common = {
        "A_log": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_norm": init_norm(cfg, d_inner),
        "out_proj": _dense_init(ks[2], (d_inner, D), dt),
    }
    if cfg.mamba_split_proj:
        # §Perf variant: one weight matrix per stream.  Mathematically
        # identical to the fused in_proj, but every projection output is
        # cleanly sharded — the fused layout forces jnp.split at
        # shard-misaligned offsets, which GSPMD can only resolve by full
        # rematerialization (the mamba2 collective-term pathology).
        return common | {
            "w_z": _dense_init(ks[0], (D, d_inner), dt),
            "w_x": _dense_init(ks[3], (D, d_inner), dt),
            "w_B": _dense_init(ks[4], (D, N), dt),
            "w_C": _dense_init(ks[5], (D, N), dt),
            "w_dt": _dense_init(ks[6], (D, H), dt),
            "conv_x": _dense_init(ks[1], (CONV_W, d_inner), dt, scale=0.5),
            "conv_B": _dense_init(ks[7], (CONV_W, N), dt, scale=0.5),
            "conv_C": _dense_init(jax.random.fold_in(ks[7], 1),
                                  (CONV_W, N), dt, scale=0.5),
        }
    return common | {
        "in_proj": _dense_init(ks[0], (D, 2 * d_inner + 2 * N + H), dt),
        "conv_w": _dense_init(ks[1], (CONV_W, conv_dim), dt, scale=0.5),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B,S,C), w (CONV_W, C)."""
    S = x.shape[1]
    x_pad = jnp.pad(x, ((0, 0), (CONV_W - 1, 0), (0, 0)))
    return sum(x_pad[:, i:i + S, :] * w[i][None, None, :]
               for i in range(CONV_W))


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., Q) log-decays -> (..., Q, Q) lower-tri cumulative sums."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh, dt_h, a_log, Bm, Cm, chunk: int,
                h0: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan (Mamba2, arXiv:2405.21060 Sec. 6).

    xh: (B,S,H,P)  dt_h: (B,S,H)  a_log: (H,)  Bm/Cm: (B,S,N).
    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    a = -jnp.exp(a_log)                                     # (H,) negative
    da = (dt_h * a[None, None, :]).astype(jnp.float32)      # (B,S,H) log decay
    xw = xh * dt_h[..., None]                               # dt-weighted input

    # reshape into chunks
    def c(t):
        return t.reshape(Bsz, nc, chunk, *t.shape[2:])
    xw_c, da_c, B_c, C_c = c(xw), c(da), c(Bm), c(Cm)

    # intra-chunk (quadratic within chunk)
    L = jnp.exp(_segsum(jnp.moveaxis(da_c, -1, 2)))         # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", C_c, B_c,
                        preferred_element_type=jnp.float32)  # (B,nc,Q,Q)
    y_intra = jnp.einsum("bchqk,bcqk,bckhp->bcqhp", L, scores, xw_c,
                         preferred_element_type=jnp.float32)

    # chunk end-states
    cum = jnp.cumsum(da_c, axis=2)                          # (B,nc,Q,H)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,Q,H)
    states = jnp.einsum("bcqh,bcqn,bcqhp->bchpn", decay_to_end, B_c, xw_c,
                        preferred_element_type=jnp.float32)  # (B,nc,H,P,N)

    # inter-chunk recurrence over nc chunks
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (B,nc,H)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(h, inp):
        dec, st = inp                                       # (B,H), (B,H,P,N)
        h_new = h * dec[..., None, None] + st
        return h_new, h

    _, h_prev = lax.scan(step,
                         h0,
                         (jnp.moveaxis(chunk_decay, 1, 0),
                          jnp.moveaxis(states, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                     # (B,nc,H,P,N) state at chunk start
    final = (h_prev[:, -1] * chunk_decay[:, -1, :, None, None]
             + states[:, -1])

    # inter-chunk contribution
    decay_from_start = jnp.exp(cum)                         # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", C_c, h_prev,
                         decay_from_start,
                         preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, final


def mamba_fwd(p: Params, cfg: ModelConfig, x: jax.Array,
              return_cache: bool = False):
    """Full-sequence Mamba2 mixer.  x: (B,S,D).  With ``return_cache`` also
    returns the decode cache {ssm, conv} after consuming the sequence."""
    B, S, D = x.shape
    d_inner, H, N = _ssm_dims(cfg)
    if cfg.mamba_split_proj:
        z = x @ p["w_z"]
        xs_raw = x @ p["w_x"]
        B_raw = x @ p["w_B"]
        C_raw = x @ p["w_C"]
        dt_r = x @ p["w_dt"]
        xbc = jnp.concatenate([xs_raw, B_raw, C_raw], axis=-1)  # cache only
        xs = jax.nn.silu(_causal_conv(xs_raw, p["conv_x"]))
        Bm = jax.nn.silu(_causal_conv(B_raw, p["conv_B"]))
        Cm = jax.nn.silu(_causal_conv(C_raw, p["conv_C"]))
    else:
        zxbcdt = x @ p["in_proj"]
        z, xs, Bm, Cm, dt_r = jnp.split(
            zxbcdt,
            [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
            axis=-1)
        # causal short conv over (x, B, C)
        xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)
        conv = jax.nn.silu(_causal_conv(xbc, p["conv_w"]))
        xs, Bm, Cm = jnp.split(conv, [d_inner, d_inner + N], axis=-1)
    dt_h = jax.nn.softplus(dt_r.astype(jnp.float32) + p["dt_bias"])
    xh = xs.reshape(B, S, H, cfg.ssm_head_dim)
    pad = (-S) % cfg.ssm_chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_h = jnp.pad(dt_h, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, final_state = ssd_chunked(xh, dt_h, p["A_log"], Bm, Cm, cfg.ssm_chunk)
    y = y[:, :S]
    y = y + xh[:, :S] * p["D_skip"][None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = norm_fwd(p["out_norm"], y) * jax.nn.silu(z)
    out = (y @ p["out_proj"]).astype(x.dtype)
    if return_cache:
        # conv state: last CONV_W-1 *pre-conv* inputs (pre-silu xbc)
        conv_tail = xbc[:, -(CONV_W - 1):, :].astype(dtype_of(cfg))
        return out, {"ssm": final_state, "conv": conv_tail}
    return out


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=None) -> Params:
    d_inner, H, N = _ssm_dims(cfg)
    conv_dim = d_inner + 2 * N
    return {
        "ssm": jnp.zeros((batch, H, cfg.ssm_head_dim, N), jnp.float32),
        "conv": jnp.zeros((batch, CONV_W - 1, conv_dim),
                          dtype or dtype_of(cfg)),
    }


def mamba_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: Params
                 ) -> tuple[jax.Array, Params]:
    """Single-token recurrent update.  x: (B,1,D)."""
    B = x.shape[0]
    d_inner, H, N = _ssm_dims(cfg)
    if cfg.mamba_split_proj:
        xt = x[:, 0]
        z = xt @ p["w_z"]
        xbc = jnp.concatenate([xt @ p["w_x"], xt @ p["w_B"],
                               xt @ p["w_C"]], axis=-1)
        dt_r = xt @ p["w_dt"]
        conv_w = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]],
                                 axis=-1)
    else:
        zxbcdt = x[:, 0] @ p["in_proj"]
        z, xs, Bm, Cm, dt_r = jnp.split(
            zxbcdt,
            [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
            axis=-1)
        xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)          # (B, conv_dim)
        conv_w = p["conv_w"]
    conv_hist = jnp.concatenate([cache["conv"],
                                 xbc[:, None, :].astype(cache["conv"].dtype)],
                                axis=1)                       # (B, CONV_W, C)
    conv = jnp.einsum("bwc,wc->bc", conv_hist, conv_w)
    conv = jax.nn.silu(conv)
    xs, Bm, Cm = jnp.split(conv, [d_inner, d_inner + N], axis=-1)
    dt_h = jax.nn.softplus(dt_r.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt_h * a[None, :])                        # (B,H)
    xh = xs.reshape(B, H, cfg.ssm_head_dim).astype(jnp.float32)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt_h, Bm.astype(jnp.float32), xh)
    h = cache["ssm"] * decay[..., None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h)
    y = y + xh * p["D_skip"][None, :, None]
    y = y.reshape(B, d_inner).astype(x.dtype)
    y = norm_fwd(p["out_norm"], y) * jax.nn.silu(z)
    out = (y @ p["out_proj"]).astype(x.dtype)[:, None, :]
    return out, {"ssm": h, "conv": conv_hist[:, 1:, :]}
