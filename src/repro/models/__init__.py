from repro.models import layers, recsys, transformer

__all__ = ["layers", "recsys", "transformer"]
