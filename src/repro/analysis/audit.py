"""The audit matrix: every registered arch x every hot-path rule.

For each arch (reduced variant — same code paths, tractable trace sizes)
the auditor traces, never executes:

a. the layer-grouped **fused psum step** (``make_gba_fused_psum_step``)
   under a 4-worker :class:`jax.sharding.AbstractMesh` with the real LM
   loss -> GBA-COLL-001/002 (collective census vs ``group_table``) and
   GBA-DTYPE-002;
b. the same step with a **probe loss** whose sanctioned widening-convert
   count is exactly derivable (one forward ``astype(f32)`` + one
   ``ravel_group`` grad cast per non-f32 leaf) -> GBA-DTYPE-001.  The
   real LM loss has legitimate mixed-precision upcasts, so the upcast
   budget is only checkable on the probe;
c. the **sync psum step** (``make_gba_psum_step``) -> GBA-COLL-004;
d. the single-host **fused train step** lowered with the canonical
   ``donate_argnums=0`` -> GBA-DON-001, and traced twice with fresh
   same-shaped args -> GBA-RETRACE-001;
e. the **decode step** -> GBA-COLL-003, GBA-DTYPE-002, GBA-RETRACE-001;
f. the arch's ``gba_apply`` launch meta at its real sharded flat layout
   -> GBA-TILE-001 / GBA-VMEM-001/002 / GBA-GRID-001.

:func:`audit_kernels` covers the arch-independent kernels (streamed
embedding fwd/bwd, fused Adagrad, aggregate, flash decode) at their
bench shapes with the same Pallas rules.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.analysis import dataflow as DFL
from repro.analysis import jaxpr_audit as JA
from repro.analysis import pallas_check as PC
from repro.analysis import race_lint as RL
from repro.analysis import retrace_guard as RG
from repro.analysis.rules import Finding
from repro.configs import ARCH_IDS, get_config
from repro.configs.base import GBAConfig, InputShape
from repro.core.flat_sharded import ShardedFlatLayout
from repro.core.gba_shard_map import (make_gba_fused_psum_step,
                                      make_gba_psum_step)
from repro.launch.programs import (_loss_from_batch,
                                   init_fused_train_state,
                                   make_fused_train_step)
from repro.launch.steps import (_memory_len, abstract_cache,
                                abstract_params, make_decode_step,
                                model_inputs)
from repro.models import transformer as T
from repro.optim import get_optimizer

SDS = jax.ShapeDtypeStruct

AUDIT_M = 4            # workers / PS shards in the audited abstract mesh
AUDIT_SEQ = 16         # trace-only seq len (shapes don't change collectives)
AUDIT_IOTA = 4
AUDIT_LR = 1e-3


def abstract_mesh(m: int = AUDIT_M, axis: str = "data"):
    """Devices-free mesh: lets make_jaxpr trace shard_map'd steps at any
    worker count on a 1-CPU container."""
    from jax.sharding import AbstractMesh
    return AbstractMesh(((axis, m),))


def probe_loss(params, batch):
    """Loss with an exactly countable upcast budget: per non-f32 leaf,
    one widening ``astype`` here (forward) + one in ``ravel_group``
    (gradient) and nothing else."""
    sq = sum(jnp.sum(l.astype(jnp.float32) ** 2)
             for l in jax.tree.leaves(params))
    return jnp.mean(batch["x"]) * sq


def widening_budget(layout: ShardedFlatLayout) -> int:
    """Sanctioned widening-convert count of a probe-loss fused-step trace."""
    return 2 * sum(1 for dt in layout.dtypes
                   if jnp.dtype(dt) != jnp.float32)


def arch_layout(cfg, m: int = AUDIT_M) -> ShardedFlatLayout:
    """The arch's real layer-grouped flat layout at ``m`` PS shards,
    built from abstract params (no allocation)."""
    return ShardedFlatLayout.from_params(
        abstract_params(cfg), m, group_by=T.param_group_key)


def trace_fused_step(layout: ShardedFlatLayout, m: int, loss_fn,
                     batch, *, axis: str = "data", compress=None,
                     warm: bool = False):
    """Closed jaxpr of the layer-grouped fused psum step — the artifact
    every GBA-COLL/DTYPE rule (and the bench census columns) reads.
    With a lossy ``compress`` policy the step carries the per-worker
    wire state (residual/momentum), traced as abstract args."""
    step = make_gba_fused_psum_step(
        abstract_mesh(m, axis), loss_fn, layout, iota=AUDIT_IOTA,
        lr=AUDIT_LR, axis=axis, compress=compress, warm=warm)
    flat = SDS((layout.padded_total,), jnp.float32)
    if compress is None or not compress.stateful:
        return jax.make_jaxpr(step)(
            flat, flat, batch, SDS((m,), jnp.int32), SDS((), jnp.int32))
    wire = {name: SDS(shape, jnp.float32) for name, shape in
            layout.wire_state_shapes(m, compress.scheme).items()}
    return jax.make_jaxpr(step)(
        flat, flat, batch, SDS((m,), jnp.int32), SDS((), jnp.int32), wire)


@dataclass
class AuditReport:
    """One audited site group (an arch, or the global kernel set)."""

    name: str
    findings: list[Finding] = field(default_factory=list)
    suppressed: list[Finding] = field(default_factory=list)
    stats: dict = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.findings


def audit_arch(arch: str, *, m: int = AUDIT_M,
               reduced: bool = True) -> AuditReport:
    """Run the full rule matrix over one registered arch."""
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    rep = AuditReport(arch)
    pshapes = abstract_params(cfg)
    layout = arch_layout(cfg, m)
    def lm_loss(params, batch):
        return _loss_from_batch(params, cfg, batch)

    # a. fused psum step, real LM loss: collective schedule + f64 ban
    site = f"{arch}/fused_psum"
    batch = model_inputs(cfg, InputShape("audit", AUDIT_SEQ, m, "train"))
    jx = trace_fused_step(layout, m, lm_loss, batch)
    rep.findings += JA.check_fused_psum_schedule(jx, layout, m, site)
    rep.findings += JA.check_no_f64(jx, site)
    rep.findings += DFL.flow_fused_step(jx, batch, site=site)
    counts = JA.census_counts(JA.collective_census(jx))
    rep.stats.update(
        all_gather=counts.get("all_gather", 0),
        all_to_all=counts.get("all_to_all", 0),
        psum=counts.get("psum", 0),
        num_groups=layout.num_groups,
        shard_size=layout.shard_size,
        peak_gather_bytes=layout.peak_gather_bytes)

    # b. probe-loss trace: exact widening-convert budget
    probe_batch = {"x": SDS((m * 8,), jnp.float32)}
    jp = trace_fused_step(layout, m, probe_loss, probe_batch)
    rep.findings += JA.check_widening_budget(
        jp, widening_budget(layout), f"{arch}/fused_psum/probe")

    # g. compressed-wire traces (probe loss — COLL-005 only reads the
    # collective census): each lossy scheme's past-warmup jaxpr must
    # carry exactly the declared wire dtypes (no f32 leakage), psum
    # scalars only; the warmup-phase jaxpr must be the PR-5 f32 schedule
    from repro.core.compression import CompressionPolicy
    for scheme in ("int8", "onebit"):
        pol = CompressionPolicy(scheme=scheme, warmup_steps=1)
        site = f"{arch}/fused_psum/{scheme}"
        jc = trace_fused_step(layout, m, probe_loss, probe_batch,
                              compress=pol)
        rep.findings += JA.check_wire_dtypes(jc, layout, m, pol, site)
        rep.findings += JA.check_scalar_psum_only(jc, site)
        rep.findings += JA.check_no_f64(jc, site)
        wire = {name: SDS(shape, jnp.float32) for name, shape in
                layout.wire_state_shapes(m, scheme).items()}
        rep.findings += DFL.flow_fused_step(jc, probe_batch, site=site,
                                            wire=wire)
        if scheme == "int8":
            ccounts = JA.census_counts(JA.collective_census(jc))
            rep.stats.update(
                wire_dtype=pol.wire_dtype(),
                wire_bytes=pol.wire_bytes(layout),
                compression_ratio=round(pol.compression_ratio(layout), 4),
                compressed_all_to_all=ccounts.get("all_to_all", 0))
            jw = trace_fused_step(layout, m, probe_loss, probe_batch,
                                  compress=pol, warm=True)
            wsite = f"{arch}/fused_psum/warmup"
            rep.findings += JA.check_wire_dtypes(jw, layout, m, pol,
                                                 wsite, warm=True)
            rep.findings += JA.check_fused_psum_schedule(jw, layout, m,
                                                         wsite)

    # c. sync psum step: per-leaf grads + scalar loss, nothing else
    opt = get_optimizer("adagrad", AUDIT_LR)
    sync = make_gba_psum_step(abstract_mesh(m), probe_loss, opt, AUDIT_IOTA)
    jsync = jax.make_jaxpr(sync)(
        pshapes, jax.eval_shape(opt.init, pshapes), probe_batch,
        SDS((m,), jnp.int32), SDS((), jnp.int32))
    rep.findings += JA.check_sync_psum_schedule(
        jsync, [l.shape for l in jax.tree.leaves(pshapes)],
        f"{arch}/sync_psum")
    rep.findings += DFL.flow_sync_step(
        jsync, pshapes, jax.eval_shape(opt.init, pshapes),
        site=f"{arch}/sync_psum")

    # d. fused train step: donation + retrace stability + the FLOW
    # taint pass (raw-grad sanitization, exact-zero tombstones, f32
    # master chain) — one .trace() feeds both the lowering and the
    # dataflow jaxpr
    site = f"{arch}/fused_train_step"
    params = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), pshapes)
    gba = GBAConfig(local_batch=2, buffer_size=m,
                    staleness_tolerance=AUDIT_IOTA)
    flat_layout, state = init_fused_train_state(params, gba)
    step = make_fused_train_step(cfg, gba, flat_layout)
    tbatch = model_inputs(cfg, InputShape("audit", AUDIT_SEQ, 2, "train"))
    tok = SDS((), jnp.int32)
    traced = jax.jit(step, donate_argnums=0).trace(state, tbatch, tok)
    # args_info is ((args...), kwargs); the state is positional arg 0
    rep.findings += JA.check_donation(traced.lower().args_info[0][0], site)
    rep.findings += DFL.flow_fused_train_step(
        traced.jaxpr, state, site=site, m=m, iota=AUDIT_IOTA)
    state_sds = jax.tree.map(lambda x: SDS(x.shape, x.dtype), state)
    rep.findings += RG.check_retrace(
        step, lambda: ((state_sds, tbatch, tok), {}), site)

    # d2. pytree train step (build_programs mode="pytree"): the same
    # Eq. (1) contract holds leaf-by-leaf, tombstone and fresh tokens
    # taint-checked on one trace
    site = f"{arch}/pytree_step"
    from repro.launch.programs import (ARCH_ACC_DTYPE, ARCH_OPTIMIZER,
                                       init_train_state, make_train_step)
    popt = get_optimizer(ARCH_OPTIMIZER.get(cfg.name, "adam"), AUDIT_LR)
    pstate = jax.eval_shape(
        lambda p: init_train_state(
            p, popt, ARCH_ACC_DTYPE.get(cfg.name, jnp.float32)), pshapes)
    pstep = make_train_step(cfg, popt, gba)
    jpt = jax.make_jaxpr(pstep)(pstate, tbatch, tok)
    rep.findings += DFL.flow_pytree_step(jpt, pstate, site=site,
                                         iota=AUDIT_IOTA)

    # e. decode step: no collectives, no f64, no retrace
    site = f"{arch}/decode"
    dec = make_decode_step(cfg)
    cache = abstract_cache(cfg, 2, 64, _memory_len(cfg))
    dtok = model_inputs(
        cfg, InputShape("audit", 64, 2, "decode"))["tokens"]
    jdec = jax.make_jaxpr(dec)(pshapes, dtok, cache)
    rep.findings += JA.check_no_collectives(jdec, site)
    rep.findings += JA.check_no_f64(jdec, site)
    rep.findings += RG.check_retrace(
        dec, lambda: ((pshapes, dtok, cache), {}), site)

    # f. the arch's own gba_apply launch at its real shard geometry
    from repro.kernels import gba_apply
    meta = gba_apply.launch_meta(layout.shard_size, m)
    rep.findings += PC.check_launch(meta, f"{arch}/kernels/gba_apply")
    rep.stats["apply_vmem_bytes"] = meta.vmem_bytes(meta.vmem_counted)
    return rep


def kernel_metas():
    """Arch-independent kernel launches at their bench shapes."""
    from repro.kernels import (embedding_bag, flash_decode, fused_adagrad,
                               gba_aggregate, quantize)
    return (
        fused_adagrad.launch_meta(1 << 16),
        gba_aggregate.launch_meta(1 << 16, 8),
        embedding_bag.fwd_launch_meta(32, 26, 100_000, 128),
        embedding_bag.bwd_launch_meta(32, 26, 100_000, 128),
        flash_decode.launch_meta(4, 32_768, 8, 4, 128),
        quantize.quantize_launch_meta(8, 1 << 14, 2048, "minmax"),
        quantize.quantize_launch_meta(8, 1 << 14, 2048, "sign"),
        quantize.dequant_launch_meta(8, 1 << 14, 2048, "minmax"),
        quantize.dequant_launch_meta(8, 1 << 14, 2048, "sign"),
    )


def audit_kernels() -> AuditReport:
    rep = AuditReport("kernels")
    for meta in kernel_metas():
        rep.findings += PC.check_launch(meta, f"kernels/{meta.kernel}")
        rep.stats[f"{meta.kernel}_vmem_bytes"] = meta.total_vmem_bytes()
    return rep


def audit_dataflow() -> AuditReport:
    """Arch-independent dataflow sites: the Alg. 2 aggregate's masked
    divisor (GBA-FLOW-005)."""
    rep = AuditReport("dataflow")
    rep.findings += DFL.flow_aggregate_embedding(
        site="dataflow/aggregate_embedding")
    return rep


def audit_serving() -> AuditReport:
    """GBA-RACE lock-discipline lint over the serving modules + the
    hot-ID cache (see ``race_lint.DEFAULT_MODULES``)."""
    rep = AuditReport("serving")
    findings, stats = RL.lint_default()
    rep.findings += findings
    rep.stats.update(stats)
    return rep


def run_audit(archs=None, *, m: int = AUDIT_M,
              suppressions=()) -> list[AuditReport]:
    """Audit every requested arch plus the global kernel set, the
    dataflow sites, and the serving race lint, applying ``RULE`` /
    ``RULE@site`` suppressions."""
    from repro.analysis.rules import apply_suppressions, parse_suppressions
    sup = parse_suppressions(suppressions)
    reports = [audit_arch(a, m=m) for a in (archs or ARCH_IDS)]
    reports.append(audit_kernels())
    reports.append(audit_dataflow())
    reports.append(audit_serving())
    for rep in reports:
        rep.findings, dropped = apply_suppressions(rep.findings, sup)
        rep.suppressed += dropped
    return reports
