"""GBA-RACE: AST lock-discipline lint for the serving-thread modules.

The PR-9 serving path runs a daemon sync thread (``LiveSource._loop``)
and listener callbacks (``add_listener``) against engine code running on
the request thread.  The shipped concurrency contract is:

* shared mutable state is written under the instance lock, **or**
  published as a single plain assignment of an immutable snapshot
  (``self._snap = Snapshot(...)``) that readers grab with ONE attribute
  read;
* a consistent multi-field view (e.g. version+step) is only obtainable
  under the lock;
* listener callbacks are invoked with NO lock held.

This lint proves the contract per class, with inherited methods merged
in (``LiveSource`` inherits ``ParamSource._notify``):

* **RACE-001** an attribute that is lock-guarded anywhere in its class
  (written at least once under a lock), or in-place-mutated by a
  sync-thread-reachable method, is mutated somewhere WITHOUT the lock.
  A plain attribute rebind of a never-in-place-mutated attr is blessed
  as a snapshot swap.
* **RACE-002** a method outside the sync set reads >= 2 distinct
  lock-guarded attributes outside the lock — it can observe a torn
  pair.  Reads of guarded attrs of *other* analyzed classes through a
  typed attribute (``self.channel.last_step`` where
  ``channel: UpdateChannel``) count toward the pair.  A single unlocked
  guarded read (the snapshot idiom) is blessed.
* **RACE-003** a notifier (a method that calls stored listener
  callables, transitively) is reached from inside a ``with self._lock:``
  region — shared state escapes through the callback while the lock is
  held.

Thread entries are found structurally: ``threading.Thread(target=
self.M)`` and ``<anything>.add_listener(self.M)``.  The sync set is the
self-call closure of the entries.  ``__init__`` is construction-time
and exempt from access accounting.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path

from repro.analysis.rules import Finding, finding

DEFAULT_MODULES = (
    "serving/config.py",
    "serving/sources.py",
    "serving/engine.py",
    "serving/recsys.py",
    "embeddings/hot_cache.py",
)

_LOCK_CTORS = {"Lock", "RLock"}
_MUTATORS = {"append", "extend", "insert", "remove", "pop", "clear",
             "add", "discard", "update", "setdefault", "popitem",
             "appendleft", "popleft", "sort", "reverse"}


@dataclass
class Access:
    attr: str
    kind: str            # "read" | "write" | "mutate"
    locked: bool
    lineno: int
    via: str | None = None   # typed-attr chain: access to other_class.attr


@dataclass
class ClassInfo:
    name: str
    module: str
    methods: dict = field(default_factory=dict)     # name -> FunctionDef
    lock_attrs: set = field(default_factory=set)
    attr_types: dict = field(default_factory=dict)  # attr -> class name
    entries: set = field(default_factory=set)       # thread-entry methods
    calls: dict = field(default_factory=dict)       # method -> {self-calls}
    accesses: dict = field(default_factory=dict)    # method -> [Access]
    notify_roots: set = field(default_factory=set)  # direct callback callers
    locked_calls: dict = field(default_factory=dict)  # method -> {self-calls
    #                                                    made under a lock}
    locked_regions: int = 0
    bases: list = field(default_factory=list)

    def site(self, method: str) -> str:
        return f"serving/{self.module}:{self.name}.{method}"


def _is_self_attr(node) -> bool:
    return (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self")


def _self_attr_chain(node):
    """``self.a.b`` -> ("a", "b"); ``self.a`` -> ("a", None); else None."""
    if _is_self_attr(node):
        return node.attr, None
    if (isinstance(node, ast.Attribute) and _is_self_attr(node.value)):
        return node.value.attr, node.attr
    return None


def _call_name(node):
    """Callee name of a Call: ``threading.Thread`` -> "Thread",
    ``Lock()`` -> "Lock"."""
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return None


class _MethodScan(ast.NodeVisitor):
    """Collect accesses / self-calls / callback invocations of one
    method, tracking lexical ``with self.<lock>:`` depth."""

    def __init__(self, info: ClassInfo, method: str):
        self.info = info
        self.method = method
        self.depth = 0
        self.accesses: list[Access] = []
        self.calls: set = set()
        self.locked_calls: set = set()
        self.callback_vars: set = set()
        self.calls_callback = False
        self._store_ctx: list = []

    # -- lock regions ---------------------------------------------------

    def visit_With(self, node):
        lock_items = sum(
            1 for item in node.items
            if (chain := _self_attr_chain(item.context_expr)) is not None
            and chain[1] is None and chain[0] in self.info.lock_attrs)
        for item in node.items:
            self.visit(item.context_expr)
        if lock_items:
            self.info.locked_regions += 1
        self.depth += lock_items
        for stmt in node.body:
            self.visit(stmt)
        self.depth -= lock_items

    # -- stores / mutations ---------------------------------------------

    def _record(self, attr, kind, lineno, via=None):
        self.accesses.append(Access(attr, kind, self.depth > 0, lineno,
                                    via))

    def visit_Assign(self, node):
        self.visit(node.value)
        for tgt in node.targets:
            self._store(tgt, node)

    def visit_AnnAssign(self, node):
        if node.value is not None:
            self.visit(node.value)
            self._store(node.target, node)

    def visit_AugAssign(self, node):
        self.visit(node.value)
        chain = _self_attr_chain(node.target)
        if chain and chain[1] is None:
            self._record(chain[0], "mutate", node.lineno)

    def _store(self, tgt, node):
        if (chain := _self_attr_chain(tgt)) is not None:
            attr, sub = chain
            if sub is None:
                self._record(attr, "write", node.lineno)
            else:
                self._record(attr, "mutate", node.lineno)  # self.a.b = ...
        elif isinstance(tgt, ast.Subscript):
            if (chain := _self_attr_chain(tgt.value)) is not None \
                    and chain[1] is None:
                self._record(chain[0], "mutate", node.lineno)
            else:
                self.visit(tgt.value)
            self.visit(tgt.slice)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for el in tgt.elts:
                self._store(el, node)

    def visit_Delete(self, node):
        for tgt in node.targets:
            base = tgt.value if isinstance(tgt, ast.Subscript) else tgt
            if (chain := _self_attr_chain(base)) is not None:
                self._record(chain[0], "mutate", node.lineno)

    # -- calls / reads ----------------------------------------------------

    def visit_Call(self, node):
        name = _call_name(node)
        fn = node.func

        # self.method(...) — a self-call, not an attribute read
        if _is_self_attr(fn):
            if fn.attr in self.info.methods:
                self.calls.add(fn.attr)
                if self.depth > 0:
                    self.locked_calls.add(fn.attr)
            else:
                self._record(fn.attr, "read", node.lineno)
        # self.a.b(...): mutator methods mutate self.a; others read it
        elif (isinstance(fn, ast.Attribute)
              and (chain := _self_attr_chain(fn.value)) is not None
              and chain[1] is None):
            kind = "mutate" if fn.attr in _MUTATORS else "read"
            self._record(chain[0], kind, node.lineno)
        # loop_var(...) where loop_var came from iterating stored state
        elif isinstance(fn, ast.Name) and fn.id in self.callback_vars:
            self.calls_callback = True
        else:
            self.visit(fn)

        for a in node.args:
            self.visit(a)
        for kw in node.keywords:
            self.visit(kw.value)

        # thread entries: Thread(target=self.M) / x.add_listener(self.M)
        entry_args = []
        if name == "Thread":
            entry_args = [kw.value for kw in node.keywords
                          if kw.arg == "target"]
        elif name == "add_listener":
            entry_args = list(node.args)
        for a in entry_args:
            if _is_self_attr(a) and a.attr in self.info.methods:
                self.info.entries.add(a.attr)

    def visit_For(self, node):
        # ``for fn in self._listeners: fn(...)`` — fn is a stored callable
        src = node.iter
        chain = None
        if isinstance(src, ast.Call) and _call_name(src) in (
                "list", "tuple", "getattr"):
            # getattr(self, "_listeners", []) names the attr as a string
            if (_call_name(src) == "getattr" and len(src.args) >= 2
                    and isinstance(src.args[0], ast.Name)
                    and src.args[0].id == "self"
                    and isinstance(src.args[1], ast.Constant)
                    and isinstance(src.args[1].value, str)):
                chain = (src.args[1].value, None)
            else:
                for a in src.args:
                    if (c := _self_attr_chain(a)) is not None \
                            and c[1] is None:
                        chain = c
                        break
        elif (c := _self_attr_chain(src)) is not None and c[1] is None:
            chain = c
        if chain is not None and isinstance(node.target, ast.Name):
            self.callback_vars.add(node.target.id)
        self.generic_visit(node)

    def visit_Attribute(self, node):
        if isinstance(node.ctx, ast.Load):
            if _is_self_attr(node):
                self._record(node.attr, "read", node.lineno)
                return
            if (chain := _self_attr_chain(node)) is not None:
                attr, sub = chain
                self._record(attr, "read", node.lineno, via=sub)
                return
        self.generic_visit(node)


def _scan_class(node: ast.ClassDef, module: str,
                base_methods: dict | None = None) -> ClassInfo:
    info = ClassInfo(name=node.name, module=module)
    if base_methods:
        info.methods.update(base_methods)   # inherited, overridable
    for item in node.body:
        if isinstance(item, ast.FunctionDef):
            info.methods[item.name] = item

    # pass 0: lock attrs + typed attrs, from any method body
    for meth in info.methods.values():
        for sub in ast.walk(meth):
            if not isinstance(sub, ast.Assign):
                continue
            for tgt in sub.targets:
                chain = _self_attr_chain(tgt)
                if chain is None or chain[1] is not None:
                    continue
                attr = chain[0]
                v = sub.value
                if isinstance(v, ast.Call):
                    cname = _call_name(v)
                    if cname in _LOCK_CTORS:
                        info.lock_attrs.add(attr)
                    elif cname:
                        info.attr_types.setdefault(attr, cname)
                elif isinstance(v, ast.IfExp):
                    for arm in (v.body, v.orelse):
                        if isinstance(arm, ast.Call) \
                                and (cn := _call_name(arm)):
                            info.attr_types.setdefault(attr, cn)
                elif isinstance(v, ast.Name):
                    info.attr_types.setdefault(attr, f"${v.id}")
        # constructor params annotated with a class type
        if meth.name == "__init__":
            for arg in meth.args.args + meth.args.kwonlyargs:
                ann = arg.annotation
                tname = None
                if isinstance(ann, ast.Name):
                    tname = ann.id
                elif isinstance(ann, ast.Constant) \
                        and isinstance(ann.value, str):
                    tname = ann.value
                if tname:
                    for sub in ast.walk(meth):
                        if (isinstance(sub, ast.Assign)
                                and isinstance(sub.value, ast.Name)
                                and sub.value.id == arg.arg):
                            for tgt in sub.targets:
                                c = _self_attr_chain(tgt)
                                if c and c[1] is None:
                                    info.attr_types[c[0]] = tname

    # pass 1: per-method accesses / calls
    for name, meth in info.methods.items():
        scan = _MethodScan(info, name)
        for stmt in meth.body:
            scan.visit(stmt)
        info.accesses[name] = scan.accesses
        info.calls[name] = scan.calls
        info.locked_calls[name] = scan.locked_calls
        if scan.calls_callback:
            info.notify_roots.add(name)
    return info


def _closure(seeds, edges) -> set:
    out = set(seeds)
    frontier = list(seeds)
    while frontier:
        m = frontier.pop()
        for callee in edges.get(m, ()):
            if callee not in out:
                out.add(callee)
                frontier.append(callee)
    return out


def analyze_classes(sources: dict) -> dict:
    """``{module_name: source_text}`` -> ``{class_name: ClassInfo}``.
    Name-based inheritance: a subclass of another analyzed class is
    scanned with the base's method ASTs merged in, so inherited methods
    (``LiveSource._notify``) participate in the sync-reachability and
    notifier analyses of the subclass."""
    raw: dict[str, tuple] = {}
    for module, src in sources.items():
        tree = ast.parse(src)
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                raw[node.name] = (node, module)

    def methods_of(name, seen=()):
        if name not in raw or name in seen:
            return {}
        node, _ = raw[name]
        merged: dict = {}
        for b in node.bases:
            if isinstance(b, ast.Name):
                merged.update(methods_of(b.id, seen + (name,)))
        merged.update({item.name: item for item in node.body
                       if isinstance(item, ast.FunctionDef)})
        return merged

    classes: dict[str, ClassInfo] = {}
    for name, (node, module) in raw.items():
        base_methods = {}
        for b in node.bases:
            if isinstance(b, ast.Name):
                base_methods.update(methods_of(b.id))
        info = _scan_class(node, module, base_methods)
        info.bases = [b.id for b in node.bases if isinstance(b, ast.Name)]
        classes[name] = info
    return classes


def _guarded_attrs(info: ClassInfo) -> set:
    """Attrs with at least one locked write/mutate anywhere in the
    class (outside __init__ the lock is the only sanctioned writer)."""
    out = set()
    for name, accs in info.accesses.items():
        for a in accs:
            if a.kind in ("write", "mutate") and a.locked:
                out.add(a.attr)
    return out - info.lock_attrs


def lint_classes(classes: dict) -> list[Finding]:
    findings: list[Finding] = []
    for info in classes.values():
        sync = _closure(info.entries, info.calls)
        guarded = _guarded_attrs(info)
        # attrs ever mutated in place (not a plain snapshot rebind)
        inplace = {a.attr for name, accs in info.accesses.items()
                   for a in accs
                   if a.kind == "mutate" and name != "__init__"}

        # RACE-001: unlocked mutation of guarded / sync-shared state
        for name, accs in info.accesses.items():
            if name == "__init__":
                continue
            for a in accs:
                if a.locked or a.kind == "read":
                    continue
                shared = a.attr in guarded or (
                    name in sync and a.attr in inplace)
                blessed_swap = (a.kind == "write"
                                and a.attr not in inplace
                                and a.attr not in guarded)
                if shared and not blessed_swap:
                    findings.append(finding(
                        "GBA-RACE-001", info.site(name),
                        f"'{a.attr}' is lock-guarded elsewhere in "
                        f"{info.name} but {a.kind}d here (line "
                        f"{a.lineno}) without the lock"))

        # RACE-002: torn multi-attribute unlocked reads
        for name, accs in info.accesses.items():
            if name == "__init__" or name in sync:
                continue
            torn: dict[str, int] = {}
            for a in accs:
                if a.locked or a.kind != "read":
                    continue
                if a.attr in guarded:
                    # a chained self.a.b read still reads guarded self.a
                    torn.setdefault(a.attr, a.lineno)
                elif a.via is not None:
                    tname = info.attr_types.get(a.attr)
                    other = classes.get(tname) if tname else None
                    if other is not None and a.via in _guarded_attrs(other):
                        torn.setdefault(f"{a.attr}.{a.via}", a.lineno)
            if len(torn) >= 2:
                findings.append(finding(
                    "GBA-RACE-002", info.site(name),
                    f"reads {sorted(torn)} outside the lock — the pair "
                    f"can be torn by a concurrent sync (first reads at "
                    f"lines {sorted(torn.values())})"))

        # RACE-003: callback invoked while holding the lock.  A method
        # reaches-notify if its self-call chain ends in a notify root.
        reaches_notify = set(info.notify_roots)
        changed = True
        while changed:
            changed = False
            for m, callees in info.calls.items():
                if m not in reaches_notify and callees & reaches_notify:
                    reaches_notify.add(m)
                    changed = True
        for name, locked_callees in info.locked_calls.items():
            hot = locked_callees & reaches_notify
            if hot:
                findings.append(finding(
                    "GBA-RACE-003", info.site(name),
                    f"calls {sorted(hot)} (which invokes stored listener "
                    f"callbacks) while holding the lock — callbacks must "
                    f"run lock-free"))
    return findings


def lint_sources(sources: dict) -> tuple[list[Finding], dict]:
    """``{module: source}`` -> (findings, stats)."""
    classes = analyze_classes(sources)
    findings = lint_classes(classes)
    stats = {
        "race_classes": len(classes),
        "race_entries": sum(len(c.entries) for c in classes.values()),
        "race_guarded_attrs": sum(len(_guarded_attrs(c))
                                  for c in classes.values()),
        "race_locked_regions": sum(c.locked_regions
                                   for c in classes.values()),
    }
    return findings, stats


def lint_default() -> tuple[list[Finding], dict]:
    """Lint the shipped serving modules + the hot-ID cache."""
    import repro
    root = Path(next(iter(repro.__path__)))
    sources = {Path(rel).stem: (root / rel).read_text()
               for rel in DEFAULT_MODULES}
    return lint_sources(sources)
