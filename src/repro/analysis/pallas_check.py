"""Pallas rule family: tile alignment, VMEM budgets, index-map bounds.

Operates purely on the static :class:`repro.kernels.launch_meta.LaunchMeta`
each kernel exports (and, for the 1-D kernels, builds its real specs
from) — nothing is compiled or executed, so these checks run in the CPU
container even though Mosaic tile legality is a real-TPU property.

Calibration notes (what the rules deliberately allow):

* an axis whose block covers the whole (padded) array axis is exempt
  from tile alignment — Mosaic pads untiled axes internally (e.g. the
  narrow-table ``BLOCK_D=16`` embedding tiles, ``flash_decode``'s
  (KV, G) trailing dims).  Only genuinely TILED axes must align.
* only the last two block dims carry tiling constraints (lane = 128,
  sublane = per-dtype min from the TPU packing table); higher dims are
  unconstrained.
* scratch buffers are counted for VMEM residency but not tile-checked
  (they are kernel-internal layout, legal for Mosaic to pad).
"""
from __future__ import annotations

import itertools
import math

from repro.analysis.rules import Finding, finding
from repro.kernels.launch_meta import VMEM as VMEM_SPACE, LaunchMeta

# per-dtype min sublane count by itemsize (f32 -> (8, 128),
# bf16 -> (16, 128), int8/fp8 -> (32, 128)); lane dim is always 128
MIN_SUBLANE = {8: 8, 4: 8, 2: 16, 1: 32}
LANE = 128
VMEM_BUDGET_BYTES = 16 * 2 ** 20       # per-core VMEM (v4/v5 ~16MiB)


def check_tiles(meta: LaunchMeta, site: str) -> list[Finding]:
    """GBA-TILE-001 over every VMEM in/out block."""
    findings = []
    for bm in meta.inputs + meta.outputs:
        if bm.memory_space != VMEM_SPACE or bm.block is None:
            continue
        block, array = bm.block, bm.array_shape
        # lane (last) dim
        if block[-1] != array[-1] and block[-1] % LANE:
            findings.append(finding(
                "GBA-TILE-001", site,
                f"{meta.kernel}/{bm.name}: tiled lane dim {block[-1]} "
                f"not a multiple of {LANE} (block {block}, "
                f"array {array})"))
        # sublane (second-to-last) dim
        if len(block) >= 2:
            sub_min = MIN_SUBLANE[bm.itemsize]
            if block[-2] != array[-2] and block[-2] % sub_min:
                findings.append(finding(
                    "GBA-TILE-001", site,
                    f"{meta.kernel}/{bm.name}: tiled sublane dim "
                    f"{block[-2]} not a multiple of {sub_min} "
                    f"({bm.dtype} min tile; block {block}, "
                    f"array {array})"))
    return findings


def check_vmem(meta: LaunchMeta, site: str,
               budget: int = VMEM_BUDGET_BYTES) -> list[Finding]:
    """GBA-VMEM-001 (declared formula == recomputed residency over the
    counted blocks) + GBA-VMEM-002 (total residency under budget)."""
    findings = []
    if meta.declared_vmem_bytes is not None:
        recomputed = meta.vmem_bytes(meta.vmem_counted)
        if recomputed != meta.declared_vmem_bytes:
            findings.append(finding(
                "GBA-VMEM-001", site,
                f"{meta.kernel}: declared VMEM cap "
                f"{meta.declared_vmem_bytes}B != {recomputed}B recomputed "
                f"from blocks {list(meta.vmem_counted)} — the formula "
                f"drifted from the launch"))
    total = meta.total_vmem_bytes()
    if total > budget:
        findings.append(finding(
            "GBA-VMEM-002", site,
            f"{meta.kernel}: total VMEM residency {total}B "
            f"({ {k: v for k, v in meta.named_bytes().items() if v} }) "
            f"exceeds the {budget}B per-core budget"))
    return findings


def _grid_points(grid: tuple[int, ...], cap: int):
    if math.prod(grid) <= cap:
        return itertools.product(*(range(n) for n in grid))
    # huge grids: corners (and near-corners) catch off-by-one maps
    return itertools.product(*(sorted({0, 1, n - 1}) for n in grid))


def check_grid_bounds(meta: LaunchMeta, site: str,
                      max_points: int = 4096) -> list[Finding]:
    """GBA-GRID-001: every index map lands every block inside the padded
    array over the whole grid (corner sampling past ``max_points``)."""
    findings = []
    for bm in meta.inputs + meta.outputs:
        if bm.index_map is None or bm.block is None:
            continue
        for pt in _grid_points(meta.grid, max_points):
            idx = tuple(bm.index_map(*pt))
            bad = (len(idx) != len(bm.block)
                   or any(i < 0 for i in idx)
                   or any((i + 1) * blk > dim for i, blk, dim
                          in zip(idx, bm.block, bm.array_shape)))
            if bad:
                findings.append(finding(
                    "GBA-GRID-001", site,
                    f"{meta.kernel}/{bm.name}: index map at grid {pt} "
                    f"-> block index {idx} puts block {bm.block} outside "
                    f"array {bm.array_shape}"))
                break                      # one point per operand is enough
    return findings


def check_launch(meta: LaunchMeta, site: str,
                 budget: int = VMEM_BUDGET_BYTES) -> list[Finding]:
    """All Pallas rules over one launch."""
    return (check_tiles(meta, site)
            + check_vmem(meta, site, budget)
            + check_grid_bounds(meta, site))
