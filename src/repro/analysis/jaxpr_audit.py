"""jaxpr rule family: collective census, dtype lints, donation lint.

Everything here works on TRACED programs — ``jax.make_jaxpr`` output (which
``jax.sharding.AbstractMesh`` lets us build for any mesh size without
devices) and ``jax.jit(...).lower(...)`` argument metadata.  Nothing is
executed or compiled.

The census is the machine-checked form of the collective schedule
documented on ``core.gba_shard_map.make_gba_fused_psum_step``: one tiled
``all_gather`` per layer group (exact ``group_shard_sizes`` shapes, group
order) plus the (M,) token gather, one ``all_to_all`` per group (exact
``(M, group_shard)`` shapes), all gathers issued before any routing, and
the only ``psum`` left the scalar loss.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.analysis.rules import Finding, finding

# primitive names across jax versions: psum lowers as "psum" or "psum2"
_COLLECTIVES = ("all_gather", "all_to_all", "psum", "reduce_scatter",
                "ppermute", "all_reduce")


def _canon(name: str) -> str | None:
    for c in _COLLECTIVES:
        if name == c or (name.startswith(c) and name[len(c):].isdigit()):
            return c
    return None


def iter_eqns(jaxpr):
    """Depth-first walk over every eqn, descending into sub-jaxprs
    (pjit/closed_call/cond/scan/while/shard_map/custom_vjp/pallas_call)
    at their call site, so program order is preserved."""
    from jax.core import ClosedJaxpr, Jaxpr

    closed = getattr(jaxpr, "jaxpr", None)
    if closed is not None and not isinstance(jaxpr, Jaxpr):
        jaxpr = closed
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (tuple, list)) else (v,)):
                if isinstance(sub, ClosedJaxpr):
                    yield from iter_eqns(sub.jaxpr)
                elif isinstance(sub, Jaxpr):
                    yield from iter_eqns(sub)


@dataclass(frozen=True)
class Collective:
    """One collective eqn: canonical op name + operand/result avals."""

    op: str
    in_shapes: tuple[tuple[int, ...], ...]
    out_shapes: tuple[tuple[int, ...], ...]
    in_dtypes: tuple[str, ...]

    def scalar_only(self) -> bool:
        return all(s == () for s in self.in_shapes)


def collective_census(jaxpr) -> list[Collective]:
    """All collectives in program order (recursing into sub-jaxprs)."""
    out = []
    for eqn in iter_eqns(jaxpr):
        op = _canon(eqn.primitive.name)
        if op is None:
            continue
        out.append(Collective(
            op,
            tuple(tuple(v.aval.shape) for v in eqn.invars
                  if hasattr(v, "aval")),
            tuple(tuple(v.aval.shape) for v in eqn.outvars),
            tuple(str(v.aval.dtype) for v in eqn.invars
                  if hasattr(v, "aval")),
        ))
    return out


def census_counts(census: list[Collective]) -> dict[str, int]:
    counts: dict[str, int] = {}
    for c in census:
        counts[c.op] = counts.get(c.op, 0) + 1
    return counts


# ---------------------------------------------------------------------------
# GBA-COLL rules
# ---------------------------------------------------------------------------

def expected_fused_collectives(layout, m: int):
    """The declared schedule of ``make_gba_fused_psum_step`` for this
    layout: (per-group gather operand shapes, per-group all_to_all
    operand shapes, token-gather operand shape)."""
    gathers = [(layout.group_shard_sizes[g],)
               for g in range(layout.num_groups)]
    routes = [(m, layout.group_shard_sizes[g])
              for g in range(layout.num_groups)]
    return gathers, routes, (1,)


def check_fused_psum_schedule(jaxpr, layout, m: int,
                              site: str) -> list[Finding]:
    """GBA-COLL-001 + GBA-COLL-002 over a traced fused-psum step."""
    census = collective_census(jaxpr)
    findings = []
    exp_gathers, exp_routes, token = expected_fused_collectives(layout, m)

    gathers = [c for c in census if c.op == "all_gather"]
    routes = [c for c in census if c.op == "all_to_all"]
    got_gathers = [c.in_shapes[0] for c in gathers]
    got_routes = [c.in_shapes[0] for c in routes]
    if got_gathers != exp_gathers + [token]:
        findings.append(finding(
            "GBA-COLL-001", site,
            f"all_gather operands {got_gathers} != per-group "
            f"{exp_gathers} + token {token} (group_table order)"))
    if got_routes != exp_routes:
        findings.append(finding(
            "GBA-COLL-001", site,
            f"all_to_all operands {got_routes} != per-group {exp_routes}"))
    # schedule property: every param gather is issued before any routing
    first_route = next((i for i, c in enumerate(census)
                        if c.op == "all_to_all"), len(census))
    late_gather = [c.in_shapes[0] for c in census[first_route:]
                   if c.op == "all_gather" and c.in_shapes[0] != token]
    if late_gather:
        findings.append(finding(
            "GBA-COLL-001", site,
            f"param gathers {late_gather} issued after gradient routing"))
    stray = [c.op for c in census
             if c.op not in ("all_gather", "all_to_all", "psum")]
    if stray:
        findings.append(finding(
            "GBA-COLL-001", site, f"unexpected collectives {stray}"))
    findings += check_scalar_psum_only(jaxpr, site, census=census)
    return findings


def expected_wire_collectives(layout, m: int, policy, warm: bool = False):
    """The declared wire of a compressed fused-psum step: per-group
    ``all_to_all`` operand ``(shape, dtype)`` lists under ``policy``.

    Past warmup each group routes its int8 payload plus the per-tile f32
    sideband(s) — scale and zero-point for int8 min-max, the single norm
    for onebit sign; during warmup (or scheme ``none``) each group routes
    one f32 ``(m, group_shard)`` operand, the PR-5 wire."""
    per_group = []
    for g in range(layout.num_groups):
        gsh = layout.group_shard_sizes[g]
        if warm or policy.scheme == "none":
            per_group.append([((m, gsh), "float32")])
            continue
        n_tiles = gsh // layout.tile
        ops = [((m, gsh), "int8"), ((m, n_tiles), "float32")]
        if policy.scheme == "int8":
            ops.append(((m, n_tiles), "float32"))    # zero-point sideband
        per_group.append(ops)
    return per_group


def check_wire_dtypes(jaxpr, layout, m: int, policy, site: str,
                      warm: bool = False) -> list[Finding]:
    """GBA-COLL-005: every ``all_to_all``/``all_gather`` operand dtype on
    a traced fused-psum step matches the declared ``CompressionPolicy``.

    Routing: the flattened per-group (shape, dtype) sequence must equal
    :func:`expected_wire_collectives` exactly — an f32 ``(m,
    group_shard)`` operand in a past-warmup trace is full-precision
    leakage and fails CI.  Gathers: params always travel f32 (compression
    is a routing-stage transform) and the token gather stays int32."""
    census = collective_census(jaxpr)
    findings = []
    expected = [op for group in
                expected_wire_collectives(layout, m, policy, warm=warm)
                for op in group]
    routes = [(c.in_shapes[0], c.in_dtypes[0])
              for c in census if c.op == "all_to_all"]
    if routes != expected:
        findings.append(finding(
            "GBA-COLL-005", site,
            f"all_to_all wire {routes} != declared "
            f"{policy.scheme}{' warmup' if warm else ''} wire {expected}"))
    token = (1,)
    for c in census:
        if c.op != "all_gather":
            continue
        want = "int32" if c.in_shapes[0] == token else "float32"
        if c.in_dtypes[0] != want:
            findings.append(finding(
                "GBA-COLL-005", site,
                f"all_gather operand {c.in_shapes[0]} has dtype "
                f"{c.in_dtypes[0]}, expected {want} (params travel full "
                f"precision; compression is routing-stage only)"))
    return findings


def check_scalar_psum_only(jaxpr, site: str, census=None) -> list[Finding]:
    """GBA-COLL-002: psum reduces scalars only."""
    census = collective_census(jaxpr) if census is None else census
    bad = [c.in_shapes for c in census
           if c.op == "psum" and not c.scalar_only()]
    if bad:
        return [finding("GBA-COLL-002", site,
                        f"non-scalar psum operands: {bad}")]
    return []


def check_no_collectives(jaxpr, site: str) -> list[Finding]:
    """GBA-COLL-003: the path launches no collectives at all."""
    counts = census_counts(collective_census(jaxpr))
    if counts:
        return [finding("GBA-COLL-003", site, f"collectives found: {counts}")]
    return []


def check_sync_psum_schedule(jaxpr, leaf_shapes, site: str) -> list[Finding]:
    """GBA-COLL-004: the sync step psums exactly the per-leaf decayed
    gradients plus one scalar loss; no gathers or routing."""
    census = collective_census(jaxpr)
    findings = []
    others = census_counts([c for c in census if c.op != "psum"])
    if others:
        findings.append(finding(
            "GBA-COLL-004", site,
            f"sync step should only psum; found {others}"))
    psummed = [s for c in census if c.op == "psum" for s in c.in_shapes]
    want = sorted([tuple(s) for s in leaf_shapes] + [()])
    if sorted(psummed) != want:
        findings.append(finding(
            "GBA-COLL-004", site,
            f"psum operand shapes {sorted(psummed)} != per-leaf "
            f"gradients + scalar loss {want}"))
    return findings


# ---------------------------------------------------------------------------
# GBA-DTYPE rules
# ---------------------------------------------------------------------------

def widening_converts(jaxpr, min_elements: int = 8):
    """All float->wider-float convert_element_type eqns with at least
    ``min_elements`` elements: (shape, src_dtype, dst_dtype) list."""
    out = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        src = eqn.invars[0].aval
        dst = eqn.outvars[0].aval
        if (jnp.issubdtype(src.dtype, jnp.floating)
                and jnp.issubdtype(dst.dtype, jnp.floating)
                and dst.dtype.itemsize > src.dtype.itemsize
                and math.prod(src.shape) >= min_elements):
            out.append((tuple(src.shape), str(src.dtype), str(dst.dtype)))
    return out


def check_widening_budget(jaxpr, budget: int, site: str,
                          min_elements: int = 8) -> list[Finding]:
    """GBA-DTYPE-001: at most ``budget`` widening float converts.  Run on
    probe-loss traces where the sanctioned count (per-leaf ravel/loss
    casts) is exactly derivable — a real mixed-precision LM forward has
    legitimate upcasts this rule would misflag."""
    got = widening_converts(jaxpr, min_elements)
    if len(got) > budget:
        sample = got[:6]
        return [finding(
            "GBA-DTYPE-001", site,
            f"{len(got)} widening float converts > sanctioned {budget} "
            f"(per-leaf ravel/loss casts); e.g. {sample}")]
    return []


def check_no_f64(jaxpr, site: str) -> list[Finding]:
    """GBA-DTYPE-002: float64 never appears on a hot path."""
    hits = []
    for eqn in iter_eqns(jaxpr):
        for v in eqn.outvars:
            dt = getattr(v.aval, "dtype", None)
            if dt is not None and dt == jnp.float64:
                hits.append((eqn.primitive.name, tuple(v.aval.shape)))
    if hits:
        return [finding("GBA-DTYPE-002", site,
                        f"float64 values produced by {hits[:6]}")]
    return []


# ---------------------------------------------------------------------------
# GBA-DON donation lint
# ---------------------------------------------------------------------------

def undonated_paths(args_info) -> list[str]:
    """Leaves of a ``lowered.args_info`` subtree whose buffer is NOT
    donated, as readable path strings."""
    out = []
    for path, info in jax.tree_util.tree_flatten_with_path(args_info)[0]:
        if not getattr(info, "donated", False):
            out.append(jax.tree_util.keystr(path))
    return out


def check_donation(args_info, site: str) -> list[Finding]:
    """GBA-DON-001: every array leaf of the state argument is donated."""
    bad = undonated_paths(args_info)
    if bad:
        sample = ", ".join(bad[:8]) + ("..." if len(bad) > 8 else "")
        return [finding(
            "GBA-DON-001", site,
            f"{len(bad)} state leaves not donated (double-allocated on "
            f"every step): {sample}")]
    return []
