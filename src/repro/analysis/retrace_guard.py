"""Retrace rule family: GBA-RETRACE-001.

``jax.jit(f).trace(args)`` populates the same aval-keyed cache the real
call path uses, without compiling or executing anything.  Tracing twice
with *fresh but same-shaped* arguments must hit the cache the second
time; if the traced function leaks a python scalar, a weak-typed
constant, or a non-hashable static into its signature, the second trace
re-enters it and this guard sees the function body run again.
"""
from __future__ import annotations

import jax

from repro.analysis.rules import Finding, finding


def count_traces(fn, args_factory, n_calls: int = 2,
                 **jit_kwargs) -> int:
    """Trace ``jax.jit(fn)`` ``n_calls`` times with fresh args from
    ``args_factory()`` and return how many times the function body
    actually ran (1 == cached, stable)."""
    traces = 0

    def counted(*args, **kwargs):
        nonlocal traces
        traces += 1
        return fn(*args, **kwargs)

    jitted = jax.jit(counted, **jit_kwargs)
    for _ in range(n_calls):
        args, kwargs = args_factory()
        jitted.trace(*args, **kwargs)
    return traces


def check_retrace(fn, args_factory, site: str, **jit_kwargs) -> list[Finding]:
    """GBA-RETRACE-001: a second same-shaped call must not retrace."""
    traces = count_traces(fn, args_factory, n_calls=2, **jit_kwargs)
    if traces > 1:
        return [finding(
            "GBA-RETRACE-001", site,
            f"traced {traces}x for identical avals — the step leaks a "
            f"python scalar / weak type / unhashable static into its "
            f"jit signature")]
    return []
