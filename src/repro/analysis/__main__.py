"""CLI: audit every registered arch config against the hot-path rules.

    PYTHONPATH=src python -m repro.analysis --check            # CI gate
    PYTHONPATH=src python -m repro.analysis --arch granite-8b
    PYTHONPATH=src python -m repro.analysis --check \
        --suppress GBA-TILE-001@granite-8b/kernels/gba_apply
    PYTHONPATH=src python -m repro.analysis --markdown >> "$GITHUB_STEP_SUMMARY"

Exit status under ``--check`` is the number of unsuppressed findings
(0 == every audited hot path clean).
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.analysis.audit import AUDIT_M, run_audit
from repro.analysis.rules import RULES
from repro.configs import ARCH_IDS


def render_text(reports, elapsed: float) -> str:
    lines = []
    for rep in reports:
        mark = "ok" if rep.ok else f"{len(rep.findings)} FINDINGS"
        stats = " ".join(f"{k}={v}" for k, v in rep.stats.items())
        lines.append(f"[{mark:>11s}] {rep.name}" + (f"  ({stats})"
                                                    if stats else ""))
        for f in rep.findings:
            lines.append(f"    FAIL {f}")
        for f in rep.suppressed:
            lines.append(f"    supp {f.rule} @ {f.site}")
    total = sum(len(r.findings) for r in reports)
    supp = sum(len(r.suppressed) for r in reports)
    lines.append(
        f"audited {len(reports)} site groups x {len(RULES)} rules in "
        f"{elapsed:.1f}s: {total} finding(s), {supp} suppressed")
    return "\n".join(lines)


def render_markdown(reports, elapsed: float) -> str:
    total = sum(len(r.findings) for r in reports)
    lines = [
        "### Static audit (`python -m repro.analysis`)", "",
        f"{len(reports)} site groups x {len(RULES)} rules in "
        f"{elapsed:.1f}s — "
        + ("**all clean**" if total == 0 else f"**{total} finding(s)**"),
        "", "| site group | status | collectives (gather/route/psum) |",
        "|---|---|---|",
    ]
    for rep in reports:
        status = "✅ clean" if rep.ok else f"❌ {len(rep.findings)}"
        if rep.suppressed:
            status += f" ({len(rep.suppressed)} suppressed)"
        s = rep.stats
        coll = (f"{s['all_gather']}/{s['all_to_all']}/{s['psum']}"
                if "all_gather" in s else "—")
        lines.append(f"| {rep.name} | {status} | {coll} |")
    for rep in reports:
        for f in rep.findings:
            lines.append(f"- `{f.rule}` @ `{f.site}`: {f.detail}")
    lines.append("")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--arch", action="append", choices=ARCH_IDS,
                    help="audit only this arch (repeatable; default all)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero on any unsuppressed finding")
    ap.add_argument("--suppress", action="append", default=[],
                    metavar="RULE[@site]",
                    help="drop findings for RULE (optionally one site)")
    ap.add_argument("--workers", type=int, default=AUDIT_M,
                    help="PS shards / workers in the audited mesh")
    ap.add_argument("--markdown", action="store_true",
                    help="GitHub step-summary markdown instead of text")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    reports = run_audit(args.arch, m=args.workers,
                        suppressions=args.suppress)
    elapsed = time.perf_counter() - t0
    render = render_markdown if args.markdown else render_text
    print(render(reports, elapsed))
    total = sum(len(r.findings) for r in reports)
    return min(total, 125) if args.check else 0


if __name__ == "__main__":
    sys.exit(main())
