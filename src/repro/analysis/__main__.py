"""CLI: audit every registered arch config against the hot-path rules.

    PYTHONPATH=src python -m repro.analysis --check            # CI gate
    PYTHONPATH=src python -m repro.analysis --arch granite-8b
    PYTHONPATH=src python -m repro.analysis --check \
        --suppress GBA-TILE-001@granite-8b/kernels/gba_apply
    PYTHONPATH=src python -m repro.analysis --check --baseline .gba-audit.toml
    PYTHONPATH=src python -m repro.analysis --markdown >> "$GITHUB_STEP_SUMMARY"

Exit status under ``--check`` is the number of unsuppressed findings
(0 == every audited hot path clean).

``--baseline`` reads the checked-in suppression file — deliberate,
reviewable exceptions with a required reason per entry::

    [[suppress]]
    rule = "GBA-TILE-001"
    site = "granite-8b/kernels/gba_apply"   # optional: all sites if absent
    reason = "why this exception is deliberate"

A baseline entry that suppresses nothing prints an unused-suppression
warning so stale exceptions get cleaned up instead of hiding future
regressions.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from repro.analysis.audit import AUDIT_M, run_audit
from repro.analysis.rules import RULES
from repro.configs import ARCH_IDS


def _parse_minimal_toml(text: str) -> dict:
    """Fallback for pythons without :mod:`tomllib` (3.10): just enough
    TOML for the baseline format — ``[[suppress]]`` table arrays of
    ``key = "string"`` pairs, comments, blank lines."""
    data: dict = {}
    current = None
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line.startswith("[[") and line.endswith("]]"):
            name = line[2:-2].strip()
            current = {}
            data.setdefault(name, []).append(current)
            continue
        key, sep, value = line.partition("=")
        if not sep or current is None:
            raise ValueError(
                f"baseline line {lineno}: expected '[[suppress]]' or "
                f"'key = \"value\"', got {raw!r}")
        value = value.split("#", 1)[0].strip()
        if not (value.startswith('"') and value.endswith('"')):
            raise ValueError(
                f"baseline line {lineno}: values must be quoted strings")
        current[key.strip()] = value[1:-1]
    return data


def load_baseline(path) -> list[tuple[str, str | None, str]]:
    """``.gba-audit.toml`` -> ``[(rule, site_or_None, reason), ...]``."""
    p = Path(path)
    if not p.is_file():
        raise SystemExit(f"baseline file not found: {path}")
    try:
        import tomllib
        data = tomllib.loads(p.read_text())
    except ModuleNotFoundError:
        data = _parse_minimal_toml(p.read_text())
    entries = []
    for entry in data.get("suppress", []):
        if "rule" not in entry:
            raise SystemExit(
                f"baseline {path}: every [[suppress]] needs a 'rule'")
        if not entry.get("reason"):
            raise SystemExit(
                f"baseline {path}: entry for {entry['rule']} needs a "
                f"'reason' — exceptions must be reviewable")
        entries.append((entry["rule"], entry.get("site") or None,
                        entry["reason"]))
    return entries


def unused_baseline_entries(entries, reports):
    """Baseline entries whose (rule, site) suppressed no finding."""
    return [(rule, site, reason) for rule, site, reason in entries
            if not any(f.rule == rule and (site is None or f.site == site)
                       for rep in reports for f in rep.suppressed)]


def render_text(reports, elapsed: float) -> str:
    lines = []
    for rep in reports:
        mark = "ok" if rep.ok else f"{len(rep.findings)} FINDINGS"
        stats = " ".join(f"{k}={v}" for k, v in rep.stats.items())
        lines.append(f"[{mark:>11s}] {rep.name}" + (f"  ({stats})"
                                                    if stats else ""))
        for f in rep.findings:
            lines.append(f"    FAIL {f}")
        for f in rep.suppressed:
            lines.append(f"    supp {f.rule} @ {f.site}")
    total = sum(len(r.findings) for r in reports)
    supp = sum(len(r.suppressed) for r in reports)
    lines.append(
        f"audited {len(reports)} site groups x {len(RULES)} rules in "
        f"{elapsed:.1f}s: {total} finding(s), {supp} suppressed")
    return "\n".join(lines)


def render_markdown(reports, elapsed: float) -> str:
    total = sum(len(r.findings) for r in reports)
    lines = [
        "### Static audit (`python -m repro.analysis`)", "",
        f"{len(reports)} site groups x {len(RULES)} rules in "
        f"{elapsed:.1f}s — "
        + ("**all clean**" if total == 0 else f"**{total} finding(s)**"),
        "", "| site group | status | collectives (gather/route/psum) |",
        "|---|---|---|",
    ]
    for rep in reports:
        status = "✅ clean" if rep.ok else f"❌ {len(rep.findings)}"
        if rep.suppressed:
            status += f" ({len(rep.suppressed)} suppressed)"
        s = rep.stats
        coll = (f"{s['all_gather']}/{s['all_to_all']}/{s['psum']}"
                if "all_gather" in s else "—")
        lines.append(f"| {rep.name} | {status} | {coll} |")
    for rep in reports:
        for f in rep.findings:
            lines.append(f"- `{f.rule}` @ `{f.site}`: {f.detail}")
    lines.append("")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--arch", action="append", choices=ARCH_IDS,
                    help="audit only this arch (repeatable; default all)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero on any unsuppressed finding")
    ap.add_argument("--suppress", action="append", default=[],
                    metavar="RULE[@site]",
                    help="drop findings for RULE (optionally one site)")
    ap.add_argument("--workers", type=int, default=AUDIT_M,
                    help="PS shards / workers in the audited mesh")
    ap.add_argument("--markdown", action="store_true",
                    help="GitHub step-summary markdown instead of text")
    ap.add_argument("--baseline", metavar="TOML",
                    help="checked-in suppression file (.gba-audit.toml)")
    args = ap.parse_args(argv)

    baseline = load_baseline(args.baseline) if args.baseline else []
    suppressions = list(args.suppress) + [
        rule + (f"@{site}" if site else "")
        for rule, site, _ in baseline]

    t0 = time.perf_counter()
    reports = run_audit(args.arch, m=args.workers,
                        suppressions=suppressions)
    elapsed = time.perf_counter() - t0
    render = render_markdown if args.markdown else render_text
    print(render(reports, elapsed))
    for rule, site, reason in unused_baseline_entries(baseline, reports):
        print(f"warning: unused baseline suppression {rule}"
              + (f"@{site}" if site else "")
              + f" ({reason}) — remove it from {args.baseline}",
              file=sys.stderr)
    total = sum(len(r.findings) for r in reports)
    return min(total, 125) if args.check else 0


if __name__ == "__main__":
    sys.exit(main())
