"""Rule registry for the hot-path static auditor.

Every check the auditor runs carries a stable rule ID (``GBA-<FAM>-<NNN>``)
so CI failures, suppressions, and the bench columns all reference the same
name.  A violation is a :class:`Finding`; suppression is by rule ID —
globally (``"GBA-TILE-001"``) or per call site
(``"GBA-TILE-001@granite-8b/kernels/gba_apply"``).  See
``src/repro/analysis/README.md`` for what each rule guarantees.
"""
from __future__ import annotations

from dataclasses import dataclass

RULES: dict[str, str] = {
    "GBA-COLL-001": (
        "layer-grouped fused-psum collective schedule matches "
        "ShardedFlatLayout.group_table: one tiled all_gather per group "
        "(exact per-group shapes, group order) + one (M,) token gather, "
        "one all_to_all per group (exact (M, group_shard) shapes, group "
        "order), gathers before routing"),
    "GBA-COLL-002": (
        "every psum on the audited path reduces scalars only — the "
        "gradient buffer is routed, never summed"),
    "GBA-COLL-003": (
        "the serving decode path launches no collectives"),
    "GBA-COLL-004": (
        "the sync psum step reduces exactly the per-leaf decayed "
        "gradients plus one scalar loss — no gathers, no all_to_all"),
    "GBA-COLL-005": (
        "every all_to_all/all_gather operand dtype on the fused-psum "
        "wire matches the declared CompressionPolicy: per group, one "
        "int8 payload + the per-tile f32 sideband(s) past warmup, one "
        "f32 operand during warmup/none — full-precision leakage after "
        "warmup is a CI failure"),
    "GBA-DTYPE-001": (
        "no silent f32 upcast on the gradient path: widening float "
        "convert_element_type count equals the sanctioned per-leaf "
        "ravel/loss casts of the probe trace"),
    "GBA-DTYPE-002": (
        "no float64 anywhere in a traced hot path (x64/weak-type leak)"),
    "GBA-DON-001": (
        "the flat (M, shard) buffer, Adagrad accumulators, and params "
        "are donated into the jitted train step (no double allocation)"),
    "GBA-RETRACE-001": (
        "a second call with same-shaped inputs does not retrace "
        "(weak-type / python-scalar leak)"),
    "GBA-TILE-001": (
        "every tiled VMEM block axis is aligned to the per-dtype TPU "
        "min tile (lane 128; sublane 8/16/32 for 4/2/1-byte dtypes)"),
    "GBA-VMEM-001": (
        "the kernel's declared VMEM cap (apply_vmem_bytes-style formula) "
        "equals the residency recomputed from its launch meta"),
    "GBA-VMEM-002": (
        "total per-step VMEM residency (blocks + scratch) fits the "
        "16MiB per-core budget"),
    "GBA-GRID-001": (
        "every BlockSpec index map stays in bounds over the whole grid"),
    "GBA-FLOW-001": (
        "no path from a raw per-token gradient to the optimizer update "
        "bypasses the Eq. (1) decay-weight multiply (taint pass over the "
        "traced step: a 'raw' tag must be cleared by a decay-mask mul "
        "before it reaches a params/accum output)"),
    "GBA-FLOW-002": (
        "tombstone tokens propagate symbolic zero into the aggregate: at "
        "the decay multiply, the concretely-evaluated weight of every "
        "slot staler than iota is EXACTLY 0.0 (not just small) and every "
        "fresh slot's weight is nonzero"),
    "GBA-FLOW-003": (
        "the error-feedback residual feeds only the next quantize, never "
        "the apply: a 'residual' tag may reach params/accum outputs only "
        "through the quantize kernel's code path"),
    "GBA-FLOW-004": (
        "bf16-param models update through an f32 master chain: no "
        "sub-f32 float arithmetic on decayed-gradient values, and every "
        "narrowing convert of an updated value is a single final "
        "downcast (feeds outputs/stores, never further compute)"),
    "GBA-FLOW-005": (
        "the per-ID aggregate divisor counts only valid contributors: "
        "the divide of a gradient aggregate must be by a count carrying "
        "both the padding mask and the token-decay mask, never by a "
        "constant"),
    "GBA-RACE-001": (
        "no unlocked shared mutation: an attribute written by the sync "
        "thread, or one that is lock-guarded anywhere in its class, is "
        "only mutated under the instance lock (a single plain attribute "
        "assignment of a never-mutated-in-place object is blessed as an "
        "immutable snapshot swap)"),
    "GBA-RACE-002": (
        "no torn multi-attribute view: a method reading two or more "
        "lock-guarded attributes outside the lock can observe a torn "
        "version/step pair; one unlocked guarded read (the snapshot "
        "idiom) is blessed"),
    "GBA-RACE-003": (
        "no callback invoked while holding the lock: a method that calls "
        "stored listener callables must not be reached from inside a "
        "with-lock region (deadlock/reentrancy escape of shared state)"),
}


@dataclass(frozen=True)
class Finding:
    """One rule violation at one call site."""

    rule: str
    site: str
    detail: str

    def __str__(self) -> str:
        return f"{self.rule} @ {self.site}: {self.detail}"


def _validate(rule: str) -> None:
    if rule not in RULES:
        raise KeyError(f"unknown rule ID {rule!r}; known: {sorted(RULES)}")


def finding(rule: str, site: str, detail: str) -> Finding:
    _validate(rule)
    return Finding(rule, site, detail)


def parse_suppressions(items) -> tuple[tuple[str, str | None], ...]:
    """``["GBA-X-001", "GBA-Y-002@site"]`` -> ((rule, site-or-None), ...).
    Unknown rule IDs are rejected so a typo can't silently disable
    nothing."""
    out = []
    for item in items:
        rule, _, site = str(item).partition("@")
        _validate(rule)
        out.append((rule, site or None))
    return tuple(out)


def is_suppressed(f: Finding,
                  suppressions: tuple[tuple[str, str | None], ...]) -> bool:
    return any(rule == f.rule and (site is None or site == f.site)
               for rule, site in suppressions)


def apply_suppressions(findings, suppressions):
    """-> (kept, suppressed) finding lists."""
    kept, dropped = [], []
    for f in findings:
        (dropped if is_suppressed(f, suppressions) else kept).append(f)
    return kept, dropped
