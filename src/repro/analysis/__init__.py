"""Hot-path static auditor: traced (never executed) invariant checks.

Five rule families over the compiled hot paths — jaxpr collective
census + dtype/donation lints (``jaxpr_audit``), Pallas tile/VMEM/grid
checks over exported launch metadata (``pallas_check``), the retrace
guard (``retrace_guard``), the staleness-taint dataflow pass
(``dataflow``, GBA-FLOW), and the serving-thread lock-discipline lint
(``race_lint``, GBA-RACE) — wired into the per-arch matrix in ``audit``
and the ``python -m repro.analysis`` CLI.  Rule IDs, what each
guarantees, and the suppression syntax live in ``rules`` and
``src/repro/analysis/README.md``.
"""
from repro.analysis.audit import (AuditReport, audit_arch, audit_dataflow,
                                  audit_kernels, audit_serving,
                                  kernel_metas, run_audit,
                                  trace_fused_step, widening_budget)
from repro.analysis.dataflow import (FlowContext, Taint, analyze,
                                     check_divisor, check_no_raw,
                                     check_no_residual, check_tombstone,
                                     flow_aggregate_embedding,
                                     flow_fused_step,
                                     flow_fused_train_step,
                                     flow_pytree_step, flow_sync_step,
                                     out_paths, seed_taints, taint)
from repro.analysis.race_lint import (analyze_classes, lint_classes,
                                      lint_default, lint_sources)
from repro.analysis.jaxpr_audit import (Collective, census_counts,
                                        check_donation,
                                        check_fused_psum_schedule,
                                        check_no_collectives, check_no_f64,
                                        check_scalar_psum_only,
                                        check_sync_psum_schedule,
                                        check_widening_budget,
                                        collective_census,
                                        expected_fused_collectives,
                                        iter_eqns, undonated_paths,
                                        widening_converts)
from repro.analysis.pallas_check import (check_grid_bounds, check_launch,
                                         check_tiles, check_vmem)
from repro.analysis.retrace_guard import check_retrace, count_traces
from repro.analysis.rules import (RULES, Finding, apply_suppressions,
                                  finding, is_suppressed,
                                  parse_suppressions)

__all__ = [
    "AuditReport", "Collective", "Finding", "FlowContext", "RULES",
    "Taint", "analyze", "analyze_classes", "apply_suppressions",
    "audit_arch", "audit_dataflow", "audit_kernels", "audit_serving",
    "census_counts", "check_divisor", "check_donation",
    "check_fused_psum_schedule", "check_grid_bounds", "check_launch",
    "check_no_collectives", "check_no_f64", "check_no_raw",
    "check_no_residual", "check_retrace", "check_scalar_psum_only",
    "check_sync_psum_schedule", "check_tiles", "check_tombstone",
    "check_vmem", "check_widening_budget", "collective_census",
    "count_traces", "expected_fused_collectives", "finding",
    "flow_aggregate_embedding", "flow_fused_step",
    "flow_fused_train_step", "flow_pytree_step", "flow_sync_step",
    "is_suppressed", "iter_eqns", "kernel_metas", "lint_classes",
    "lint_default", "lint_sources", "out_paths", "parse_suppressions",
    "run_audit", "seed_taints", "taint", "trace_fused_step",
    "undonated_paths", "widening_budget", "widening_converts",
]
