"""Hot-path static auditor: traced (never executed) invariant checks.

Three rule families over the compiled hot paths — jaxpr collective
census + dtype/donation lints (``jaxpr_audit``), Pallas tile/VMEM/grid
checks over exported launch metadata (``pallas_check``), and the
retrace guard (``retrace_guard``) — wired into the per-arch matrix in
``audit`` and the ``python -m repro.analysis`` CLI.  Rule IDs, what each
guarantees, and the suppression syntax live in ``rules`` and
``src/repro/analysis/README.md``.
"""
from repro.analysis.audit import (AuditReport, audit_arch, audit_kernels,
                                  kernel_metas, run_audit,
                                  trace_fused_step, widening_budget)
from repro.analysis.jaxpr_audit import (Collective, census_counts,
                                        check_donation,
                                        check_fused_psum_schedule,
                                        check_no_collectives, check_no_f64,
                                        check_scalar_psum_only,
                                        check_sync_psum_schedule,
                                        check_widening_budget,
                                        collective_census,
                                        expected_fused_collectives,
                                        iter_eqns, undonated_paths,
                                        widening_converts)
from repro.analysis.pallas_check import (check_grid_bounds, check_launch,
                                         check_tiles, check_vmem)
from repro.analysis.retrace_guard import check_retrace, count_traces
from repro.analysis.rules import (RULES, Finding, apply_suppressions,
                                  finding, is_suppressed,
                                  parse_suppressions)

__all__ = [
    "AuditReport", "Collective", "Finding", "RULES",
    "apply_suppressions", "audit_arch", "audit_kernels", "census_counts",
    "check_donation", "check_fused_psum_schedule", "check_grid_bounds",
    "check_launch", "check_no_collectives", "check_no_f64",
    "check_retrace", "check_scalar_psum_only", "check_sync_psum_schedule",
    "check_tiles", "check_vmem", "check_widening_budget",
    "collective_census", "count_traces", "expected_fused_collectives",
    "finding", "is_suppressed", "iter_eqns", "kernel_metas",
    "parse_suppressions", "run_audit", "trace_fused_step",
    "undonated_paths", "widening_budget", "widening_converts",
]
