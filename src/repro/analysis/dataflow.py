"""GBA-FLOW: staleness-taint abstract interpretation over traced jaxprs.

The PR-6 census proves the collective *schedule*; this pass proves the
*dataflow* of every training mode.  Each input aval is seeded with a
provenance tag set drawn from a small lattice::

    raw        per-token gradient before Eq. (1) weighting
    decayed    gradient after a decay-mask multiply (sanitized)
    residual   quantization error-feedback state
    decay_mask the Eq. (1) weight ((gstep - tokens) <= iota)
    pad_mask   a validity mask derived from comparing ids to a bound
    token      per-slot token (arrival order) values
    step       the global step counter
    ids        embedding-row indices
    param      optimizer state (params / accumulators / f32 master)

and the interpreter walks every eqn — descending into ``pjit`` /
``cond`` / ``scan`` / ``while`` / ``shard_map`` / ``custom_vjp`` /
``pallas_call`` sub-jaxprs — propagating tags by union plus three
special transfer rules:

* a comparison mixing ``token`` and ``step`` taints produces a
  ``decay_mask`` (the Eq. (1) threshold); a comparison of ``ids``
  against an untainted bound produces a ``pad_mask``;
* a multiply of a ``raw``/``decayed`` value by a ``decay_mask`` operand
  *sanitizes*: ``raw`` is cleared, ``decayed`` is added, and the event
  is recorded (with the concretely-evaluated mask when the token seeds
  were concrete — that is how FLOW-002 proves tombstone weights are
  EXACTLY zero, not just small);
* the quantize Pallas kernel is the one sanctioned producer/consumer of
  ``residual``: its payload-shaped f32 output keeps the tag, every
  other output (the int8 payload and the f32 sidebands) drops it.

Alongside tags, the interpreter forward-evaluates a *concrete* numpy
value for vars whose inputs are all concretely known (token seeds, the
global step, literals), capped at :data:`MAX_CONCRETE` elements.  This
is what lets FLOW-002 check the actual weight of a tombstone slot
inside the ``gba_apply`` kernel without running it.

Checks (see ``rules.RULES`` for the contracts):

* **FLOW-001** no ``raw`` tag on a params/optimizer-state output;
* **FLOW-002** every concretely-evaluated decay mask gives weight 0.0
  to stale slots and nonzero weight to fresh ones;
* **FLOW-003** no ``residual`` tag on a params/optimizer-state output;
* **FLOW-004** no sub-f32 float arithmetic on ``decayed`` values, and
  every narrowing float convert is a terminal downcast;
* **FLOW-005** a gradient aggregate is divided by a divisor carrying
  both ``pad_mask`` and ``decay_mask`` (never by a constant).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.analysis.rules import Finding, finding

# tags -------------------------------------------------------------------
RAW = "raw"
DECAYED = "decayed"
RESIDUAL = "residual"
DECAY_MASK = "decay_mask"
PAD_MASK = "pad_mask"
TOKEN = "token"
STEP = "step"
IDS = "ids"
PARAM = "param"

MAX_CONCRETE = 1 << 16   # cap forward-evaluated arrays (elements)
_SCAN_FIXPOINT_ITERS = 16


@dataclass(frozen=True)
class Taint:
    """Tag set + optional concretely-known value for one var."""

    tags: frozenset
    val: Any = None      # np.ndarray when the value is concretely known

    def with_tags(self, tags) -> "Taint":
        return Taint(frozenset(tags), self.val)

    def drop_val(self) -> "Taint":
        return self if self.val is None else Taint(self.tags, None)


EMPTY = Taint(frozenset())


def taint(*tags, val=None) -> Taint:
    if val is not None:
        val = np.asarray(val)
        if val.size > MAX_CONCRETE:
            val = None
    return Taint(frozenset(tags), val)


@dataclass
class FlowContext:
    """Events recorded while interpreting one trace."""

    site: str
    sanitize_masks: list = field(default_factory=list)  # np arrays or None
    div_events: list = field(default_factory=list)      # (num_tags, den_tags,
    #                                                      den_is_const)
    findings: list = field(default_factory=list)
    f32_chain: bool = False   # enable FLOW-004 narrow-arith checks


# -- concrete forward evaluation ----------------------------------------

def _np_binop(fn):
    return lambda vals, params, aval: fn(vals[0], vals[1])


def _np_convert(vals, params, aval):
    return np.asarray(vals[0]).astype(params["new_dtype"])


def _np_broadcast(vals, params, aval):
    shape = tuple(params["shape"])
    bd = tuple(params["broadcast_dimensions"])
    tmp_shape = [1] * len(shape)
    for src_dim, dst_dim in enumerate(bd):
        tmp_shape[dst_dim] = np.shape(vals[0])[src_dim]
    return np.broadcast_to(np.reshape(vals[0], tmp_shape), shape)


def _np_reshape(vals, params, aval):
    v = vals[0]
    if params.get("dimensions") is not None:
        v = np.transpose(v, params["dimensions"])
    return np.reshape(v, params["new_sizes"])


def _np_slice(vals, params, aval):
    idx = tuple(slice(s, l, (st or 1)) for s, l, st in zip(
        params["start_indices"], params["limit_indices"],
        params.get("strides") or [1] * len(params["start_indices"])))
    return np.asarray(vals[0])[idx]


def _np_select_n(vals, params, aval):
    pred = np.asarray(vals[0]).astype(np.int64)
    cases = np.broadcast_arrays(*vals[1:])
    return np.choose(pred, cases, mode="clip")


def _np_reduce(fn):
    def run(vals, params, aval):
        return fn(np.asarray(vals[0]), axis=tuple(params["axes"]))
    return run


def _np_iota(vals, params, aval):
    shape = tuple(params["shape"])
    dim = params["dimension"]
    r = np.arange(shape[dim], dtype=params["dtype"])
    bshape = [1] * len(shape)
    bshape[dim] = shape[dim]
    return np.broadcast_to(np.reshape(r, bshape), shape)


def _np_dynamic_slice(vals, params, aval):
    op = np.asarray(vals[0])
    sizes = params["slice_sizes"]
    starts = [int(np.asarray(i)) for i in vals[1:]]
    idx = tuple(slice(min(max(s, 0), d - n), min(max(s, 0), d - n) + n)
                for s, d, n in zip(starts, op.shape, sizes))
    return op[idx]


def _np_dynamic_update_slice(vals, params, aval):
    op = np.array(vals[0])
    upd = np.asarray(vals[1])
    starts = [int(np.asarray(i)) for i in vals[2:]]
    idx = tuple(slice(min(max(s, 0), d - n), min(max(s, 0), d - n) + n)
                for s, d, n in zip(starts, op.shape, upd.shape))
    op[idx] = upd
    return op


_NP_EVAL: dict[str, Callable] = {
    "add": _np_binop(np.add), "sub": _np_binop(np.subtract),
    "mul": _np_binop(np.multiply), "div": _np_binop(np.true_divide),
    "max": _np_binop(np.maximum), "min": _np_binop(np.minimum),
    "rem": _np_binop(np.fmod), "pow": _np_binop(np.power),
    "lt": _np_binop(np.less), "le": _np_binop(np.less_equal),
    "gt": _np_binop(np.greater), "ge": _np_binop(np.greater_equal),
    "eq": _np_binop(np.equal), "ne": _np_binop(np.not_equal),
    "and": _np_binop(np.bitwise_and), "or": _np_binop(np.bitwise_or),
    "xor": _np_binop(np.bitwise_xor),
    "not": lambda vals, params, aval: np.bitwise_not(vals[0]),
    "neg": lambda vals, params, aval: np.negative(vals[0]),
    "abs": lambda vals, params, aval: np.abs(vals[0]),
    "sign": lambda vals, params, aval: np.sign(vals[0]),
    "sqrt": lambda vals, params, aval: np.sqrt(vals[0]),
    "floor": lambda vals, params, aval: np.floor(vals[0]),
    "ceil": lambda vals, params, aval: np.ceil(vals[0]),
    "integer_pow": lambda vals, params, aval: np.power(vals[0],
                                                       params["y"]),
    "is_finite": lambda vals, params, aval: np.isfinite(vals[0]),
    "stop_gradient": lambda vals, params, aval: vals[0],
    "copy": lambda vals, params, aval: vals[0],
    "convert_element_type": _np_convert,
    "broadcast_in_dim": _np_broadcast,
    "reshape": _np_reshape,
    "squeeze": lambda vals, params, aval: np.squeeze(
        vals[0], axis=tuple(params["dimensions"])),
    "expand_dims": lambda vals, params, aval: np.expand_dims(
        vals[0], axis=tuple(params["dimensions"])),
    "transpose": lambda vals, params, aval: np.transpose(
        vals[0], params["permutation"]),
    "slice": _np_slice,
    "rev": lambda vals, params, aval: np.flip(
        vals[0], axis=tuple(params["dimensions"])),
    "concatenate": lambda vals, params, aval: np.concatenate(
        vals, axis=params["dimension"]),
    "select_n": _np_select_n,
    "reduce_sum": _np_reduce(np.sum), "reduce_max": _np_reduce(np.max),
    "reduce_min": _np_reduce(np.min), "reduce_prod": _np_reduce(np.prod),
    "reduce_and": _np_reduce(np.all), "reduce_or": _np_reduce(np.any),
    "iota": _np_iota,
    "dynamic_slice": _np_dynamic_slice,
    "dynamic_update_slice": _np_dynamic_update_slice,
}


def _concrete(prim_name, in_taints, params, out_avals):
    """Forward-evaluate one eqn when all inputs are concrete.  Returns a
    list aligned with out_avals (``None`` entries = unknown)."""
    fn = _NP_EVAL.get(prim_name)
    if fn is None or any(t.val is None for t in in_taints):
        return [None] * len(out_avals)
    try:
        out = fn([t.val for t in in_taints], params, out_avals[0])
    except Exception:
        return [None] * len(out_avals)
    out = np.asarray(out)
    if out.size > MAX_CONCRETE:
        return [None] * len(out_avals)
    return [out] + [None] * (len(out_avals) - 1)


# -- jaxpr plumbing ------------------------------------------------------

def _is_literal(v) -> bool:
    return hasattr(v, "val")


def _read(env, v) -> Taint:
    if _is_literal(v):
        val = np.asarray(v.val)
        return Taint(frozenset(), val if val.size <= MAX_CONCRETE else None)
    return env.get(v, EMPTY)


def _union(taints) -> frozenset:
    tags = frozenset()
    for t in taints:
        tags |= t.tags
    return tags


_ARITH = {"add", "sub", "mul", "div", "max", "min", "neg", "abs",
          "dot_general", "reduce_sum", "reduce_max", "reduce_min",
          "sqrt", "rsqrt", "exp", "log", "integer_pow", "pow", "rem",
          "sign", "tanh", "logistic", "erf", "cumsum", "cumprod"}

# consumers a terminal downcast may legally feed (pure data movement)
_TERMINAL_OK = {"reshape", "squeeze", "expand_dims", "broadcast_in_dim",
                "transpose", "slice", "concatenate", "copy", "rev",
                "dynamic_update_slice", "swap", "convert_element_type"}

_CMP = {"lt", "le", "gt", "ge", "eq", "ne"}

_COLLECTIVES = {"psum", "all_gather", "all_to_all", "ppermute",
                "pbroadcast", "reduce_scatter", "pmax", "pmin"}


def _is_narrow_float(dtype) -> bool:
    return (jnp.issubdtype(dtype, jnp.floating)
            and np.dtype(dtype).itemsize < 4)


def _sub_closed(params):
    """Best-effort extraction of a single ClosedJaxpr from call params."""
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        sub = params.get(key)
        if sub is None:
            continue
        if hasattr(sub, "jaxpr"):       # ClosedJaxpr
            return sub
        if hasattr(sub, "eqns"):        # open Jaxpr
            return jax.extend.core.ClosedJaxpr(sub, ())
    return None


def _join(a: Taint, b: Taint) -> Taint:
    val = a.val if (a.val is not None and b.val is not None
                    and np.shape(a.val) == np.shape(b.val)
                    and np.array_equal(a.val, b.val)) else None
    return Taint(a.tags | b.tags, val)


class _Interp:
    """One taint interpretation of one (closed) jaxpr tree."""

    def __init__(self, ctx: FlowContext):
        self.ctx = ctx

    # -- special transfer rules ----------------------------------------

    def _compare(self, ins, out_tags):
        if (TOKEN in out_tags and STEP in out_tags):
            out_tags = out_tags | {DECAY_MASK}
        if IDS in out_tags and any(not t.tags for t in ins):
            # ids compared against a literal / untainted bound:
            # the validity (padding / capacity) mask
            out_tags = out_tags | {PAD_MASK}
        return out_tags

    def _mul(self, ins, out_tags):
        for data, mask in ((ins[0], ins[1]), (ins[1], ins[0])):
            if (DECAY_MASK in mask.tags and RAW not in mask.tags
                    and (RAW in data.tags or DECAYED in data.tags)):
                self.ctx.sanitize_masks.append(
                    None if mask.val is None else np.asarray(
                        mask.val, dtype=np.float64))
                return (out_tags - {RAW}) | {DECAYED}
        return out_tags

    # -- eqn dispatch ---------------------------------------------------

    def eqn_taints(self, eqn, ins):
        name = eqn.primitive.name
        params = eqn.params

        if name in ("pjit", "closed_call", "core_call", "xla_call",
                    "custom_jvp_call", "custom_vjp_call",
                    "custom_vjp_call_jaxpr", "remat", "checkpoint",
                    "custom_lin", "remat2"):
            sub = _sub_closed(params)
            if sub is not None and len(sub.jaxpr.invars) == len(ins):
                return self.run(sub.jaxpr, sub.consts, ins)
            tags = _union(ins)
            return [Taint(tags) for _ in eqn.outvars]

        if name == "cond":
            branches = params["branches"]
            ops = ins[1:]
            outs = None
            for br in branches:
                b_outs = self.run(br.jaxpr, br.consts, ops)
                outs = b_outs if outs is None else [
                    _join(a, b) for a, b in zip(outs, b_outs)]
            return outs

        if name == "scan":
            closed = params["jaxpr"]
            nc, ncar = params["num_consts"], params["num_carry"]
            consts_in = ins[:nc]
            carry = [t.drop_val() for t in ins[nc:nc + ncar]]
            xs = [t.drop_val() for t in ins[nc + ncar:]]
            outs = carry + [EMPTY] * (len(eqn.outvars) - ncar)
            for _ in range(_SCAN_FIXPOINT_ITERS):
                outs = self.run(closed.jaxpr, closed.consts,
                                consts_in + carry + xs)
                new_carry = [Taint(c.tags | o.tags)
                             for c, o in zip(carry, outs[:ncar])]
                if all(n.tags == c.tags
                       for n, c in zip(new_carry, carry)):
                    break
                carry = new_carry
            return ([Taint(t.tags) for t in outs[:ncar]]
                    + [Taint(t.tags) for t in outs[ncar:]])

        if name == "while":
            body = params["body_jaxpr"]
            nb = params["body_nconsts"]
            ncond = params["cond_nconsts"]
            consts_in = ins[ncond:ncond + nb]
            carry = [t.drop_val() for t in ins[ncond + nb:]]
            for _ in range(_SCAN_FIXPOINT_ITERS):
                outs = self.run(body.jaxpr, body.consts, consts_in + carry)
                new_carry = [Taint(c.tags | o.tags)
                             for c, o in zip(carry, outs)]
                if all(n.tags == c.tags
                       for n, c in zip(new_carry, carry)):
                    break
                carry = new_carry
            return carry

        if name == "shard_map":
            sub = params["jaxpr"]          # open Jaxpr
            in_names = params.get("in_names", ())
            seeded = []
            for i, t in enumerate(ins):
                split = i < len(in_names) and bool(in_names[i])
                seeded.append(t.drop_val() if split else t)
            return self.run(sub, (), seeded)

        if name == "pallas_call":
            return self._pallas(eqn, ins)

        if name in _COLLECTIVES:
            tags = _union(ins)
            return [Taint(tags) for _ in eqn.outvars]

        # -- leaf primitive: tag union + special rules + concrete eval --
        out_tags = _union(ins)
        if name in _CMP:
            out_tags = self._compare(ins, out_tags)
        elif name == "mul":
            out_tags = self._mul(ins, out_tags)
        elif name == "div":
            num, den = ins[0], ins[1]
            if RAW in num.tags or DECAYED in num.tags:
                self.ctx.div_events.append(
                    (num.tags, den.tags,
                     _is_literal(eqn.invars[1]) or not den.tags))

        out_avals = [v.aval for v in eqn.outvars]
        vals = _concrete(name, ins, params, out_avals)

        if self.ctx.f32_chain and name in _ARITH and DECAYED in out_tags:
            narrow = [v for v in list(eqn.invars) + list(eqn.outvars)
                      if hasattr(v.aval, "dtype")
                      and _is_narrow_float(v.aval.dtype)]
            if narrow:
                self.ctx.findings.append(finding(
                    "GBA-FLOW-004", self.ctx.site,
                    f"'{name}' on a decayed-gradient value uses "
                    f"{narrow[0].aval.dtype} — the update chain must stay "
                    f"f32 until the final downcast"))

        return [Taint(out_tags, val) for val in vals]

    # -- pallas kernels --------------------------------------------------

    def _pallas(self, eqn, ins):
        params = eqn.params
        gm = params.get("grid_mapping")
        kj = params.get("jaxpr")
        if gm is None or kj is None:
            tags = _union(ins)
            return [Taint(tags) for _ in eqn.outvars]
        n_scalar = getattr(gm, "num_index_operands", 0)
        n_in = getattr(gm, "num_inputs", 0)
        n_out = getattr(gm, "num_outputs", 0)

        ref_env = {}
        kvars = kj.invars
        for i, v in enumerate(kvars[:n_scalar]):
            ref_env[v] = ins[i]                      # scalar prefetch: keep
        for i, v in enumerate(kvars[n_scalar:n_scalar + n_in]):
            ref_env[v] = ins[n_scalar + i].drop_val()  # blocked: shape lies
        for v in kvars[n_scalar + n_in:]:
            ref_env[v] = EMPTY                       # outputs + scratch

        self._run_refs(kj, ref_env)

        outs = [ref_env.get(v, EMPTY).drop_val()
                for v in kvars[n_scalar + n_in:n_scalar + n_in + n_out]]

        kname = str(params.get("name_and_src_info", ""))
        if "quant" in kname and "dequant" not in kname:
            # the quantize kernel is the sanctioned residual producer:
            # only its payload-shaped f32 output carries the residual
            # forward; the int8 payload and the sidebands drop it.
            pay = eqn.invars[n_scalar].aval if len(eqn.invars) > n_scalar \
                else None
            fixed = []
            for v, t in zip(eqn.outvars, outs):
                is_res = (pay is not None
                          and v.aval.shape == pay.shape
                          and v.aval.dtype == np.float32)
                fixed.append(t if is_res
                             else Taint(t.tags - {RESIDUAL}, t.val))
            outs = fixed
        return outs

    def _run_refs(self, jaxpr, env):
        """Interpret a kernel body where Ref vars mutate in ``env``."""
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            ins = [_read(env, v) for v in eqn.invars]
            if name == "get":
                ref_t = ins[0]
                val = None
                if ref_t.val is not None:
                    try:
                        if len(ins) == 1:
                            val = ref_t.val
                        else:
                            idx = tuple(int(np.asarray(t.val))
                                        for t in ins[1:])
                            val = np.asarray(ref_t.val)[idx]
                    except Exception:
                        val = None
                outs = [Taint(ref_t.tags, val)]
            elif name == "swap":
                old = ins[0]
                env[eqn.invars[0]] = Taint(old.tags | ins[1].tags, None)
                outs = [Taint(old.tags, None)]
            elif name == "addupdate":
                env[eqn.invars[0]] = Taint(_union(ins), None)
                outs = []
            elif name == "run_scoped":
                sub = eqn.params.get("jaxpr")
                if sub is not None:
                    scoped = dict(env)
                    for v in sub.invars:
                        scoped[v] = EMPTY
                    self._run_refs(sub, scoped)
                    for v in jaxpr.invars:      # refs visible both scopes
                        if v in scoped:
                            env[v] = scoped[v]
                outs = [EMPTY for _ in eqn.outvars]
            else:
                outs = self.eqn_taints(eqn, ins)
            for v, t in zip(eqn.outvars, outs):
                if type(v).__name__ != "DropVar":
                    env[v] = t

    # -- driver ----------------------------------------------------------

    def run(self, jaxpr, consts, in_taints):
        env = {}
        for v, c in zip(jaxpr.constvars, consts):
            env[v] = c if isinstance(c, Taint) else taint(val=np.asarray(c))
        for v, t in zip(jaxpr.invars, in_taints):
            env[v] = t
        narrow_converts = []
        for eqn in jaxpr.eqns:
            ins = [_read(env, v) for v in eqn.invars]
            outs = self.eqn_taints(eqn, ins)
            for v, t in zip(eqn.outvars, outs):
                if type(v).__name__ != "DropVar":
                    env[v] = t
            if (self.ctx.f32_chain
                    and eqn.primitive.name == "convert_element_type"
                    and hasattr(eqn.invars[0], "aval")
                    and jnp.issubdtype(eqn.invars[0].aval.dtype, jnp.floating)
                    and np.dtype(eqn.invars[0].aval.dtype).itemsize
                    > np.dtype(eqn.outvars[0].aval.dtype).itemsize
                    and jnp.issubdtype(eqn.outvars[0].aval.dtype,
                                       jnp.floating)
                    and DECAYED in _read(env, eqn.outvars[0]).tags):
                narrow_converts.append(eqn.outvars[0])
        if narrow_converts:
            self._check_terminal(jaxpr, narrow_converts)
        return [_read(env, v) for v in jaxpr.outvars]

    def _check_terminal(self, jaxpr, narrow_vars):
        """FLOW-004: a narrowing downcast of a decayed value must be
        terminal — it may feed outputs and data movement, never further
        compute."""
        consumers: dict = {}
        for eqn in jaxpr.eqns:
            for v in eqn.invars:
                if not _is_literal(v):
                    consumers.setdefault(v, []).append(eqn)
        out_set = set(jaxpr.outvars)
        for nv in narrow_vars:
            frontier = [nv]
            seen = set()
            while frontier:
                v = frontier.pop()
                if v in seen:
                    continue
                seen.add(v)
                for eqn in consumers.get(v, ()):
                    if eqn.primitive.name in _TERMINAL_OK:
                        for ov in eqn.outvars:
                            if type(ov).__name__ != "DropVar":
                                frontier.append(ov)
                    else:
                        self.ctx.findings.append(finding(
                            "GBA-FLOW-004", self.ctx.site,
                            f"narrowed ({nv.aval.dtype}) update value "
                            f"feeds '{eqn.primitive.name}' — the downcast "
                            f"must be the final op of the update chain"))
                        return


# -- public API ----------------------------------------------------------

def analyze(closed, in_taints, *, site, f32_chain=False):
    """Run the taint pass over a ClosedJaxpr.  Returns
    ``(out_taints, ctx)``; FLOW-004 findings accumulate in ``ctx``."""
    jaxpr = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    consts = getattr(closed, "consts", ())
    if len(in_taints) != len(jaxpr.invars):
        raise ValueError(
            f"{site}: seeded {len(in_taints)} taints for "
            f"{len(jaxpr.invars)} invars")
    ctx = FlowContext(site=site, f32_chain=f32_chain)
    outs = _Interp(ctx).run(jaxpr, consts, list(in_taints))
    return outs, ctx


def seed_taints(args, specs) -> list[Taint]:
    """Flatten ``args`` (a tuple of pytrees, one per traced positional
    arg) into per-invar taints.  ``specs[i]`` is a :class:`Taint`
    applied to every leaf of ``args[i]``, or a callable
    ``(path_str, leaf) -> Taint``."""
    if len(args) != len(specs):
        raise ValueError("one spec per traced positional arg")
    out = []
    for arg, spec in zip(args, specs):
        leaves = jax.tree_util.tree_flatten_with_path(arg)[0]
        for path, leaf in leaves:
            if callable(spec) and not isinstance(spec, Taint):
                out.append(spec(jax.tree_util.keystr(path), leaf))
            else:
                out.append(spec)
    return out


def out_paths(tree) -> list[str]:
    """Leaf key-paths of a pytree, aligned with its flatten order — used
    to name which traced output a finding refers to."""
    return [jax.tree_util.keystr(p)
            for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


# -- checks --------------------------------------------------------------

def check_no_raw(out_taints, paths, guard, site) -> list[Finding]:
    """FLOW-001 over the update-state outputs selected by ``guard``
    (a predicate over the output path)."""
    out = []
    for t, p in zip(out_taints, paths):
        if guard(p) and RAW in t.tags:
            out.append(finding(
                "GBA-FLOW-001", site,
                f"raw per-token gradient reaches update output '{p}' "
                f"without passing the Eq. (1) decay multiply"))
    return out


def check_no_residual(out_taints, paths, guard, site) -> list[Finding]:
    """FLOW-003 over the update-state outputs selected by ``guard``."""
    out = []
    for t, p in zip(out_taints, paths):
        if guard(p) and RESIDUAL in t.tags:
            out.append(finding(
                "GBA-FLOW-003", site,
                f"error-feedback residual reaches update output '{p}' — "
                f"the residual may only feed the next quantize"))
    return out


def check_tombstone(ctx, stale_rows, site) -> list[Finding]:
    """FLOW-002: every concretely-evaluated decay mask must weight the
    stale slots (``stale_rows`` bool array, length M) EXACTLY 0.0 and
    the fresh slots nonzero."""
    stale_rows = np.asarray(stale_rows, dtype=bool)
    m = stale_rows.size
    out = []
    concrete = [w for w in ctx.sanitize_masks if w is not None]
    if not concrete:
        out.append(finding(
            "GBA-FLOW-002", site,
            "no concretely-evaluable decay mask found on the update path "
            "— tombstone weights cannot be proven exactly zero"))
        return out
    for w in concrete:
        flat = np.asarray(w, dtype=np.float64).reshape(-1)
        if flat.size % m:
            continue                     # mask not per-slot shaped
        per_slot = flat.reshape(m, -1)
        bad_stale = stale_rows & np.any(per_slot != 0.0, axis=1)
        bad_fresh = (~stale_rows) & np.all(per_slot == 0.0, axis=1)
        if bad_stale.any():
            out.append(finding(
                "GBA-FLOW-002", site,
                f"tombstone slot(s) {np.where(bad_stale)[0].tolist()} get "
                f"nonzero decay weight "
                f"{per_slot[bad_stale].reshape(-1)[:4].tolist()} — the "
                f"contract is weight EXACTLY 0, not just small"))
            break
        if bad_fresh.any():
            out.append(finding(
                "GBA-FLOW-002", site,
                f"fresh slot(s) {np.where(bad_fresh)[0].tolist()} get "
                f"decay weight 0 — live gradients must not be dropped"))
            break
    return out


# -- audited sites -------------------------------------------------------

def flow_fused_step(closed, batch, *, site, wire=None) -> list[Finding]:
    """FLOW-001 (and FLOW-003 when ``wire`` state is traced) on a
    layer-grouped fused psum step: args ``(param_flat, accum_flat,
    batch, tokens, gstep[, wire])``, outputs ``(new_p, new_a, loss
    [, new_wire])``."""
    seeds = [taint(PARAM), taint(PARAM)]
    seeds += [taint(RAW)] * len(jax.tree.leaves(batch))
    seeds += [taint(TOKEN), taint(STEP)]
    if wire is not None:
        for path, _ in jax.tree_util.tree_flatten_with_path(wire)[0]:
            is_res = "residual" in jax.tree_util.keystr(path)
            seeds.append(taint(RESIDUAL) if is_res else taint(RAW))
    outs, _ = analyze(closed, seeds, site=site)
    paths = ["new_param_flat", "new_accum_flat"]
    guard = lambda p: True
    return (check_no_raw(outs[:2], paths, guard, site)
            + check_no_residual(outs[:2], paths, guard, site))


def _tomb_tokens(m: int, step: int, iota: int) -> np.ndarray:
    """Buffer token seeds with one tombstone slot (index 1: staler than
    ``iota`` by exactly one — the Alg. 1 excluded-slot encoding) among
    fresh slots; slot m-1 is overwritten by the pushed token."""
    tokens = np.full((m,), step, dtype=np.int32)
    if m > 1:
        tokens[1] = step - iota - 1
    tokens[m - 1] = 0        # replaced by the push before the apply
    return tokens


def flow_fused_train_step(closed, state, *, site, m, iota,
                          f32_chain=True, step_seed=9) -> list[Finding]:
    """FLOW-001/002/004 on the single-host fused train step.  The
    buffer is seeded at fill m-1 with concrete tokens (one tombstone)
    so the decay weight inside ``gba_apply`` concretely evaluates."""
    tokens_seed = _tomb_tokens(m, step_seed, iota)

    def state_spec(path, leaf):
        if "tokens" in path:
            return taint(TOKEN, val=tokens_seed)
        if "fill" in path:
            return taint(val=np.int32(m - 1))
        if "step" in path:
            return taint(STEP, val=np.int32(step_seed))
        if "grads" in path:
            return taint(RAW)
        return taint(PARAM)          # params + accum

    seeds = seed_taints((state,), [state_spec])
    # batch leaves fill the gap between the state and the trailing token
    jaxpr = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    n_batch = len(jaxpr.invars) - len(seeds) - 1
    seeds += ([taint(RAW)] * n_batch
              + [taint(TOKEN, val=np.int32(step_seed))])

    outs, ctx = analyze(closed, seeds, site=site, f32_chain=f32_chain)
    paths = out_paths(state) + ["loss"]
    guard = lambda p: ("params" in p or "accum" in p)
    final_tokens = tokens_seed.copy()
    final_tokens[m - 1] = step_seed
    stale = (step_seed - final_tokens) > iota
    return (check_no_raw(outs, paths, guard, site)
            + check_tombstone(ctx, stale, site)
            + list(ctx.findings))


def flow_pytree_step(closed, state, *, site, iota,
                     step_seed=9) -> list[Finding]:
    """FLOW-001/002 on the per-leaf pytree train step.  One token per
    micro-step, so the taint pass runs twice over the one trace: a
    tombstone token must weight exactly 0, a fresh token nonzero.
    (FLOW-004 is not asserted here: the pytree mode deliberately
    accumulates in the arch's ``acc_dtype``; the f32-master contract
    belongs to the fused/flat path.)"""
    n_state = len(jax.tree.leaves(state))
    jaxpr = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    n_batch = len(jaxpr.invars) - n_state - 1
    findings: list[Finding] = []
    paths = out_paths(state) + ["loss"]
    guard = lambda p: ("params" in p or "opt" in p or "acc" in p)
    for token_val, stale in ((step_seed - iota - 1, [True]),
                             (step_seed, [False])):
        def state_spec(path, leaf):
            if "gstep" in path:
                return taint(STEP, val=np.int32(step_seed))
            if "micro" in path:
                return taint(val=np.int32(0))
            return taint(PARAM)
        seeds = ([state_spec(p, None) for p in out_paths(state)]
                 + [taint(RAW)] * n_batch
                 + [taint(TOKEN, val=np.int32(token_val))])
        outs, ctx = analyze(closed, seeds, site=site)
        findings += check_no_raw(outs, paths, guard, site)
        findings += check_tombstone(ctx, np.asarray(stale), site)
        if findings:
            break
    return findings


def flow_sync_step(closed, pshapes, opt_shapes, *, site) -> list[Finding]:
    """FLOW-001 on the sync psum step ``(params, opt, batch, tokens,
    gstep) -> (params, opt, loss)``."""
    n_p = len(jax.tree.leaves(pshapes))
    n_o = len(jax.tree.leaves(opt_shapes))
    jaxpr = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    n_batch = len(jaxpr.invars) - n_p - n_o - 2
    seeds = ([taint(PARAM)] * (n_p + n_o) + [taint(RAW)] * n_batch
             + [taint(TOKEN), taint(STEP)])
    outs, _ = analyze(closed, seeds, site=site)
    if len(outs) != n_p + n_o + 1:
        return [finding("GBA-FLOW-001", site,
                        f"sync step output arity {len(outs)} != params "
                        f"({n_p}) + opt ({n_o}) + loss — cannot prove "
                        f"the update path")]
    paths = (out_paths(pshapes) + out_paths(opt_shapes))
    return check_no_raw(outs[:-1], paths, lambda p: True, site)


def flow_aggregate_embedding(*, site, m=4, n=8, dim=8, capacity=64,
                             iota=4) -> list[Finding]:
    """FLOW-005 on the Alg. 2 per-ID aggregate: the divide that turns
    the scattered sum into a mean must be by the masked contributor
    count."""
    from functools import partial

    from repro.core.gba import aggregate_embedding
    SDS = jax.ShapeDtypeStruct
    args = (SDS((m, n), jnp.int32), SDS((m, n, dim), jnp.float32),
            SDS((m,), jnp.int32), SDS((capacity,), jnp.int32),
            SDS((), jnp.int32))
    closed = jax.make_jaxpr(
        partial(aggregate_embedding, iota=iota, capacity=capacity))(*args)
    seeds = seed_taints(args, [taint(IDS), taint(RAW), taint(TOKEN),
                               taint(STEP), taint(STEP)])
    _, ctx = analyze(closed, seeds, site=site)
    return check_divisor(ctx, site)


def check_divisor(ctx, site) -> list[Finding]:
    """FLOW-005: some divide of a gradient aggregate must exist, and
    every such divide's divisor must carry both masks."""
    out = []
    grad_divs = [(n, d, const) for n, d, const in ctx.div_events
                 if RAW in n or DECAYED in n]
    if not grad_divs:
        out.append(finding(
            "GBA-FLOW-005", site,
            "no divide of the gradient aggregate found — the mean over "
            "contributors is missing"))
        return out
    for _, den, const in grad_divs:
        if const or PAD_MASK not in den or DECAY_MASK not in den:
            have = sorted(den & {PAD_MASK, DECAY_MASK})
            out.append(finding(
                "GBA-FLOW-005", site,
                "aggregate divisor is "
                + ("a constant" if const else f"masked only by {have}")
                + " — the divisor must count exactly the valid "
                "(non-padding, non-tombstone) contributors"))
            break
    return out
