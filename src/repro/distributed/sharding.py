"""Sharding rules: params / caches / batches -> PartitionSpec trees.

Scheme (DESIGN.md §5): 2-D "fsdp + tensor" sharding on the single-pod
(data=16, model=16) mesh —

  weight matrices    rows over ``data`` (FSDP), cols over ``model`` (TP)
  attention heads    q/kv head axis over ``model`` (hd fallback when the
                     head count does not divide, e.g. starcoder2's 24H)
  MoE experts        expert axis over ``model`` (expert parallel), d_model
                     over ``data`` (FSDP) — the 1T kimi-k2 needs both
  embeddings/vocab   rows over ``model``, dim over ``data``
  norms/scalars      replicated

The multi-pod mesh adds a ``pod`` axis used purely for data parallelism:
params replicated across pods (DCN carries only gradient all-reduces),
batch sharded over ``(pod, data)``.

Every rule degrades to ``None`` when the dimension does not divide the mesh
axis, so one engine covers all ten architectures.  GBA state (gradient
buffer / accumulator) shards exactly like its gradient — the paper's
"each PS owns the buffer of its partition" mapped onto SPMD.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.flat_sharded import path_names as _path_names


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis]


def _fits(dim: int, mesh: Mesh, axis: str) -> bool:
    return dim % _axis_size(mesh, axis) == 0


def _maybe(dim: int, mesh: Mesh, axis: str) -> str | None:
    return axis if _fits(dim, mesh, axis) else None


def _leaf_spec(names: list[str], shape: tuple[int, ...], mesh: Mesh) -> P:
    """Trailing-dims rule table; leading stacked dims (scan repeats, GBA
    buffer slots) are replicated."""
    name = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    stacked = sum(1 for n in names if n in ("blocks", "encoder"))
    # GBA buffer / stacked-grad leading axis is handled by the caller
    # passing the unstacked shape; here stacked == scan repeats only.
    core = shape[stacked:]
    lead = (None,) * stacked

    def spec(*dims):
        return P(*lead, *dims)

    if name in ("embed",):
        return spec(_maybe(core[0], mesh, "model"),
                    _maybe(core[1], mesh, "data"))
    if name == "lm_head":
        return spec(_maybe(core[0], mesh, "data"),
                    _maybe(core[1], mesh, "model"))
    if parent == "moe":                                     # expert parallel
        if name == "router":
            return spec(None, _maybe(core[1], mesh, "model"))
        if name in ("wi_gate", "wi_up"):
            e, d, f = core
            return spec(_maybe(e, mesh, "model"),
                        _maybe(d, mesh, "data"), None)
        if name == "wo":
            e, f, d = core
            return spec(_maybe(e, mesh, "model"), None,
                        _maybe(d, mesh, "data"))
    if name in ("wq", "wk", "wv") and len(core) == 3:
        d, h, hd = core
        if _fits(h, mesh, "model"):
            return spec(_maybe(d, mesh, "data"), "model", None)
        return spec(_maybe(d, mesh, "data"), None,
                    _maybe(hd, mesh, "model"))
    if name == "wo" and len(core) == 3:                     # attention out
        h, hd, d = core
        if _fits(h, mesh, "model"):
            return spec("model", None, _maybe(d, mesh, "data"))
        return spec(None, _maybe(hd, mesh, "model"),
                    _maybe(d, mesh, "data"))
    if name in ("wi_gate", "wi_up") and len(core) == 2:     # dense mlp
        return spec(_maybe(core[0], mesh, "data"),
                    _maybe(core[1], mesh, "model"))
    if name == "wo" and len(core) == 2:
        return spec(_maybe(core[0], mesh, "model"),
                    _maybe(core[1], mesh, "data"))
    if name in ("in_proj", "w_z", "w_x", "w_B", "w_C", "w_dt"):  # mamba
        return spec(_maybe(core[0], mesh, "data"),
                    _maybe(core[1], mesh, "model"))
    if name in ("conv_x", "conv_B", "conv_C"):
        return spec(None, _maybe(core[1], mesh, "model"))
    if name == "out_proj":
        return spec(_maybe(core[0], mesh, "model"),
                    _maybe(core[1], mesh, "data"))
    if name == "conv_w":
        return spec(None, _maybe(core[1], mesh, "model"))
    if name in ("wx", "wh"):                                # recsys GRU
        return spec(None, None)
    # norms, biases, A_log, dt_bias, D_skip, scalars
    return spec(*([None] * len(core)))


def param_specs(params_shapes: Any, mesh: Mesh) -> Any:
    """ShapeDtypeStruct pytree -> PartitionSpec pytree."""

    def per_leaf(path, leaf):
        names = _path_names(path)
        sp = _leaf_spec(names, leaf.shape, mesh)
        return sp

    return jax.tree_util.tree_map_with_path(per_leaf, params_shapes)


def serve_param_specs(params_shapes: Any, mesh: Mesh,
                      hbm_budget: float = 8e9) -> Any:
    """Inference sharding (§Perf `serve_tp` variant): drop the `data`
    (FSDP) axis from weight specs — pure tensor parallelism — when the
    resulting per-device param bytes fit ``hbm_budget``.  Decode steps then
    read weights locally instead of all-gathering them every token."""
    pspecs = param_specs(params_shapes, mesh)

    def drop_data(spec):
        return P(*(None if ax == "data" else ax for ax in spec))

    dropped = jax.tree.map(drop_data, pspecs,
                           is_leaf=lambda s: isinstance(s, P))

    def per_dev_bytes(shapes, specs) -> float:
        total = 0.0
        for leaf, spec in zip(jax.tree.leaves(shapes),
                              jax.tree.leaves(
                                  specs, is_leaf=lambda s: isinstance(s, P))):
            shard = 1
            for ax in spec:
                if ax is None:
                    continue
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    shard *= _axis_size(mesh, a)
            total += leaf.size * leaf.dtype.itemsize / shard
        return total

    if per_dev_bytes(params_shapes, dropped) <= hbm_budget:
        return dropped
    return pspecs  # too big without FSDP (kimi-k2): keep 2-D sharding


def stacked_specs(specs: Any, lead: int = 1) -> Any:
    """Prepend ``lead`` replicated dims (M-slot GBA buffer over params)."""
    return jax.tree.map(lambda s: P(*((None,) * lead), *s), specs,
                        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# flat-sharded GBA state (core.flat_sharded.ShardedFlatLayout)
# ---------------------------------------------------------------------------

def flat_slice_specs(layout: Any, mesh: Mesh, axis: str = "data") -> dict:
    """PartitionSpecs for a ShardedFlatLayout's state: flat param/accum
    vectors split over ``axis`` (each PS shard owns one contiguous
    tile-aligned slice), buffer columns likewise with the M slot axis
    replicated, slot tokens / fill / step scalars replicated.  The specs
    are grouping-agnostic — a layer-grouped layout orders the flat axis
    shard-major, so ``P(axis)`` still hands every shard one contiguous
    slice containing its sub-slice of every layer group.

    Validates the layout geometry against the mesh: the layout must have
    exactly one shard per device on ``axis``, its padded total must split
    evenly, and its layer-group table must be self-consistent (every
    group a whole number of ``num_shards * tile`` chunks summing to the
    padded total, every leaf assigned to a real group).  All guaranteed
    by ``ShardedFlatLayout.from_params``; re-checked here so a stale or
    hand-built layout fails loudly at spec-build time rather than as an
    XLA shape error inside shard_map.
    """
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {axis!r}: {mesh.axis_names}")
    n_dev = _axis_size(mesh, axis)
    if layout.num_shards != n_dev:
        raise ValueError(
            f"layout has {layout.num_shards} shards, mesh axis {axis!r} "
            f"has {n_dev} devices")
    if layout.padded_total != layout.num_shards * layout.shard_size:
        raise ValueError(
            f"layout padded_total {layout.padded_total} != "
            f"{layout.num_shards} * {layout.shard_size}")
    chunk = layout.num_shards * layout.tile
    for key, gs in zip(layout.group_keys, layout.group_sizes):
        if gs % chunk:
            raise ValueError(
                f"layer group {key!r} extent {gs} is not a multiple of "
                f"num_shards * tile = {chunk}")
    if sum(layout.group_sizes) != layout.padded_total:
        raise ValueError(
            f"layer groups cover {sum(layout.group_sizes)} elements, "
            f"layout padded_total is {layout.padded_total}")
    if any(g >= len(layout.group_keys) for g in layout.leaf_group):
        raise ValueError("leaf_group indexes past the group table")
    return {
        "flat": P(axis),
        "buffer": {
            "grads": P(None, axis),
            "tokens": P(),
            "fill": P(),
            "step": P(),
        },
    }


def wire_state_specs(layout: Any, mesh: Mesh, scheme: str,
                     axis: str = "data") -> dict:
    """PartitionSpecs for the compressed-wire state of
    ``core.gba_shard_map.make_gba_fused_psum_step``: per-worker
    error-feedback residual (and onebit momentum) rows of shape
    ``(M, padded_total)``, row ``w`` = worker ``w``'s state — split over
    ``axis`` on the worker axis, columns local (``P(axis, None)``).
    Returns one spec per ``layout.wire_state_shapes`` entry ({} for
    ``scheme="none"``).  Reuses :func:`flat_slice_specs`'s geometry
    validation so a stale layout fails at spec-build time."""
    flat_slice_specs(layout, mesh, axis)        # geometry validation only
    m = _axis_size(mesh, axis)
    return {name: P(axis, None)
            for name in layout.wire_state_shapes(m, scheme)}


def fused_state_specs(layout: Any, mesh: Mesh, pspecs: Any,
                      axis: str = "data") -> dict:
    """Spec tree for ``launch.steps``'s fused train state: model params
    keep their per-leaf rules (``pspecs``, the forward consumes them),
    while the Adagrad accumulator and the M-slot gradient buffer live
    flat — sliced over ``axis`` for a ShardedFlatLayout, replicated for
    the single-host ``FlatLayout``."""
    from repro.core.flat_sharded import ShardedFlatLayout
    if isinstance(layout, ShardedFlatLayout):
        flat = flat_slice_specs(layout, mesh, axis)
    else:
        flat = {"flat": P(), "buffer": {"grads": P(), "tokens": P(),
                                        "fill": P(), "step": P()}}
    return {"params": pspecs, "accum": flat["flat"],
            "buffer": flat["buffer"]}


def cache_specs(cache_shapes: Any, cfg: ModelConfig, mesh: Mesh,
                batch: int) -> Any:
    """Decode-cache PartitionSpecs.  Batch shards over (pod, data) when it
    divides; otherwise (long_500k, B=1) the KV sequence dim shards over
    ``data`` — sequence-parallel cache, DESIGN.md §5."""
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= _axis_size(mesh, a)
    batch_ok = batch % dp_size == 0
    bspec = dp if batch_ok else None
    seq_axis = None if batch_ok else "data"

    def per_leaf(path, leaf):
        names = _path_names(path)
        name = names[-1]
        stacked = 1 if "blocks" in names else 0
        lead = (None,) * stacked
        core = leaf.shape[stacked:]
        if name in ("k", "v"):
            b, L, kv, hd = core
            kvs = _maybe(kv, mesh, "model")
            hds = None if kvs else _maybe(hd, mesh, "model")
            Ls = seq_axis if (seq_axis and _fits(L, mesh, "data")) else None
            return P(*lead, bspec, Ls, kvs, hds)
        if name == "ssm":
            b, h, pdim, n = core
            return P(*lead, bspec, _maybe(h, mesh, "model"), None, None)
        if name == "conv":
            b, w, c = core
            return P(*lead, bspec, None, _maybe(c, mesh, "model"))
        if name == "memory":
            b, t, d = core
            return P(bspec, None, None)
        return P(*([None] * leaf.ndim))  # pos scalar etc.

    return jax.tree_util.tree_map_with_path(per_leaf, cache_shapes)


def batch_partition(mesh: Mesh, batch: int, ndim: int) -> P:
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= _axis_size(mesh, a)
    lead = dp if batch % dp_size == 0 else None
    return P(lead, *([None] * (ndim - 1)))


def to_named(specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
