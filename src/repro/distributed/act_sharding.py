"""Activation sharding constraints (§Perf iteration 4).

GSPMD resolves the sharding conflict at the embedding gather (tokens
batch-sharded over `data` vs table dim-sharded over `data`) by *replicating
the batch* — every downstream activation then loses data parallelism (seen
as full-batch f32 temps in the HLO and TB-scale memory terms).

The fix is the canonical one: pin the residual stream to the
megatron-style layout P(data_axes, None, None) at block boundaries.
``set_act_spec`` is called by launch.steps before tracing; models call
``constrain`` on (B, S, D) activations.  Outside a mesh context (smoke
tests) the spec is None and ``constrain`` is the identity.
"""
from __future__ import annotations

import jax

_ACT_SHARDING = None
_EXPERT_SHARDING = None


def set_act_spec(sharding) -> None:
    """sharding: a NamedSharding for (B, S, D) activations, or None."""
    global _ACT_SHARDING
    _ACT_SHARDING = sharding


def set_expert_spec(sharding) -> None:
    """sharding for (E, capacity, D) MoE dispatch buffers (expert-parallel:
    E over `model`), or None."""
    global _EXPERT_SHARDING
    _EXPERT_SHARDING = sharding


def constrain(x: jax.Array) -> jax.Array:
    if _ACT_SHARDING is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, _ACT_SHARDING)


def constrain_expert(x: jax.Array) -> jax.Array:
    """Pin (E, cap, D) dispatch buffers to expert-parallel layout so GSPMD
    lowers the dispatch scatter as a partitioned scatter instead of
    converting it to dense one-hot contractions (§Perf H3)."""
    if _EXPERT_SHARDING is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, _EXPERT_SHARDING)
