from repro.distributed.sharding import (batch_partition, cache_specs,
                                        data_axes, param_specs)

__all__ = ["batch_partition", "cache_specs", "data_axes", "param_specs"]
