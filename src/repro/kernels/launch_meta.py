"""Static Pallas launch geometry, exported instead of buried in closures.

Every kernel in this package describes its launch — grid, per-operand
BlockSpec blocks and index maps, VMEM scratch, scalar-prefetch count,
in-place aliases, and the *declared* VMEM cap its docstring/bench rows
advertise — as a :class:`LaunchMeta` built by a ``*_launch_meta()``
function next to the kernel.  The simple 1-D kernels (``gba_apply``,
``fused_adagrad``, ``gba_aggregate``) construct their real
``pallas_call`` specs FROM the meta (single source of truth); the
DMA-streamed kernels (``embedding_bag``, ``flash_decode``) build their
VMEM scratch from it and mirror the block specs, which the static
auditor (``repro.analysis.pallas_check``) then cross-checks: tile
alignment against per-dtype TPU min tiles (GBA-TILE-001), recomputed
vs declared VMEM residency (GBA-VMEM-001), total residency under the
per-core budget (GBA-VMEM-002), and index-map bounds over the whole
grid (GBA-GRID-001) — all without executing or compiling anything.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import jax.numpy as jnp

# memory spaces a BlockMeta can live in
VMEM, SMEM, ANY = "vmem", "smem", "any"


def _round_up_static(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclass(frozen=True)
class BlockMeta:
    """One pallas_call operand: its (padded) array, block, and index map.

    ``block`` is the BlockSpec block shape; ``index_map`` maps grid
    indices to BLOCK indices (the BlockSpec convention).  Operands in
    ``ANY`` memory space (HBM-resident, DMA-streamed by the kernel body)
    carry ``block=None`` and contribute nothing to VMEM residency.
    """

    name: str
    array_shape: tuple[int, ...]
    dtype: Any
    block: tuple[int, ...] | None = None
    index_map: Callable[..., tuple[int, ...]] | None = None
    memory_space: str = VMEM

    @property
    def itemsize(self) -> int:
        return jnp.dtype(self.dtype).itemsize

    def block_bytes(self) -> int:
        if self.memory_space != VMEM:
            return 0
        # a VMEM operand with no block spec is fully resident
        shape = self.block if self.block is not None else self.array_shape
        return math.prod(shape) * self.itemsize


@dataclass(frozen=True)
class ScratchMeta:
    """One VMEM scratch buffer (DMA semaphores are not VMEM residency)."""

    name: str
    shape: tuple[int, ...]
    dtype: Any

    def bytes(self) -> int:
        return math.prod(self.shape) * jnp.dtype(self.dtype).itemsize


@dataclass(frozen=True)
class LaunchMeta:
    """Complete static description of one pallas_call launch."""

    kernel: str
    grid: tuple[int, ...]
    inputs: tuple[BlockMeta, ...]
    outputs: tuple[BlockMeta, ...]
    scratch: tuple[ScratchMeta, ...] = ()
    num_scalar_prefetch: int = 0
    # array-input index (position within ``inputs``) -> output index,
    # NOT counting scalar-prefetch operands; ``pallas_aliases`` shifts
    aliases: tuple[tuple[int, int], ...] = ()
    # the VMEM cap the kernel declares (apply_vmem_bytes-style) and which
    # block/scratch names that formula counts; None = no declared cap
    declared_vmem_bytes: int | None = None
    vmem_counted: tuple[str, ...] = ()

    def pallas_aliases(self) -> dict[int, int]:
        """``input_output_aliases`` for the real pallas_call: flat input
        positions COUNT the scalar-prefetch operands."""
        return {self.num_scalar_prefetch + i: o for i, o in self.aliases}

    def named_bytes(self) -> dict[str, int]:
        """VMEM bytes per named block/scratch (ANY-space operands = 0)."""
        out: dict[str, int] = {}
        for bm in self.inputs + self.outputs:
            out[bm.name] = bm.block_bytes()
        for sm in self.scratch:
            out[sm.name] = sm.bytes()
        return out

    def vmem_bytes(self, names: tuple[str, ...] | None = None) -> int:
        """Recomputed VMEM residency over ``names`` (default: everything).
        ``names=self.vmem_counted`` reproduces what the declared formula
        is supposed to cover."""
        by_name = self.named_bytes()
        if names is None:
            return sum(by_name.values())
        missing = [n for n in names if n not in by_name]
        if missing:
            raise KeyError(f"{self.kernel}: unknown block names {missing}")
        return sum(by_name[n] for n in names)

    def total_vmem_bytes(self) -> int:
        return self.vmem_bytes(None)


def block_specs(blocks: tuple[BlockMeta, ...]):
    """BlockMeta tuple -> the real pallas BlockSpec list (imports pallas
    lazily so the dataclasses stay importable without a TPU toolchain)."""
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    specs = []
    for bm in blocks:
        if bm.memory_space == ANY:
            specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
        else:
            specs.append(pl.BlockSpec(bm.block, bm.index_map))
    return specs


def scratch_shapes(scratch: tuple[ScratchMeta, ...]):
    """ScratchMeta tuple -> pltpu.VMEM scratch list (semaphores are
    appended by the kernel itself — they are not VMEM residency)."""
    from jax.experimental.pallas import tpu as pltpu

    return [pltpu.VMEM(sm.shape, sm.dtype) for sm in scratch]
