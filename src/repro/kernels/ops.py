"""jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to CPU-interpret mode in this container; on real
TPUs call ``set_interpret(False)`` once at startup (launch scripts do).
The tree-level helpers apply the kernels across parameter pytrees.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag import embedding_bag, embedding_bag_grad
from repro.kernels.fused_adagrad import fused_adagrad
from repro.kernels.gba_aggregate import gba_aggregate
from repro.kernels.gba_apply import gba_apply

_INTERPRET = True


def set_interpret(value: bool) -> None:
    global _INTERPRET
    _INTERPRET = value


def gba_aggregate_tree(grads_stacked: Any, tokens: jax.Array,
                       step: jax.Array, *, iota: int) -> Any:
    """Kernel-backed version of repro.core.gba.aggregate_dense: flattens
    each leaf to (M, -1), runs the fused kernel, restores shapes."""

    def per_leaf(g):
        m = g.shape[0]
        flat = g.reshape(m, -1)
        out = gba_aggregate(flat, tokens, step, iota=iota,
                            interpret=_INTERPRET)
        return out.reshape(g.shape[1:])

    return jax.tree.map(per_leaf, grads_stacked)


def gba_apply_flat(param_flat: jax.Array, accum_flat: jax.Array,
                   buffer: jax.Array, tokens: jax.Array, step: jax.Array,
                   lr, *, iota: int, eps: float = 1e-10,
                   interpret: bool | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Fused decay-aggregate + Adagrad over the flat (M, N) buffer — the
    single-launch PS apply path (see repro.core.gba.FlatLayout)."""
    return gba_apply(param_flat, accum_flat, buffer, tokens, step, lr,
                     iota=iota, eps=eps,
                     interpret=_INTERPRET if interpret is None else interpret)


def adagrad_apply_tree(params: Any, grads: Any, accums: Any, lr
                       ) -> tuple[Any, Any]:
    """Fused Adagrad over a pytree (flattening each leaf to 1-D)."""

    def per_leaf(p, g, a):
        np_, na = fused_adagrad(p.reshape(-1), g.reshape(-1), a.reshape(-1),
                                lr, interpret=_INTERPRET)
        return np_.reshape(p.shape), na.reshape(a.shape)

    out = jax.tree.map(per_leaf, params, grads, accums)
    is2 = lambda t: isinstance(t, tuple)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=is2)
    new_a = jax.tree.map(lambda t: t[1], out, is_leaf=is2)
    return new_p, new_a


def pooled_lookup(ids: jax.Array, table: jax.Array) -> jax.Array:
    return embedding_bag(ids, table, interpret=_INTERPRET)


def pooled_lookup_grad(ids: jax.Array, grad_out: jax.Array, capacity: int
                       ) -> tuple[jax.Array, jax.Array]:
    return embedding_bag_grad(ids, grad_out, capacity, interpret=_INTERPRET)
