"""jit'd public wrappers over the Pallas kernels.

``interpret`` resolves from the platform at first use — CPU/GPU containers
interpret, real TPUs compile (``repro.kernels.runtime``).  Override the
session default with the ``REPRO_INTERPRET`` env var or ``set_interpret``;
every wrapper additionally honors a per-call ``interpret=`` override.
The tree-level helpers apply the kernels across parameter pytrees; the
pooled-lookup wrappers expose the streamed embedding kernels' capacity
knobs (``block_v``/``block_d``/``chunk_e``).
"""
from __future__ import annotations

import collections
from typing import Any

import jax

from repro.kernels import runtime
from repro.kernels.embedding_bag import embedding_bag, embedding_bag_grad
from repro.kernels.fused_adagrad import fused_adagrad
from repro.kernels.gba_aggregate import gba_aggregate
from repro.kernels.gba_apply import gba_apply
from repro.kernels.quantize import dequantize, quantize_minmax, quantize_sign
from repro.kernels.runtime import set_interpret  # noqa: F401  (re-export)

# Python-level invocation census of the eager wrappers below.  This is
# the structural evidence the serving stack leans on: a hot-ID cache hit
# must leave ``kernel_calls["pooled_lookup"]`` unchanged — the batch
# never reached the streamed kernel (gated as ``audit_hit_skips_kernel``
# in the serving bench and asserted by tests/test_serving_live.py).
# Counts wrapper INVOCATIONS (including cached jit executions), not
# traces — exactly what "did this request touch the kernel path" means.
kernel_calls: collections.Counter = collections.Counter()


def gba_aggregate_tree(grads_stacked: Any, tokens: jax.Array,
                       step: jax.Array, *, iota: int,
                       interpret: bool | None = None) -> Any:
    """Kernel-backed version of repro.core.gba.aggregate_dense: flattens
    each leaf to (M, -1), runs the fused kernel, restores shapes."""
    itp = runtime.resolve(interpret)

    def per_leaf(g):
        m = g.shape[0]
        flat = g.reshape(m, -1)
        out = gba_aggregate(flat, tokens, step, iota=iota, interpret=itp)
        return out.reshape(g.shape[1:])

    return jax.tree.map(per_leaf, grads_stacked)


def gba_apply_flat(param_flat: jax.Array, accum_flat: jax.Array,
                   buffer: jax.Array, tokens: jax.Array, step: jax.Array,
                   lr, *, iota: int, eps: float = 1e-10,
                   interpret: bool | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Fused decay-aggregate + Adagrad over the flat (M, N) buffer — the
    single-launch PS apply path (see repro.core.gba.FlatLayout)."""
    return gba_apply(param_flat, accum_flat, buffer, tokens, step, lr,
                     iota=iota, eps=eps, interpret=runtime.resolve(interpret))


def quantize_wire(payload: jax.Array, *, tile: int, mode: str,
                  interpret: bool | None = None):
    """Quantize a routing payload with fused error feedback.

    ``mode="minmax"`` -> ``(qvals, scale, zero, residual)``;
    ``mode="sign"``   -> ``(qvals, scale, residual)`` (no zero-point).
    See ``repro.kernels.quantize``.
    """
    itp = runtime.resolve(interpret)
    if mode == "minmax":
        return quantize_minmax(payload, tile=tile, interpret=itp)
    if mode == "sign":
        return quantize_sign(payload, tile=tile, interpret=itp)
    raise ValueError(f"unknown quantize mode {mode!r}")


def dequantize_wire(qvals: jax.Array, scale: jax.Array,
                    zero: jax.Array | None = None, *, tile: int, mode: str,
                    interpret: bool | None = None) -> jax.Array:
    """Reconstruct the f32 payload from routed wire arrays (see
    ``repro.kernels.quantize.dequantize``)."""
    return dequantize(qvals, scale, zero, tile=tile, mode=mode,
                      interpret=runtime.resolve(interpret))


def adagrad_apply_tree(params: Any, grads: Any, accums: Any, lr, *,
                       interpret: bool | None = None) -> tuple[Any, Any]:
    """Fused Adagrad over a pytree (flattening each leaf to 1-D)."""
    itp = runtime.resolve(interpret)

    def per_leaf(p, g, a):
        np_, na = fused_adagrad(p.reshape(-1), g.reshape(-1), a.reshape(-1),
                                lr, interpret=itp)
        return np_.reshape(p.shape), na.reshape(a.shape)

    out = jax.tree.map(per_leaf, params, grads, accums)
    is2 = lambda t: isinstance(t, tuple)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=is2)
    new_a = jax.tree.map(lambda t: t[1], out, is_leaf=is2)
    return new_p, new_a


def pooled_lookup(ids: jax.Array, table: jax.Array, *,
                  block_v: int | None = None, block_d: int | None = None,
                  chunk_e: int | None = None,
                  interpret: bool | None = None) -> jax.Array:
    """Streamed pooled lookup: the (V, D) table stays in HBM; VMEM holds
    O(block_v * block_d + chunk_e * block_d) scratch regardless of V."""
    kernel_calls["pooled_lookup"] += 1
    return embedding_bag(ids, table, block_v=block_v, block_d=block_d,
                         chunk_e=chunk_e,
                         interpret=runtime.resolve(interpret))


def pooled_lookup_grad(ids: jax.Array, grad_out: jax.Array, capacity: int,
                       *, block_v: int | None = None,
                       block_d: int | None = None,
                       chunk_e: int | None = None,
                       interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Streamed sorted-scatter backward with per-ID contributor counts."""
    kernel_calls["pooled_lookup_grad"] += 1
    return embedding_bag_grad(ids, grad_out, capacity, block_v=block_v,
                              block_d=block_d, chunk_e=chunk_e,
                              interpret=runtime.resolve(interpret))
