"""Interpret-mode resolution shared by every kernel wrapper.

The Pallas kernels take an ``interpret=`` flag; what it should default to
depends on where the process runs: CPU/GPU containers (this repo's test
environment) must interpret, real TPUs must compile.  Hard-coding ``True``
(the pre-PR-2 state) silently interpreted on real TPUs.  Resolution order:

1. an explicit per-call ``interpret=`` override (never resolved here),
2. ``set_interpret(...)`` — programmatic override for launch scripts,
3. the ``REPRO_INTERPRET`` env var (``0``/``false``/``off`` compile,
   anything else interprets),
4. the platform: ``jax.default_backend() != "tpu"``.

The platform probe is deferred to first use so importing kernel modules
never initializes the JAX backend.
"""
from __future__ import annotations

import os

_TRUTHY_OFF = ("0", "false", "no", "off", "")

_INTERPRET: bool | None = None


def default_interpret() -> bool:
    """Environment/platform default, ignoring any set_interpret override."""
    env = os.environ.get("REPRO_INTERPRET")
    if env is not None:
        return env.strip().lower() not in _TRUTHY_OFF
    import jax
    return jax.default_backend() != "tpu"


def interpret_mode() -> bool:
    """The session-wide interpret default (cached after first resolution)."""
    global _INTERPRET
    if _INTERPRET is None:
        _INTERPRET = default_interpret()
    return _INTERPRET


def set_interpret(value: bool | None) -> None:
    """Force interpret mode on/off; ``None`` re-enables auto-resolution."""
    global _INTERPRET
    _INTERPRET = value


def resolve(override: bool | None) -> bool:
    """Per-call resolution: explicit override wins, else the session mode."""
    return interpret_mode() if override is None else override
