"""Pallas TPU kernels: wire quantization for the fused-psum routing stage.

The layer-grouped fused-psum schedule (``core.gba_shard_map``) routes
each group's ``(M, group_shard)`` gradient block through an
``all_to_all``.  These kernels transform that block at the wire boundary
so the payload travels as int8 instead of f32:

``quantize_minmax``
    Bagua ``MinMaxUInt8`` idiom, per ``tile``-aligned slice of each row
    (the same tile the layout aligns shard slices to):
    ``zero_point = min``, ``scale = (max - min) / 255``, code =
    ``round((x - zp) / scale)`` in [0, 255] stored as int8 (code - 128).
``quantize_sign``
    1-bit idiom: ``sign(x)`` as int8 with a per-tile mean-|x| norm as
    the single f32 sideband word.

Both quantizers emit the **error-feedback residual**
``payload - dequantize(quantize(payload))`` in the same VMEM pass — the
payload and its dequantized image are both already in VMEM, so error
feedback costs no extra launch and no extra HBM round-trip, and the
residual is bit-exactly consistent with what ``dequantize`` reconstructs
on the receiving shard (identical arithmetic, identical sideband).

Per-tile scale/zero sidebands are ``(R, n_tiles)`` f32 arrays held fully
VMEM-resident across the grid (constant index map — they are ~1/tile of
the payload) while the payload streams through ``(R, tile)`` blocks; each
grid step writes its own sideband column with a dynamic ``pl.ds`` store.
Every launch exports a :class:`~repro.kernels.launch_meta.LaunchMeta`
the real ``pallas_call`` builds its specs from, so the static auditor
(``repro.analysis``) checks tiles/VMEM/grid of the launch that runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.launch_meta import BlockMeta, LaunchMeta, block_specs

MODES = ("minmax", "sign")


def _check_geometry(r: int, c: int, tile: int) -> int:
    if tile < 1:
        raise ValueError(f"tile must be >= 1, got {tile}")
    if c % tile:
        raise ValueError(
            f"payload columns {c} not a multiple of tile {tile} — the "
            f"routing stage only quantizes tile-aligned group slices")
    return c // tile


def quantize_vmem_bytes(r: int, c: int, tile: int, mode: str) -> int:
    """Per-grid-step VMEM residency of a quantize launch: payload in +
    residual out f32 blocks, int8 code block, and the fully-resident
    f32 sideband(s) (scale, plus zero-point for minmax)."""
    n_tiles = _check_geometry(r, c, tile)
    sidebands = 2 if mode == "minmax" else 1
    return r * tile * 4 + r * tile * 1 + r * tile * 4 \
        + sidebands * r * n_tiles * 4


def dequant_vmem_bytes(r: int, c: int, tile: int, mode: str) -> int:
    """Per-grid-step VMEM residency of a dequantize launch: int8 code
    block + f32 out block + resident sideband(s)."""
    n_tiles = _check_geometry(r, c, tile)
    sidebands = 2 if mode == "minmax" else 1
    return r * tile * 1 + r * tile * 4 + sidebands * r * n_tiles * 4


def _sideband_blocks(r: int, n_tiles: int, names: tuple[str, ...]
                     ) -> tuple[BlockMeta, ...]:
    # constant index map: the whole (R, n_tiles) sideband stays VMEM-
    # resident across the grid; grid step i owns column i
    return tuple(BlockMeta(name, (r, n_tiles), jnp.float32, (r, n_tiles),
                           lambda i: (0, 0))
                 for name in names)


def quantize_launch_meta(r: int, c: int, tile: int, mode: str) -> LaunchMeta:
    """Static launch geometry of a ``(r, c)`` payload quantize; the real
    ``pallas_call`` builds its specs from this."""
    if mode not in MODES:
        raise ValueError(f"unknown quantize mode {mode!r}")
    n_tiles = _check_geometry(r, c, tile)
    sidebands = ("scale", "zero") if mode == "minmax" else ("scale",)
    return LaunchMeta(
        kernel=f"quantize_{mode}",
        grid=(n_tiles,),
        inputs=(
            BlockMeta("payload", (r, c), jnp.float32, (r, tile),
                      lambda i: (0, i)),
        ),
        outputs=(
            BlockMeta("qvals", (r, c), jnp.int8, (r, tile),
                      lambda i: (0, i)),
            *_sideband_blocks(r, n_tiles, sidebands),
            BlockMeta("residual", (r, c), jnp.float32, (r, tile),
                      lambda i: (0, i)),
        ),
        declared_vmem_bytes=quantize_vmem_bytes(r, c, tile, mode),
        vmem_counted=("payload", "qvals", *sidebands, "residual"),
    )


def dequant_launch_meta(r: int, c: int, tile: int, mode: str) -> LaunchMeta:
    """Static launch geometry of the matching dequantize."""
    if mode not in MODES:
        raise ValueError(f"unknown dequantize mode {mode!r}")
    n_tiles = _check_geometry(r, c, tile)
    sidebands = ("scale", "zero") if mode == "minmax" else ("scale",)
    return LaunchMeta(
        kernel=f"dequantize_{mode}",
        grid=(n_tiles,),
        inputs=(
            BlockMeta("qvals", (r, c), jnp.int8, (r, tile),
                      lambda i: (0, i)),
            *_sideband_blocks(r, n_tiles, sidebands),
        ),
        outputs=(
            BlockMeta("out", (r, c), jnp.float32, (r, tile),
                      lambda i: (0, i)),
        ),
        declared_vmem_bytes=dequant_vmem_bytes(r, c, tile, mode),
        vmem_counted=("qvals", *sidebands, "out"),
    )


def _minmax_kernel(pay_ref, q_ref, sc_ref, zp_ref, res_ref):
    i = pl.program_id(0)
    x = pay_ref[...]                                   # (R, tile) f32
    mn = jnp.min(x, axis=1, keepdims=True)             # (R, 1)
    mx = jnp.max(x, axis=1, keepdims=True)
    scale = (mx - mn) / 255.0
    safe = jnp.where(scale > 0.0, scale, 1.0)          # constant tile -> q=0
    code = jnp.clip(jnp.round((x - mn) / safe), 0.0, 255.0)
    q = (code - 128.0).astype(jnp.int8)
    q_ref[...] = q
    sc_ref[:, pl.ds(i, 1)] = scale
    zp_ref[:, pl.ds(i, 1)] = mn
    # same expression as _dequant_minmax_kernel -> residual is consistent
    # with the receiving shard's reconstruction
    deq = (q.astype(jnp.float32) + 128.0) * scale + mn
    res_ref[...] = x - deq


def _sign_kernel(pay_ref, q_ref, sc_ref, res_ref):
    i = pl.program_id(0)
    x = pay_ref[...]
    scale = jnp.mean(jnp.abs(x), axis=1, keepdims=True)
    q = jnp.where(x >= 0.0, 1, -1).astype(jnp.int8)
    q_ref[...] = q
    sc_ref[:, pl.ds(i, 1)] = scale
    deq = q.astype(jnp.float32) * scale
    res_ref[...] = x - deq


def _dequant_minmax_kernel(q_ref, sc_ref, zp_ref, out_ref):
    i = pl.program_id(0)
    scale = sc_ref[:, pl.ds(i, 1)]
    zp = zp_ref[:, pl.ds(i, 1)]
    out_ref[...] = (q_ref[...].astype(jnp.float32) + 128.0) * scale + zp


def _dequant_sign_kernel(q_ref, sc_ref, out_ref):
    i = pl.program_id(0)
    out_ref[...] = q_ref[...].astype(jnp.float32) * sc_ref[:, pl.ds(i, 1)]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def quantize_minmax(payload: jax.Array, *, tile: int, interpret: bool = True
                    ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Min-max int8 quantize with fused error feedback.

    payload: (R, C) f32, C a ``tile`` multiple ->
    ``(qvals int8 (R, C), scale f32 (R, C//tile), zero f32 (R, C//tile),
    residual f32 (R, C))`` with ``residual == payload -
    dequantize(qvals, scale, zero)`` exactly.
    """
    r, c = payload.shape
    n_tiles = _check_geometry(r, c, tile)
    meta = quantize_launch_meta(r, c, tile, "minmax")
    return pl.pallas_call(
        _minmax_kernel,
        grid=meta.grid,
        in_specs=block_specs(meta.inputs),
        out_specs=block_specs(meta.outputs),
        out_shape=[
            jax.ShapeDtypeStruct((r, c), jnp.int8),
            jax.ShapeDtypeStruct((r, n_tiles), jnp.float32),
            jax.ShapeDtypeStruct((r, n_tiles), jnp.float32),
            jax.ShapeDtypeStruct((r, c), jnp.float32),
        ],
        interpret=interpret,
    )(payload.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def quantize_sign(payload: jax.Array, *, tile: int, interpret: bool = True
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sign (1-bit) quantize with per-tile mean-|x| norm and fused error
    feedback: payload (R, C) f32 -> ``(qvals int8 ±1, scale f32
    (R, C//tile), residual f32 (R, C))``."""
    r, c = payload.shape
    n_tiles = _check_geometry(r, c, tile)
    meta = quantize_launch_meta(r, c, tile, "sign")
    return pl.pallas_call(
        _sign_kernel,
        grid=meta.grid,
        in_specs=block_specs(meta.inputs),
        out_specs=block_specs(meta.outputs),
        out_shape=[
            jax.ShapeDtypeStruct((r, c), jnp.int8),
            jax.ShapeDtypeStruct((r, n_tiles), jnp.float32),
            jax.ShapeDtypeStruct((r, c), jnp.float32),
        ],
        interpret=interpret,
    )(payload.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("tile", "mode", "interpret"))
def dequantize(qvals: jax.Array, scale: jax.Array,
               zero: jax.Array | None = None, *, tile: int, mode: str,
               interpret: bool = True) -> jax.Array:
    """Reconstruct the f32 payload from the routed wire arrays.

    qvals: (R, C) int8; scale (and, for ``mode="minmax"``, zero):
    (R, C//tile) f32 -> (R, C) f32.
    """
    r, c = qvals.shape
    _check_geometry(r, c, tile)
    if mode == "minmax":
        if zero is None:
            raise ValueError("minmax dequantize needs the zero-point array")
        kernel, operands = _dequant_minmax_kernel, (qvals, scale, zero)
    elif mode == "sign":
        kernel, operands = _dequant_sign_kernel, (qvals, scale)
    else:
        raise ValueError(f"unknown dequantize mode {mode!r}")
    meta = dequant_launch_meta(r, c, tile, mode)
    out, = pl.pallas_call(
        kernel,
        grid=meta.grid,
        in_specs=block_specs(meta.inputs),
        out_specs=block_specs(meta.outputs),
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out
