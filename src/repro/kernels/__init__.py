"""Pallas TPU kernels for the PS hot path (+ pure-jnp oracles in ref.py).

Layout of the package:

* ``embedding_bag``  — pooled lookup forward; **sorted-scatter** backward:
  the B*F (id, row) pairs are sorted by id once, per-vocab-block segment
  boundaries come from a searchsorted, and the grid runs one program per
  disjoint (BLOCK_V, D) output block — parallel, race-free, with per-ID
  contributor counts produced in the same pass (Alg. 2 line 23).
* ``gba_apply``      — the fused PS apply: token-decay aggregation over the
  flat (M, N_total) gradient buffer AND the Adagrad update in one VMEM
  pass; fed by ``repro.core.gba.FlatLayout`` (dense pytree leaves raveled
  back-to-back with an offsets table) so the whole apply is one launch.
* ``gba_aggregate``  — standalone decayed reduction (M, D) -> (D,); kept
  for tree-level use, superseded on the train path by ``gba_apply``.
* ``fused_adagrad``  — standalone one-pass Adagrad; same story.
* ``flash_decode``   — decode-time attention for the serving stack.
* ``ops``            — jit'd wrappers + the global interpret-mode switch.

Every kernel has an allclose oracle in ``ref`` and a parity sweep in
``tests/test_kernels.py``.  Remaining gaps (ROADMAP "Open items"): tables
larger than VMEM need DMA-streamed rows, and the kernels have only been
validated in interpret mode in this container, not on real TPUs.
"""
