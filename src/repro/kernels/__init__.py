"""Pallas TPU kernels for the PS hot path (+ pure-jnp oracles in ref.py).

Layout of the package:

* ``embedding_bag``  — pooled lookup forward + **sorted-scatter** backward,
  both **DMA-streamed**: the (V, D) table and the sorted (E, D) gradient
  rows live in HBM (``pltpu.ANY``) and move through double-buffered VMEM
  scratch blocks with ``pltpu.make_async_copy``, so VMEM residency is
  O(block_v * block_d + chunk_e * block_d) at any vocabulary size.  The
  B*F (id, row) pairs are sorted by id once, per-vocab-block segment
  boundaries come from a searchsorted, and the backward grid runs one
  program per disjoint (BLOCK_V, BLOCK_D) output tile — parallel,
  race-free, with per-ID contributor counts produced in the same pass
  (Alg. 2 line 23).  The PR-1 whole-array-in-VMEM backward survives as
  ``embedding_bag_grad_resident``, a bit-exactness regression oracle.
* ``gba_apply``      — the fused PS apply: token-decay aggregation over the
  flat (M, N_total) gradient buffer AND the Adagrad update in one VMEM
  pass; fed by ``repro.core.gba.FlatLayout`` (dense pytree leaves raveled
  back-to-back with an offsets table) so the whole apply is one launch.
* ``gba_aggregate``  — standalone decayed reduction (M, D) -> (D,); kept
  for tree-level use, superseded on the train path by ``gba_apply``.
* ``fused_adagrad``  — standalone one-pass Adagrad; same story.
* ``flash_decode``   — decode-time attention for the serving stack.
* ``ops``            — jit'd wrappers with per-call ``interpret=`` control.
* ``runtime``        — interpret-mode resolution (platform default, env
  var ``REPRO_INTERPRET``, ``set_interpret``).

Every kernel has an allclose oracle in ``ref`` and a parity sweep in
``tests/test_kernels.py`` (+ ``tests/test_embedding_stream.py`` for the
streamed paths).  Remaining gap (ROADMAP "Open items"): the kernels have
only been validated in interpret mode in this container, not on real TPUs.
"""
