"""Pallas TPU kernel: blocked decode attention (beyond-paper).

One new token attends to a long KV cache (decode_32k / long_500k shapes).
The naive XLA lowering materializes the full (H, L) score row in f32 and
reads it three times (max, exp-sum, weighted sum).  This kernel streams the
cache in (BLOCK_L) chunks with an online-softmax accumulator held in VMEM
scratch — one HBM pass over K and V, which is the roofline for decode.

Grid: (B, L/BLOCK_L); the L dimension is sequential ("arbitrary") so the
scratch accumulators carry across cache blocks; batch is parallel.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.launch_meta import (BlockMeta, LaunchMeta, ScratchMeta,
                                       block_specs, scratch_shapes)

BLOCK_L = 512


def decode_vmem_bytes(kv: int, g: int, hd: int, l: int,
                      itemsize: int = 4) -> int:
    """Per-grid-step VMEM residency: q + output blocks, two (blk, KV, hd)
    cache blocks, and the f32 online-softmax accumulators."""
    blk = min(BLOCK_L, l)
    return ((2 * kv * g * hd + 2 * blk * kv * hd) * itemsize
            + (2 * kv * g + kv * g * hd) * 4)


def launch_meta(b: int, l: int, kv: int, g: int, hd: int,
                dtype=jnp.float32) -> LaunchMeta:
    """Static launch geometry for a (B, KV, G, hd) x (B, L, KV, hd)
    decode; the pallas_call builds its specs and scratch from this."""
    blk = min(BLOCK_L, l)
    return LaunchMeta(
        kernel="flash_decode",
        grid=(b, l // blk),
        num_scalar_prefetch=1,
        inputs=(
            BlockMeta("q", (b, kv, g, hd), dtype, (1, kv, g, hd),
                      lambda bi, j, *_: (bi, 0, 0, 0)),
            BlockMeta("k", (b, l, kv, hd), dtype, (1, blk, kv, hd),
                      lambda bi, j, *_: (bi, j, 0, 0)),
            BlockMeta("v", (b, l, kv, hd), dtype, (1, blk, kv, hd),
                      lambda bi, j, *_: (bi, j, 0, 0)),
        ),
        outputs=(
            BlockMeta("o", (b, kv, g, hd), dtype, (1, kv, g, hd),
                      lambda bi, j, *_: (bi, 0, 0, 0)),
        ),
        scratch=(
            ScratchMeta("m_scratch", (kv, g), jnp.float32),
            ScratchMeta("l_scratch", (kv, g), jnp.float32),
            ScratchMeta("acc_scratch", (kv, g, hd), jnp.float32),
        ),
        declared_vmem_bytes=decode_vmem_bytes(
            kv, g, hd, l, jnp.dtype(dtype).itemsize),
        vmem_counted=("q", "k", "v", "o", "m_scratch", "l_scratch",
                      "acc_scratch"),
    )


def _compiler_params():
    """jax renamed TPUCompilerParams -> CompilerParams across versions;
    fall back to no params (compiler defaults) rather than crashing when
    neither name exists."""
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:
        return None
    return cls(dimension_semantics=("parallel", "arbitrary"))


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, blk: int):
    j = pl.program_id(1)
    nblk = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (KV, G, hd)
    k = k_ref[0].astype(jnp.float32)            # (BLK, KV, hd)
    v = v_ref[0].astype(jnp.float32)
    hd = q.shape[-1]
    scores = jnp.einsum("ngh,lnh->ngl", q, k) / math.sqrt(hd)
    # causal/validity mask: absolute cache index <= pos
    idx = j * blk + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 2)
    scores = jnp.where(idx <= pos_ref[0], scores, -1e30)

    m_prev = m_ref[...]                          # (KV, G)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new[..., None])       # (KV, G, BLK)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = (acc_ref[...] * alpha[..., None]
                    + jnp.einsum("ngl,lnh->ngh", p, v))
    m_ref[...] = m_new

    @pl.when(j == nblk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / l_ref[...][..., None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array,
                 *, interpret: bool = True) -> jax.Array:
    """q: (B, KV, G, hd) one-token queries grouped by kv head;
    k/v: (B, L, KV, hd) cache; pos: scalar int32 (last valid index).
    Returns (B, KV, G, hd)."""
    B, KV, G, hd = q.shape
    L = k.shape[1]
    blk = min(BLOCK_L, L)
    assert L % blk == 0
    meta = launch_meta(B, L, KV, G, hd, q.dtype)
    out = pl.pallas_call(
        functools.partial(_kernel, blk=blk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=meta.num_scalar_prefetch,
            grid=meta.grid,
            in_specs=block_specs(meta.inputs),
            out_specs=block_specs(meta.outputs)[0],
            scratch_shapes=scratch_shapes(meta.scratch),
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32).reshape(1), q, k, v)
    return out
