"""Pallas TPU kernel: fused staleness-decay gradient aggregation.

The PS-side hot loop of GBA (Alg. 2 lines 20/22): given the M-slot gradient
buffer ``(M, D)``, the slot tokens ``(M,)`` and the current global step,
compute ``sum_m f(token_m, k) * g_m / M`` — decay mask, weighting and
reduction in one VMEM pass instead of XLA's mask -> broadcast-mul -> reduce
chain (3x HBM traffic on the buffer).

TPU adaptation: the buffer is tiled along D into ``(M, BLOCK_D)`` VMEM
blocks (M is small — 8..100 — so a full buffer column always fits VMEM);
tokens ride in SMEM via ``PrefetchScalarGridSpec`` so the mask is computed
on the scalar core before the vector pass.

NOTE: the train path now prefers ``repro.kernels.gba_apply``, which fuses
this reduction WITH the Adagrad update so the aggregated gradient never
round-trips through HBM; this standalone kernel remains for tree-level
aggregation (``ops.gba_aggregate_tree``) and non-Adagrad optimizers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.launch_meta import (BlockMeta, LaunchMeta, block_specs,
                                       _round_up_static)

BLOCK_D = 2048


def aggregate_vmem_bytes(m: int, block_d: int = BLOCK_D,
                         itemsize: int = 4) -> int:
    """Per-grid-step VMEM residency: the (m, BLOCK_D) buffer block plus
    the (BLOCK_D,) output block, in the buffer dtype."""
    return (m + 1) * block_d * itemsize


def launch_meta(d: int, m: int, dtype=jnp.float32) -> LaunchMeta:
    """Static launch geometry for an (m, d)-buffer aggregate; the
    pallas_call builds its specs from this."""
    d_pad = _round_up_static(d, BLOCK_D)
    itemsize = jnp.dtype(dtype).itemsize
    return LaunchMeta(
        kernel="gba_aggregate",
        grid=(d_pad // BLOCK_D,),
        num_scalar_prefetch=3,
        inputs=(
            BlockMeta("grads", (m, d_pad), dtype, (m, BLOCK_D),
                      lambda i, *_: (0, i)),
        ),
        outputs=(
            BlockMeta("out", (d_pad,), dtype, (BLOCK_D,),
                      lambda i, *_: (i,)),
        ),
        declared_vmem_bytes=aggregate_vmem_bytes(m, BLOCK_D, itemsize),
        vmem_counted=("grads", "out"),
    )


def _kernel(tokens_ref, step_ref, iota_ref, grads_ref, out_ref):
    """grads_ref: (M, BLOCK_D) VMEM block; tokens/step/iota in SMEM."""
    m = grads_ref.shape[0]
    tokens = tokens_ref[...]                       # (M,) int32
    step = step_ref[0]
    iota = iota_ref[0]
    keep = (step - tokens) <= iota                 # Eq. (1)
    w = keep.astype(jnp.float32) / jnp.float32(m)
    g = grads_ref[...].astype(jnp.float32)         # (M, BLOCK_D)
    out_ref[...] = jnp.sum(g * w[:, None], axis=0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("iota", "interpret"))
def gba_aggregate(grads: jax.Array, tokens: jax.Array, step: jax.Array,
                  *, iota: int, interpret: bool = True) -> jax.Array:
    """grads: (M, D) -> (D,) decayed mean.  ``interpret=True`` runs the
    kernel body on CPU (this container); pass False on real TPUs."""
    m, d = grads.shape
    pad = (-d) % BLOCK_D
    if pad:
        grads = jnp.pad(grads, ((0, 0), (0, pad)))
    d_pad = d + pad
    meta = launch_meta(d, m, grads.dtype)

    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=meta.num_scalar_prefetch,
            grid=meta.grid,
            in_specs=block_specs(meta.inputs),
            out_specs=block_specs(meta.outputs)[0],
        ),
        out_shape=jax.ShapeDtypeStruct((d_pad,), grads.dtype),
        interpret=interpret,
    )(tokens.astype(jnp.int32),
      jnp.asarray(step, jnp.int32).reshape(1),
      jnp.full((1,), iota, jnp.int32),
      grads)
    return out[:d]
