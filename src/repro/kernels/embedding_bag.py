"""Pallas TPU kernels: pooled hash-embedding lookup + sorted-scatter grad.

The compute hot-spot of the paper's recommendation workloads is the sparse
module: per-batch gather of F rows per example (forward) and the per-ID
normalized scatter-add (backward, Alg. 2 line 23).

TPU adaptation (DESIGN.md §2): instead of the PS's host-side hash lookup we
tile the batch over the grid and keep the table in VMEM blocks (tables are
model-axis sharded, so per-core slices are VMEM-sized for the scaled
configs; production tables would stream rows by DMA — noted, not modeled).

* forward: grid over batch blocks; each program gathers F rows per example
  and sum-pools them: ids (Bblk, F) + table (V, D) -> out (Bblk, D).

* backward: **sort-based segment reduce** instead of a serial scatter.
  Scatter targets collide, so a naive grid over (batch x field) would race
  on the output rows.  We instead sort the B*F (id, row) pairs by id ONCE
  on the host side of the kernel (XLA sort), compute per-vocab-block
  segment boundaries with a searchsorted, and grid over vocab blocks: each
  program owns a disjoint (BLOCK_V, D) slice of the gradient table and
  consumes only its own contiguous run of sorted entries, so there are no
  races and the grid is fully parallel.  Within a program the run is
  processed in CHUNK_E-sized chunks as a one-hot matmul
  (CHUNK_E, BLOCK_V)^T @ (CHUNK_E, D) — MXU-shaped, not element-at-a-time —
  and the per-ID contributor counts fall out of the same one-hot reduction
  in the same pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_B = 256
BLOCK_V = 512      # vocab rows owned by one backward program
CHUNK_E = 256      # sorted (id, row) entries consumed per inner step


def _fwd_kernel(ids_ref, table_ref, out_ref):
    """ids: (BLOCK_B, F) int32; table: (V, D); out: (BLOCK_B, D)."""
    f = ids_ref.shape[1]

    def body(j, acc):
        rows = table_ref[ids_ref[:, j], :]         # (BLOCK_B, D) gather
        return acc + rows.astype(jnp.float32)

    acc = jax.lax.fori_loop(
        0, f, body, jnp.zeros(out_ref.shape, jnp.float32))
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(ids: jax.Array, table: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    """ids: (B, F) int32, table: (V, D) -> pooled (B, D)."""
    b, f = ids.shape
    v, d = table.shape
    pad = (-b) % BLOCK_B
    if pad:
        ids = jnp.pad(ids, ((0, pad), (0, 0)))
    bp = b + pad
    out = pl.pallas_call(
        _fwd_kernel,
        grid=(bp // BLOCK_B,),
        in_specs=[
            pl.BlockSpec((BLOCK_B, f), lambda i: (i, 0)),
            pl.BlockSpec((v, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, d), table.dtype),
        interpret=interpret,
    )(ids, table)
    return out[:b]


def _bwd_kernel(offsets_ref, ids_ref, rows_ref, gtable_ref, counts_ref):
    """Segment reduce for one vocab block.

    offsets_ref: (nblocks+1,) SMEM — run boundaries in the sorted arrays
    ids_ref:     (E_pad,)  sorted ids
    rows_ref:    (E_pad, D) gradient rows in sorted-id order
    gtable_ref:  (BLOCK_V, D) output block owned exclusively by this program
    counts_ref:  (BLOCK_V,)   contributor counts for the same rows
    """
    i = pl.program_id(0)
    v0 = i * BLOCK_V
    start = offsets_ref[i]
    end = offsets_ref[i + 1]
    d = rows_ref.shape[1]
    vids = v0 + jax.lax.broadcasted_iota(jnp.int32, (CHUNK_E, BLOCK_V), 1)

    def body(c, carry):
        acc, cnt = carry
        p0 = start + c * CHUNK_E
        idx = ids_ref[pl.ds(p0, CHUNK_E)]                     # (CHUNK_E,)
        rows = rows_ref[pl.ds(p0, CHUNK_E), :].astype(jnp.float32)
        pos = p0 + jax.lax.broadcasted_iota(jnp.int32, (CHUNK_E, 1),
                                            0)[:, 0]
        valid = pos < end
        onehot = ((idx[:, None] == vids)
                  & valid[:, None]).astype(jnp.float32)       # (E, V)
        acc = acc + jax.lax.dot_general(
            onehot, rows, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (V, D)
        cnt = cnt + jnp.sum(onehot, axis=0)
        return acc, cnt

    nchunks = (end - start + CHUNK_E - 1) // CHUNK_E
    acc, cnt = jax.lax.fori_loop(
        0, nchunks, body,
        (jnp.zeros((BLOCK_V, d), jnp.float32),
         jnp.zeros((BLOCK_V,), jnp.float32)))
    gtable_ref[...] = acc
    counts_ref[...] = cnt


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def embedding_bag_grad(ids: jax.Array, grad_out: jax.Array, capacity: int,
                       *, interpret: bool = True
                       ) -> tuple[jax.Array, jax.Array]:
    """Scatter grads back to rows with per-ID contributor counts.

    ids: (B, F); grad_out: (B, D) -> (grad_table (V, D), counts (V,)).

    Sort once, then reduce disjoint segments in parallel over the grid —
    see the module docstring for the design.
    """
    b, f = ids.shape
    d = grad_out.shape[1]
    e = b * f
    flat_ids = ids.reshape(-1).astype(jnp.int32)
    order = jnp.argsort(flat_ids)
    sorted_ids = flat_ids[order]
    sorted_rows = grad_out[order // f]                        # (E, D)

    cap_pad = capacity + ((-capacity) % BLOCK_V)
    nblocks = cap_pad // BLOCK_V
    boundaries = jnp.arange(nblocks + 1, dtype=jnp.int32) * BLOCK_V
    offsets = jnp.searchsorted(sorted_ids, boundaries).astype(jnp.int32)

    # pad so the CHUNK_E-wide dynamic slices never run off the end; the
    # sentinel id cap_pad matches no block and is masked out anyway
    e_pad = e + ((-e) % CHUNK_E) + CHUNK_E
    sorted_ids = jnp.pad(sorted_ids, (0, e_pad - e),
                         constant_values=cap_pad)
    sorted_rows = jnp.pad(sorted_rows, ((0, e_pad - e), (0, 0)))

    gtable, counts = pl.pallas_call(
        _bwd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nblocks,),
            in_specs=[
                pl.BlockSpec((e_pad,), lambda i, *_: (0,)),
                pl.BlockSpec((e_pad, d), lambda i, *_: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((BLOCK_V, d), lambda i, *_: (i, 0)),
                pl.BlockSpec((BLOCK_V,), lambda i, *_: (i,)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((cap_pad, d), jnp.float32),
            jax.ShapeDtypeStruct((cap_pad,), jnp.float32),
        ],
        interpret=interpret,
    )(offsets, sorted_ids, sorted_rows)
    return gtable[:capacity], counts[:capacity]
