"""Pallas TPU kernels: pooled hash-embedding lookup + scatter gradient.

The compute hot-spot of the paper's recommendation workloads is the sparse
module: per-batch gather of F rows per example (forward) and the per-ID
normalized scatter-add (backward, Alg. 2 line 23).

TPU adaptation (DESIGN.md §2): instead of the PS's host-side hash lookup we
tile the batch over the grid and keep the table in VMEM blocks (tables are
model-axis sharded, so per-core slices are VMEM-sized for the scaled
configs; production tables would stream rows by DMA — noted, not modeled).

* forward: grid over batch blocks; each program gathers F rows per example
  and sum-pools them: ids (Bblk, F) + table (V, D) -> out (Bblk, D).
* backward: scatter-add with contributor counts — a single-program serial
  kernel (scatter targets collide, so parallelizing over the grid would
  race; the TPU-native answer is one sequential vector pass, which is also
  how the PS applies its buffer).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 256


def _fwd_kernel(ids_ref, table_ref, out_ref):
    """ids: (BLOCK_B, F) int32; table: (V, D); out: (BLOCK_B, D)."""
    f = ids_ref.shape[1]

    def body(j, acc):
        rows = table_ref[ids_ref[:, j], :]         # (BLOCK_B, D) gather
        return acc + rows.astype(jnp.float32)

    acc = jax.lax.fori_loop(
        0, f, body, jnp.zeros(out_ref.shape, jnp.float32))
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(ids: jax.Array, table: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    """ids: (B, F) int32, table: (V, D) -> pooled (B, D)."""
    b, f = ids.shape
    v, d = table.shape
    pad = (-b) % BLOCK_B
    if pad:
        ids = jnp.pad(ids, ((0, pad), (0, 0)))
    bp = b + pad
    out = pl.pallas_call(
        _fwd_kernel,
        grid=(bp // BLOCK_B,),
        in_specs=[
            pl.BlockSpec((BLOCK_B, f), lambda i: (i, 0)),
            pl.BlockSpec((v, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, d), table.dtype),
        interpret=interpret,
    )(ids, table)
    return out[:b]


def _bwd_kernel(ids_ref, gout_ref, gtable_ref, counts_ref):
    """Serial scatter-add: grad_out (B, D), ids (B, F) ->
    grad_table (V, D), counts (V,)."""
    b, f = ids_ref.shape
    gtable_ref[...] = jnp.zeros_like(gtable_ref)
    counts_ref[...] = jnp.zeros_like(counts_ref)

    def body(i, _):
        bi = i // f
        fi = i % f
        idx = ids_ref[bi, fi]
        row = gout_ref[bi, :].astype(jnp.float32)
        gtable_ref[idx, :] += row.astype(gtable_ref.dtype)
        counts_ref[idx] += jnp.float32(1.0)
        return 0

    jax.lax.fori_loop(0, b * f, body, 0)


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def embedding_bag_grad(ids: jax.Array, grad_out: jax.Array, capacity: int,
                       *, interpret: bool = True
                       ) -> tuple[jax.Array, jax.Array]:
    """Scatter grads back to rows with per-ID contributor counts.

    ids: (B, F); grad_out: (B, D) -> (grad_table (V, D), counts (V,))."""
    b, f = ids.shape
    d = grad_out.shape[1]
    gtable, counts = pl.pallas_call(
        _bwd_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((b, f), lambda i: (0, 0)),
            pl.BlockSpec((b, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((capacity, d), lambda i: (0, 0)),
            pl.BlockSpec((capacity,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((capacity, d), jnp.float32),
            jax.ShapeDtypeStruct((capacity,), jnp.float32),
        ],
        interpret=interpret,
    )(ids, grad_out)
    return gtable, counts
