"""Pallas TPU kernels: pooled hash-embedding lookup + sorted-scatter grad,
with HBM-resident tables and double-buffered DMA block streaming.

The compute hot-spot of the paper's recommendation workloads is the sparse
module: per-batch gather of F rows per example (forward) and the per-ID
normalized scatter-add (backward, Alg. 2 line 23).  Production vocabularies
(10^6-10^8 hashed IDs) never fit a ``(V, D)`` VMEM block, so both kernels
keep the big arrays in HBM (``pltpu.ANY`` memory space) and stream
fixed-size blocks through a 2-deep VMEM scratch pipeline with
``pltpu.make_async_copy``: the DMA of block ``c+1`` overlaps the compute of
block ``c``, and the VMEM footprint is O(block) — independent of the
vocabulary size ``V`` and the entry count ``E = B*F``.

* forward: the B*F (id, batch_row) entries are sorted by id ONCE on the
  XLA side and bucketed into ``BLOCK_V``-row vocab blocks (searchsorted
  segment offsets — the same sort machinery the backward uses).  A
  precomputed (block, chunk) step schedule drives one fused pipeline per
  ``BLOCK_D`` output tile: each step DMAs the next ``(BLOCK_V, BLOCK_D)``
  table tile (only when the block changes — empty blocks are never
  streamed) and the next ``CHUNK_E`` entry chunk, then pools the current
  chunk into the ``(B, BLOCK_D)`` accumulator as two MXU matmuls
  (gather-as-matmul ``(E, V_blk) @ (V_blk, D_blk)`` followed by the
  batch-row scatter ``(E, B)^T @ (E, D_blk)``) — no dynamic VMEM gathers.
  The D tiling is the forward's only parallel grid axis; vocab blocks run
  serially inside a program, hidden behind the DMA overlap — the kernel is
  HBM-bound, so the pipeline, not program count, is the throughput lever
  (the bench rows record ``grid_programs`` to keep this visible).

* backward: **sort-based segment reduce** over disjoint ``(BLOCK_V,
  BLOCK_D)`` output tiles (grid = vocab blocks x D blocks, race-free,
  fully parallel).  Each program streams its contiguous run of sorted
  (id, row) entries in ``CHUNK_E``-sized chunks through the double
  buffer and reduces them as a one-hot matmul
  ``(CHUNK_E, BLOCK_V)^T @ (CHUNK_E, BLOCK_D)``; per-ID contributor
  counts (Alg. 2 line 23) fall out of the same one-hot reduction.

Batch rows the caller padded (and any other out-of-range id) are mapped to
a sentinel id ``>= V_pad`` that sorts past the last block boundary, so they
issue no DMA traffic at all — previously they gathered row 0.

``embedding_bag_grad_resident`` keeps the PR-1 whole-array-in-VMEM
backward as a regression oracle: the streamed kernel reproduces it
bit-for-bit on the old (VMEM-sized) configs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import runtime
from repro.kernels.launch_meta import (ANY, BlockMeta, LaunchMeta,
                                       ScratchMeta, block_specs,
                                       scratch_shapes)

BLOCK_V = 512      # vocab rows per streamed table tile / backward out block
CHUNK_E = 256      # sorted (id, row) entries consumed per pipeline step
BLOCK_D = 128      # embedding columns per output tile (wide-D streaming)


def _round_up(x: int, m: int) -> int:
    return x + (-x) % m


def _block_d(d: int, block_d: int) -> int:
    """Effective D tile: no padding for narrow tables (keeps the streamed
    backward bit-identical to the resident kernel), BLOCK_D tiles else."""
    return d if d <= block_d else block_d


def stream_vmem_bytes(d: int, *, table_itemsize: int = 4,
                      row_itemsize: int = 4, block_v: int = BLOCK_V,
                      block_d: int = BLOCK_D, chunk_e: int = CHUNK_E
                      ) -> dict[str, int]:
    """Derived VMEM residency of the streamed pipelines (double-buffered
    scratch only — the V- and E-sized arrays stay in HBM).  This is the
    block-bounded footprint the bench rows record as ``vmem_bytes``."""
    bd = _block_d(d, block_d)
    return {
        # 2 table tiles + 2 (id, batch_row) entry chunks
        "fwd": 2 * block_v * bd * table_itemsize + 2 * 2 * chunk_e * 4,
        # 2 gradient-row chunks + 2 id chunks
        "bwd": 2 * chunk_e * bd * row_itemsize + 2 * chunk_e * 4,
        "block_d": bd,
    }


def _entry_pad(e: int, chunk_e: int) -> int:
    """Padded sorted-entry length: ``chunk_e``-wide slices never run off
    the end (mirrors ``_sorted_entries``)."""
    return e + ((-e) % chunk_e) + chunk_e


def fwd_launch_meta(b: int, f: int, v: int, d: int, table_dtype=jnp.float32,
                    *, block_v: int = BLOCK_V, block_d: int = BLOCK_D,
                    chunk_e: int = CHUNK_E) -> LaunchMeta:
    """Static launch geometry of the streamed forward: the V- and E-sized
    arrays are ANY (HBM) operands, VMEM holds only the double-buffered
    tile/entry scratch plus the (B, BLOCK_D) output tile.  The kernel
    builds its specs and VMEM scratch from this meta."""
    bd = _block_d(d, block_d)
    d_pad = _round_up(d, bd)
    v_rows = max(v, block_v)
    e_pad = _entry_pad(b * f, chunk_e)
    bp = _round_up(b, 8)
    vm = stream_vmem_bytes(d, table_itemsize=jnp.dtype(table_dtype).itemsize,
                           block_v=block_v, block_d=block_d, chunk_e=chunk_e)
    return LaunchMeta(
        kernel="embedding_bag_fwd",
        grid=(d_pad // bd,),
        num_scalar_prefetch=4,
        inputs=(
            BlockMeta("entries", (2, e_pad), jnp.int32, memory_space=ANY),
            BlockMeta("table", (v_rows, d_pad), table_dtype,
                      memory_space=ANY),
        ),
        outputs=(
            BlockMeta("out", (bp, d_pad), table_dtype, (bp, bd),
                      lambda j, *_: (0, j)),
        ),
        scratch=(
            ScratchMeta("tile_buf", (2, block_v, bd), table_dtype),
            ScratchMeta("ent_buf", (2, 2, chunk_e), jnp.int32),
        ),
        declared_vmem_bytes=vm["fwd"],
        vmem_counted=("tile_buf", "ent_buf"),
    )


def bwd_launch_meta(b: int, f: int, v: int, d: int, row_dtype=jnp.float32,
                    *, block_v: int = BLOCK_V, block_d: int = BLOCK_D,
                    chunk_e: int = CHUNK_E) -> LaunchMeta:
    """Static launch geometry of the sorted-scatter backward: grid =
    (vocab blocks x D blocks), each program owns one disjoint
    (BLOCK_V, BLOCK_D) output tile and streams its sorted run through the
    double-buffered chunk scratch."""
    bd = _block_d(d, block_d)
    d_pad = _round_up(d, bd)
    cap_pad = _round_up(v, block_v)
    e_pad = _entry_pad(b * f, chunk_e)
    vm = stream_vmem_bytes(d, row_itemsize=jnp.dtype(row_dtype).itemsize,
                           block_v=block_v, block_d=block_d, chunk_e=chunk_e)
    return LaunchMeta(
        kernel="embedding_bag_bwd",
        grid=(cap_pad // block_v, d_pad // bd),
        num_scalar_prefetch=1,
        inputs=(
            BlockMeta("sorted_ids", (e_pad,), jnp.int32, memory_space=ANY),
            BlockMeta("sorted_rows", (e_pad, d_pad), row_dtype,
                      memory_space=ANY),
        ),
        outputs=(
            BlockMeta("gtable", (cap_pad, d_pad), jnp.float32,
                      (block_v, bd), lambda i, j, *_: (i, j)),
            BlockMeta("counts", (cap_pad,), jnp.float32, (block_v,),
                      lambda i, j, *_: (i,)),
        ),
        scratch=(
            ScratchMeta("ids_buf", (2, chunk_e), jnp.int32),
            ScratchMeta("rows_buf", (2, chunk_e, bd), row_dtype),
        ),
        declared_vmem_bytes=vm["bwd"],
        vmem_counted=("ids_buf", "rows_buf"),
    )


# ---------------------------------------------------------------------------
# shared XLA-side sort machinery
# ---------------------------------------------------------------------------

def _sorted_entries(ids: jax.Array, capacity: int, block_v: int,
                    chunk_e: int):
    """Bucket the B*F flat ids into ``block_v``-row sorted runs.

    Returns ``(sorted_ids, order, offsets, cap_pad, nvb)``: ids sorted and
    padded so ``chunk_e``-wide slices never run off the end, the argsort
    permutation (for gathering per-entry payloads), and per-block run
    boundaries.  Out-of-range ids — including any batch padding the caller
    added — map to the sentinel ``cap_pad``, which sorts past the last
    block boundary: no run contains them, no DMA ever moves their payload.
    """
    e = ids.size
    flat = ids.reshape(-1).astype(jnp.int32)
    cap_pad = _round_up(capacity, block_v)
    flat = jnp.where((flat >= 0) & (flat < capacity), flat, cap_pad)
    order = jnp.argsort(flat)
    sorted_ids = flat[order]
    nvb = cap_pad // block_v
    boundaries = jnp.arange(nvb + 1, dtype=jnp.int32) * block_v
    offsets = jnp.searchsorted(sorted_ids, boundaries).astype(jnp.int32)
    e_pad = e + ((-e) % chunk_e) + chunk_e
    sorted_ids = jnp.pad(sorted_ids, (0, e_pad - e),
                         constant_values=cap_pad)
    return sorted_ids, order, offsets, cap_pad, nvb


# ---------------------------------------------------------------------------
# forward: streamed pooled lookup
# ---------------------------------------------------------------------------

def _fwd_kernel(nsteps_ref, offsets_ref, sblk_ref, sp0_ref,
                entries_hbm, table_hbm, out_ref,
                tile_buf, ent_buf, tile_sem, ent_sem, *,
                block_v: int, chunk_e: int):
    """One fused (tile-DMA | entry-DMA | pool) pipeline per D tile.

    nsteps_ref:  (1,) SMEM       — live steps in the schedule
    offsets_ref: (nvb+1,) SMEM   — sorted-run boundaries per vocab block
    sblk_ref:    (S,) SMEM       — vocab block of each pipeline step
    sp0_ref:     (S,) SMEM       — absolute entry offset of each step
    entries_hbm: (2, E_pad) HBM  — row 0 sorted ids, row 1 batch rows
    table_hbm:   (V_pad, D_pad) HBM
    out_ref:     (B_pad, BLOCK_D) VMEM output tile
    tile_buf:    (2, BLOCK_V, BLOCK_D) VMEM — double-buffered table tiles
    ent_buf:     (2, 2, CHUNK_E) VMEM       — double-buffered entry chunks
    """
    j = pl.program_id(0)
    n = nsteps_ref[0]
    bp, bd = out_ref.shape
    v_rows = table_hbm.shape[0]

    def tile_start(blk):
        # the last block's tile is clamped instead of padding the table:
        # its run only holds ids in [blk*block_v, v), all >= the clamped
        # start, so the local one-hot still matches exactly
        return jnp.minimum(blk * block_v, v_rows - block_v)

    def tile_dma(slot, blk):
        return pltpu.make_async_copy(
            table_hbm.at[pl.ds(tile_start(blk), block_v), pl.ds(j * bd, bd)],
            tile_buf.at[slot], tile_sem.at[slot])

    def ent_dma(slot, p0):
        return pltpu.make_async_copy(
            entries_hbm.at[:, pl.ds(p0, chunk_e)],
            ent_buf.at[slot], ent_sem.at[slot])

    @pl.when(n > 0)
    def _():
        tile_dma(0, sblk_ref[0]).start()
        ent_dma(0, sp0_ref[0]).start()

    vids = jax.lax.broadcasted_iota(jnp.int32, (chunk_e, block_v), 1)
    brows = jax.lax.broadcasted_iota(jnp.int32, (chunk_e, bp), 1)
    pos_iota = jax.lax.broadcasted_iota(jnp.int32, (chunk_e, 1), 0)[:, 0]

    def body(s, carry):
        acc, tslot, prev_blk = carry
        blk = sblk_ref[s]
        p0 = sp0_ref[s]
        end = offsets_ref[blk + 1]
        load = blk != prev_blk
        tslot = jnp.where(load, 1 - tslot, tslot)

        # prefetch step s+1 while step s computes: the entry chunk always,
        # the table tile only when s+1 crosses into a new vocab block
        @pl.when(s + 1 < n)
        def _():
            ent_dma((s + 1) % 2, sp0_ref[s + 1]).start()

            @pl.when(sblk_ref[s + 1] != blk)
            def _():
                tile_dma(1 - tslot, sblk_ref[s + 1]).start()

        @pl.when(load)
        def _():
            tile_dma(tslot, blk).wait()
        ent_dma(s % 2, p0).wait()

        idx = ent_buf[s % 2, 0, :] - tile_start(blk)     # tile-local ids
        brow = ent_buf[s % 2, 1, :]
        valid = (p0 + pos_iota) < end
        onehot_v = ((idx[:, None] == vids)
                    & valid[:, None]).astype(jnp.float32)  # (E, V_blk)
        gathered = jax.lax.dot_general(                    # gather-as-matmul
            onehot_v, tile_buf[tslot].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # (E, D_blk)
        onehot_b = ((brow[:, None] == brows)
                    & valid[:, None]).astype(jnp.float32)  # (E, B)
        acc = acc + jax.lax.dot_general(
            onehot_b, gathered, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # (B, D_blk)
        return acc, tslot, blk

    acc, _, _ = jax.lax.fori_loop(
        0, n, body,
        (jnp.zeros((bp, bd), jnp.float32), jnp.int32(1), jnp.int32(-1)))
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_v", "block_d", "chunk_e", "interpret"))
def _embedding_bag_streamed(ids: jax.Array, table: jax.Array, *,
                            block_v: int, block_d: int, chunk_e: int,
                            interpret: bool) -> jax.Array:
    b, f = ids.shape
    v, d = table.shape
    bd = _block_d(d, block_d)
    d_pad = _round_up(d, bd)
    # tables keep their HBM layout: the last tile's DMA start is clamped in
    # the kernel, so padding is only needed for sub-block tables (rows) and
    # wide non-multiple D (cols) — never for the production V >> block_v
    row_pad = block_v - v if v < block_v else 0
    if row_pad or d_pad != d:
        table = jnp.pad(table, ((0, row_pad), (0, d_pad - d)))

    e = b * f
    sorted_ids, order, offsets, _, nvb = _sorted_entries(
        ids, v, block_v, chunk_e)
    e_pad = sorted_ids.shape[0]
    entries = jnp.stack([
        sorted_ids,
        jnp.pad((order // f).astype(jnp.int32), (0, e_pad - e))
    ])                                                    # (2, E_pad)

    # (block, chunk) step schedule: empty blocks contribute no steps, so
    # only tiles with at least one id are ever streamed
    lens = offsets[1:] - offsets[:-1]
    nchunks = (lens + chunk_e - 1) // chunk_e             # per block
    s_max = nvb + e // chunk_e              # sum(nchunks) can't exceed this
    n_steps = jnp.sum(nchunks).astype(jnp.int32)
    first_step = jnp.cumsum(nchunks) - nchunks
    step_blk = jnp.repeat(jnp.arange(nvb, dtype=jnp.int32), nchunks,
                          total_repeat_length=s_max)
    chunk_in_blk = jnp.arange(s_max, dtype=jnp.int32) - first_step[step_blk]
    step_p0 = offsets[step_blk] + chunk_in_blk * chunk_e

    bp = _round_up(b, 8)
    meta = fwd_launch_meta(b, f, v, d, table.dtype, block_v=block_v,
                           block_d=block_d, chunk_e=chunk_e)
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, block_v=block_v, chunk_e=chunk_e),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=meta.num_scalar_prefetch,
            grid=meta.grid,
            in_specs=block_specs(meta.inputs),
            out_specs=block_specs(meta.outputs)[0],
            scratch_shapes=scratch_shapes(meta.scratch) + [
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bp, d_pad), table.dtype),
        interpret=interpret,
    )(jnp.reshape(n_steps, (1,)), offsets, step_blk, step_p0,
      entries, table)
    return out[:b, :d]


def embedding_bag(ids: jax.Array, table: jax.Array, *,
                  block_v: int | None = None, block_d: int | None = None,
                  chunk_e: int | None = None,
                  interpret: bool | None = None) -> jax.Array:
    """ids: (B, F) int32, table: (V, D) -> pooled (B, D).

    The table stays in HBM; VMEM holds 2 ``(block_v, block_d)`` tiles and
    2 ``chunk_e``-entry chunks regardless of V (module docstring)."""
    return _embedding_bag_streamed(
        ids, table, block_v=block_v or BLOCK_V, block_d=block_d or BLOCK_D,
        chunk_e=chunk_e or CHUNK_E, interpret=runtime.resolve(interpret))


# ---------------------------------------------------------------------------
# backward: streamed sorted-scatter segment reduce
# ---------------------------------------------------------------------------

def _sorted_grad_rows(ids: jax.Array, grad_out: jax.Array, capacity: int,
                      block_v: int, chunk_e: int, d_pad: int):
    """Sorted-run bucketing (shared ``_sorted_entries``) plus the per-entry
    gradient-row payload, D-padded for tiling and length-padded to match
    the sentinel-padded id stream."""
    f = ids.shape[1]
    sorted_ids, order, offsets, cap_pad, nvb = _sorted_entries(
        ids, capacity, block_v, chunk_e)
    rows = grad_out[order // f]                           # (E, D)
    if d_pad != grad_out.shape[1]:
        rows = jnp.pad(rows, ((0, 0), (0, d_pad - grad_out.shape[1])))
    rows = jnp.pad(rows, ((0, sorted_ids.shape[0] - rows.shape[0]), (0, 0)))
    return sorted_ids, rows, offsets, cap_pad, nvb


def _bwd_kernel(offsets_ref, ids_hbm, rows_hbm, gtable_ref, counts_ref,
                ids_buf, rows_buf, ids_sem, rows_sem, *,
                block_v: int, chunk_e: int):
    """Segment reduce for one (vocab block, D block) output tile.

    offsets_ref: (nvb+1,) SMEM — run boundaries in the sorted arrays
    ids_hbm:     (E_pad,) HBM  — sorted ids
    rows_hbm:    (E_pad, D_pad) HBM — gradient rows in sorted-id order
    gtable_ref:  (BLOCK_V, BLOCK_D) VMEM output tile owned by this program
    counts_ref:  (BLOCK_V,) contributor counts (recomputed per D block —
                 every D block of a vocab block derives the same values)
    ids_buf:     (2, CHUNK_E) / rows_buf: (2, CHUNK_E, BLOCK_D) —
                 double-buffered chunk pipeline
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    start = offsets_ref[i]
    end = offsets_ref[i + 1]
    bd = gtable_ref.shape[1]
    nchunks = (end - start + chunk_e - 1) // chunk_e

    def dmas(slot, c):
        p0 = start + c * chunk_e
        return (
            pltpu.make_async_copy(ids_hbm.at[pl.ds(p0, chunk_e)],
                                  ids_buf.at[slot], ids_sem.at[slot]),
            pltpu.make_async_copy(
                rows_hbm.at[pl.ds(p0, chunk_e), pl.ds(j * bd, bd)],
                rows_buf.at[slot], rows_sem.at[slot]))

    @pl.when(nchunks > 0)
    def _():
        for dma in dmas(0, 0):
            dma.start()

    vids = i * block_v + jax.lax.broadcasted_iota(
        jnp.int32, (chunk_e, block_v), 1)
    pos_iota = jax.lax.broadcasted_iota(jnp.int32, (chunk_e, 1), 0)[:, 0]

    def body(c, carry):
        acc, cnt = carry
        cur = c % 2

        @pl.when(c + 1 < nchunks)
        def _():
            for dma in dmas((c + 1) % 2, c + 1):   # overlap chunk c compute
                dma.start()

        for dma in dmas(cur, c):
            dma.wait()
        idx = ids_buf[cur]                                   # (CHUNK_E,)
        rows = rows_buf[cur].astype(jnp.float32)
        valid = (start + c * chunk_e + pos_iota) < end
        onehot = ((idx[:, None] == vids)
                  & valid[:, None]).astype(jnp.float32)      # (E, V)
        acc = acc + jax.lax.dot_general(
            onehot, rows, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # (V, D)
        cnt = cnt + jnp.sum(onehot, axis=0)
        return acc, cnt

    acc, cnt = jax.lax.fori_loop(
        0, nchunks, body,
        (jnp.zeros((block_v, bd), jnp.float32),
         jnp.zeros((block_v,), jnp.float32)))
    gtable_ref[...] = acc
    counts_ref[...] = cnt


@functools.partial(
    jax.jit,
    static_argnames=("capacity", "block_v", "block_d", "chunk_e",
                     "interpret"))
def _embedding_bag_grad_streamed(ids: jax.Array, grad_out: jax.Array,
                                 capacity: int, *, block_v: int,
                                 block_d: int, chunk_e: int, interpret: bool
                                 ) -> tuple[jax.Array, jax.Array]:
    d = grad_out.shape[1]
    bd = _block_d(d, block_d)
    d_pad = _round_up(d, bd)
    sorted_ids, sorted_rows, offsets, cap_pad, nvb = _sorted_grad_rows(
        ids, grad_out, capacity, block_v, chunk_e, d_pad)

    meta = bwd_launch_meta(ids.shape[0], ids.shape[1], capacity, d,
                           grad_out.dtype, block_v=block_v,
                           block_d=block_d, chunk_e=chunk_e)
    gtable, counts = pl.pallas_call(
        functools.partial(_bwd_kernel, block_v=block_v, chunk_e=chunk_e),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=meta.num_scalar_prefetch,
            grid=meta.grid,
            in_specs=block_specs(meta.inputs),
            out_specs=block_specs(meta.outputs),
            scratch_shapes=scratch_shapes(meta.scratch) + [
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((cap_pad, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((cap_pad,), jnp.float32),
        ],
        interpret=interpret,
    )(offsets, sorted_ids, sorted_rows)
    return gtable[:capacity, :d], counts[:capacity]


def embedding_bag_grad(ids: jax.Array, grad_out: jax.Array, capacity: int,
                       *, block_v: int | None = None,
                       block_d: int | None = None,
                       chunk_e: int | None = None,
                       interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Scatter grads back to rows with per-ID contributor counts.

    ids: (B, F); grad_out: (B, D) -> (grad_table (V, D), counts (V,)).

    Sort once, then stream disjoint segments through the double-buffered
    chunk pipeline in parallel over (vocab block x D block) — see the
    module docstring for the design."""
    return _embedding_bag_grad_streamed(
        ids, grad_out, capacity, block_v=block_v or BLOCK_V,
        block_d=block_d or BLOCK_D, chunk_e=chunk_e or CHUNK_E,
        interpret=runtime.resolve(interpret))


# ---------------------------------------------------------------------------
# PR-1 VMEM-resident backward — kept as a bit-exactness regression oracle
# ---------------------------------------------------------------------------

def _bwd_kernel_resident(offsets_ref, ids_ref, rows_ref, gtable_ref,
                         counts_ref):
    """PR-1 segment reduce: the whole sorted (E_pad, D) array sits in VMEM
    via a full-array BlockSpec (only viable for VMEM-sized configs)."""
    i = pl.program_id(0)
    v0 = i * BLOCK_V
    start = offsets_ref[i]
    end = offsets_ref[i + 1]
    d = rows_ref.shape[1]
    vids = v0 + jax.lax.broadcasted_iota(jnp.int32, (CHUNK_E, BLOCK_V), 1)

    def body(c, carry):
        acc, cnt = carry
        p0 = start + c * CHUNK_E
        idx = ids_ref[pl.ds(p0, CHUNK_E)]                     # (CHUNK_E,)
        rows = rows_ref[pl.ds(p0, CHUNK_E), :].astype(jnp.float32)
        pos = p0 + jax.lax.broadcasted_iota(jnp.int32, (CHUNK_E, 1),
                                            0)[:, 0]
        valid = pos < end
        onehot = ((idx[:, None] == vids)
                  & valid[:, None]).astype(jnp.float32)       # (E, V)
        acc = acc + jax.lax.dot_general(
            onehot, rows, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (V, D)
        cnt = cnt + jnp.sum(onehot, axis=0)
        return acc, cnt

    nchunks = (end - start + CHUNK_E - 1) // CHUNK_E
    acc, cnt = jax.lax.fori_loop(
        0, nchunks, body,
        (jnp.zeros((BLOCK_V, d), jnp.float32),
         jnp.zeros((BLOCK_V,), jnp.float32)))
    gtable_ref[...] = acc
    counts_ref[...] = cnt


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def _embedding_bag_grad_resident(ids: jax.Array, grad_out: jax.Array,
                                 capacity: int, *, interpret: bool
                                 ) -> tuple[jax.Array, jax.Array]:
    d = grad_out.shape[1]
    sorted_ids, sorted_rows, offsets, cap_pad, nvb = _sorted_grad_rows(
        ids, grad_out, capacity, BLOCK_V, CHUNK_E, d)
    e_pad = sorted_ids.shape[0]

    gtable, counts = pl.pallas_call(
        _bwd_kernel_resident,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nvb,),
            in_specs=[
                pl.BlockSpec((e_pad,), lambda i, *_: (0,)),
                pl.BlockSpec((e_pad, d), lambda i, *_: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((BLOCK_V, d), lambda i, *_: (i, 0)),
                pl.BlockSpec((BLOCK_V,), lambda i, *_: (i,)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((cap_pad, d), jnp.float32),
            jax.ShapeDtypeStruct((cap_pad,), jnp.float32),
        ],
        interpret=interpret,
    )(offsets, sorted_ids, sorted_rows)
    return gtable[:capacity], counts[:capacity]


def embedding_bag_grad_resident(ids: jax.Array, grad_out: jax.Array,
                                capacity: int, *,
                                interpret: bool | None = None
                                ) -> tuple[jax.Array, jax.Array]:
    return _embedding_bag_grad_resident(
        ids, grad_out, capacity, interpret=runtime.resolve(interpret))
