"""Pallas TPU kernel: fused Adagrad update.

Adagrad is the paper's optimizer for the async/GBA modes (Tab. 5.1).  The
naive XLA form reads grad, reads accum, writes accum, reads accum again,
writes param — this kernel does one VMEM pass per block: accum += g^2;
param -= lr * g / (sqrt(accum) + eps), with both outputs aliased in-place.

NOTE: when the gradient comes from the GBA buffer, the train path uses
``repro.kernels.gba_apply`` instead, which fuses the buffer aggregation
with this update in the same pass (the gradient never hits HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096


def _kernel(lr_ref, param_ref, grad_ref, accum_ref, new_param_ref,
            new_accum_ref, *, eps: float):
    g = grad_ref[...].astype(jnp.float32)
    a = accum_ref[...].astype(jnp.float32) + g * g
    p = param_ref[...].astype(jnp.float32)
    p = p - lr_ref[0] * g / (jnp.sqrt(a) + eps)
    new_param_ref[...] = p.astype(new_param_ref.dtype)
    new_accum_ref[...] = a


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def fused_adagrad(param: jax.Array, grad: jax.Array, accum: jax.Array,
                  lr: jax.Array, *, eps: float = 1e-10,
                  interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """1-D fused update.  param/grad/accum: (N,) -> (new_param, new_accum)."""
    n = param.shape[0]
    pad = (-n) % BLOCK
    if pad:
        param = jnp.pad(param, (0, pad))
        grad = jnp.pad(grad, (0, pad))
        accum = jnp.pad(accum, (0, pad))
    np_ = n + pad
    grid = (np_ // BLOCK,)
    new_param, new_accum = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), param.dtype),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(lr, jnp.float32).reshape(1), param, grad, accum)
    return new_param[:n], new_accum[:n]
