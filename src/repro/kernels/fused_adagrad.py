"""Pallas TPU kernel: fused Adagrad update.

Adagrad is the paper's optimizer for the async/GBA modes (Tab. 5.1).  The
naive XLA form reads grad, reads accum, writes accum, reads accum again,
writes param — this kernel does one VMEM pass per block: accum += g^2;
param -= lr * g / (sqrt(accum) + eps), with both outputs aliased in-place.

NOTE: when the gradient comes from the GBA buffer, the train path uses
``repro.kernels.gba_apply`` instead, which fuses the buffer aggregation
with this update in the same pass (the gradient never hits HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.launch_meta import (BlockMeta, LaunchMeta, block_specs,
                                       _round_up_static)

BLOCK = 4096


def adagrad_vmem_bytes(block: int = BLOCK) -> int:
    """Per-grid-step VMEM residency: lr + param/grad/accum in blocks +
    param/accum out blocks, all f32."""
    return 4 + 5 * block * 4


def launch_meta(n: int, param_dtype=jnp.float32,
                grad_dtype=jnp.float32) -> LaunchMeta:
    """Static launch geometry for an (n,)-param fused Adagrad update; the
    pallas_call builds its specs from this.  param -> new_param and
    accum -> new_accum are aliased in-place (the docstring's claim, now
    declared to XLA and audited by GBA-DON rules)."""
    np_ = _round_up_static(n, BLOCK)
    return LaunchMeta(
        kernel="fused_adagrad",
        grid=(np_ // BLOCK,),
        inputs=(
            BlockMeta("lr", (1,), jnp.float32, (1,), lambda i: (0,)),
            BlockMeta("param", (np_,), param_dtype, (BLOCK,),
                      lambda i: (i,)),
            BlockMeta("grad", (np_,), grad_dtype, (BLOCK,),
                      lambda i: (i,)),
            BlockMeta("accum", (np_,), jnp.float32, (BLOCK,),
                      lambda i: (i,)),
        ),
        outputs=(
            BlockMeta("new_param", (np_,), param_dtype, (BLOCK,),
                      lambda i: (i,)),
            BlockMeta("new_accum", (np_,), jnp.float32, (BLOCK,),
                      lambda i: (i,)),
        ),
        aliases=((1, 0), (3, 1)),
        declared_vmem_bytes=adagrad_vmem_bytes(BLOCK),
        vmem_counted=("lr", "param", "grad", "accum", "new_param",
                      "new_accum"),
    )


def _kernel(lr_ref, param_ref, grad_ref, accum_ref, new_param_ref,
            new_accum_ref, *, eps: float):
    g = grad_ref[...].astype(jnp.float32)
    a = accum_ref[...].astype(jnp.float32) + g * g
    p = param_ref[...].astype(jnp.float32)
    p = p - lr_ref[0] * g / (jnp.sqrt(a) + eps)
    new_param_ref[...] = p.astype(new_param_ref.dtype)
    new_accum_ref[...] = a


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def fused_adagrad(param: jax.Array, grad: jax.Array, accum: jax.Array,
                  lr: jax.Array, *, eps: float = 1e-10,
                  interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """1-D fused update.  param/grad/accum: (N,) -> (new_param, new_accum)."""
    n = param.shape[0]
    pad = (-n) % BLOCK
    if pad:
        param = jnp.pad(param, (0, pad))
        grad = jnp.pad(grad, (0, pad))
        accum = jnp.pad(accum, (0, pad))
    np_ = n + pad
    meta = launch_meta(n, param.dtype, grad.dtype)
    new_param, new_accum = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=meta.grid,
        in_specs=block_specs(meta.inputs),
        out_specs=block_specs(meta.outputs),
        input_output_aliases=meta.pallas_aliases(),
        out_shape=[
            jax.ShapeDtypeStruct((np_,), param.dtype),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(lr, jnp.float32).reshape(1), param, grad, accum)
    return new_param[:n], new_accum[:n]
