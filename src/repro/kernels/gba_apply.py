"""Pallas TPU kernel: fused GBA aggregate-and-apply.

The PS-side apply path of GBA (Alg. 2 lines 20/22 + the optimizer step)
previously ran as two kernels with an HBM round-trip between them:
``gba_aggregate`` reduced the (M, N) buffer to an aggregated gradient in
HBM, then ``fused_adagrad`` read it back alongside param/accum.  This
kernel merges both: for each N-block it computes the token-decay weights on
the scalar core, reduces the buffer column in VMEM, and immediately applies
the Adagrad update — the aggregated gradient never touches HBM.

Per-block traffic: read M rows of the buffer + param + accum, write new
param + accum — (M + 4) * BLOCK_N elements vs (M + 2) + (5) for the
two-kernel chain, i.e. the fusion removes two full reads and one full
write of an N-sized tensor per apply.

Inputs are flat (N,) vectors — ``repro.core.gba.FlatLayout`` ravels a
dense parameter pytree into exactly this shape so the whole apply is ONE
kernel launch instead of a per-leaf chain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.launch_meta import (BlockMeta, LaunchMeta, block_specs,
                                       _round_up_static)

BLOCK_N = 2048


def apply_vmem_bytes(m: int, block_n: int = BLOCK_N,
                     buf_itemsize: int = 4) -> int:
    """Per-launch VMEM residency of one grid step: the (m, BLOCK_N) buffer
    block plus param/accum in and out blocks (f32).  Shard-size
    independent — a PS shard's launch holds exactly this much regardless
    of its slice length (benchmarks/bench_kernels gba_apply_sharded
    rows)."""
    return m * block_n * buf_itemsize + 4 * block_n * 4


def launch_meta(n: int, m: int, param_dtype=jnp.float32,
                buf_dtype=jnp.float32) -> LaunchMeta:
    """Static launch geometry for an (n,)-param, (m, n)-buffer apply.
    The real ``pallas_call`` below builds its specs FROM this, so the
    auditor (repro.analysis.pallas_check) checks the launch that runs.

    The in-place aliases donate param -> new_param and accum -> new_accum
    at the kernel level (array-input indices; ``pallas_aliases()`` shifts
    them past the 4 scalar-prefetch operands)."""
    n_pad = _round_up_static(n, BLOCK_N)
    buf_itemsize = jnp.dtype(buf_dtype).itemsize
    return LaunchMeta(
        kernel="gba_apply",
        grid=(n_pad // BLOCK_N,),
        num_scalar_prefetch=4,
        inputs=(
            BlockMeta("param", (n_pad,), param_dtype, (BLOCK_N,),
                      lambda i, *_: (i,)),
            BlockMeta("accum", (n_pad,), jnp.float32, (BLOCK_N,),
                      lambda i, *_: (i,)),
            BlockMeta("buffer", (m, n_pad), buf_dtype, (m, BLOCK_N),
                      lambda i, *_: (0, i)),
        ),
        outputs=(
            BlockMeta("new_param", (n_pad,), param_dtype, (BLOCK_N,),
                      lambda i, *_: (i,)),
            BlockMeta("new_accum", (n_pad,), jnp.float32, (BLOCK_N,),
                      lambda i, *_: (i,)),
        ),
        aliases=((0, 0), (1, 1)),
        declared_vmem_bytes=apply_vmem_bytes(m, BLOCK_N, buf_itemsize),
        vmem_counted=("param", "accum", "buffer", "new_param", "new_accum"),
    )


def _kernel(tokens_ref, step_ref, iota_ref, lr_ref, param_ref, accum_ref,
            buf_ref, new_param_ref, new_accum_ref, *, eps: float):
    """buf: (M, BLOCK_N) VMEM; param/accum: (BLOCK_N,); scalars in SMEM."""
    m = buf_ref.shape[0]
    keep = (step_ref[0] - tokens_ref[...]) <= iota_ref[0]     # Eq. (1)
    w = keep.astype(jnp.float32) / jnp.float32(m)
    g = jnp.sum(buf_ref[...].astype(jnp.float32) * w[:, None], axis=0)
    a = accum_ref[...].astype(jnp.float32) + g * g
    p = param_ref[...].astype(jnp.float32)
    p = p - lr_ref[0] * g / (jnp.sqrt(a) + eps)
    new_param_ref[...] = p.astype(new_param_ref.dtype)
    new_accum_ref[...] = a


@functools.partial(jax.jit, static_argnames=("iota", "eps", "interpret"))
def gba_apply(param: jax.Array, accum: jax.Array, buffer: jax.Array,
              tokens: jax.Array, step: jax.Array, lr: jax.Array, *,
              iota: int, eps: float = 1e-10, interpret: bool = True
              ) -> tuple[jax.Array, jax.Array]:
    """Single-pass decay-aggregate + Adagrad apply.

    param/accum: (N,), buffer: (M, N), tokens: (M,) ->
    (new_param (N,), new_accum (N,)).  ``interpret=True`` runs the kernel
    body on CPU (this container); pass False on real TPUs.
    """
    n = param.shape[0]
    m = buffer.shape[0]
    pad = (-n) % BLOCK_N
    if pad:
        param = jnp.pad(param, (0, pad))
        accum = jnp.pad(accum, (0, pad))
        buffer = jnp.pad(buffer, ((0, 0), (0, pad)))
    n_pad = n + pad
    meta = launch_meta(n, m, param.dtype, buffer.dtype)

    new_param, new_accum = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=meta.num_scalar_prefetch,
            grid=meta.grid,
            in_specs=block_specs(meta.inputs),
            out_specs=block_specs(meta.outputs),
        ),
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), param.dtype),
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        ],
        input_output_aliases=meta.pallas_aliases(),
        interpret=interpret,
    )(tokens.astype(jnp.int32),
      jnp.asarray(step, jnp.int32).reshape(1),
      jnp.full((1,), iota, jnp.int32),
      jnp.asarray(lr, jnp.float32).reshape(1),
      param, accum, buffer)
    return new_param[:n], new_accum[:n]
