"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gba_aggregate_ref(grads: jax.Array, tokens: jax.Array, step: jax.Array,
                      *, iota: int) -> jax.Array:
    """(M, D), (M,) -> (D,): Eq. (1) decayed mean over the buffer."""
    m = grads.shape[0]
    keep = ((step - tokens) <= iota).astype(jnp.float32)
    g = grads.astype(jnp.float32)
    return (jnp.sum(g * keep[:, None], axis=0) / m).astype(grads.dtype)


def embedding_bag_ref(ids: jax.Array, table: jax.Array) -> jax.Array:
    """(B, F), (V, D) -> (B, D) sum-pool of gathered rows."""
    return jnp.sum(table[ids].astype(jnp.float32), axis=1).astype(table.dtype)


def embedding_bag_grad_ref(ids: jax.Array, grad_out: jax.Array,
                           capacity: int) -> tuple[jax.Array, jax.Array]:
    b, f = ids.shape
    d = grad_out.shape[1]
    rows = jnp.broadcast_to(grad_out[:, None, :], (b, f, d)).reshape(-1, d)
    flat = ids.reshape(-1)
    gtable = jnp.zeros((capacity, d), jnp.float32).at[flat].add(
        rows.astype(jnp.float32))
    counts = jnp.zeros((capacity,), jnp.float32).at[flat].add(1.0)
    return gtable, counts


def fused_adagrad_ref(param: jax.Array, grad: jax.Array, accum: jax.Array,
                      lr, *, eps: float = 1e-10
                      ) -> tuple[jax.Array, jax.Array]:
    g = grad.astype(jnp.float32)
    a = accum.astype(jnp.float32) + g * g
    p = param.astype(jnp.float32) - lr * g / (jnp.sqrt(a) + eps)
    return p.astype(param.dtype), a


def gba_apply_ref(param: jax.Array, accum: jax.Array, buffer: jax.Array,
                  tokens: jax.Array, step: jax.Array, lr, *, iota: int,
                  eps: float = 1e-10) -> tuple[jax.Array, jax.Array]:
    """Two-pass oracle for the fused aggregate+apply: decayed mean over the
    (M, N) buffer, then a plain Adagrad update of the flat params."""
    agg = gba_aggregate_ref(buffer.astype(jnp.float32), tokens, step,
                            iota=iota)
    return fused_adagrad_ref(param, agg, accum, lr, eps=eps)
