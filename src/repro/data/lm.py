"""Synthetic language-model token stream for the assigned architectures.

A Markov-chain source with vocab-dependent transition structure: learnable
enough that a ~100M model's loss visibly drops within a few hundred steps
(examples/train_lm_100m.py), deterministic per (seed, step).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class LMStream:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    order_states: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        K = self.order_states
        # hidden-state HMM-ish source: state -> state, state -> token
        self._trans = rng.dirichlet(np.ones(K) * 0.1, size=K)
        emis = rng.dirichlet(np.ones(self.vocab_size) * 0.05, size=K)
        self._emis_cum = np.cumsum(emis, axis=1)
        self._trans_cum = np.cumsum(self._trans, axis=1)

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(self.seed * 7_368_787 + step)
        B, S, K = self.batch_size, self.seq_len, self.order_states
        states = rng.integers(0, K, size=B)
        toks = np.empty((B, S + 1), np.int32)
        u_tok = rng.uniform(size=(B, S + 1))
        u_st = rng.uniform(size=(B, S + 1))
        for t in range(S + 1):
            toks[:, t] = (
                self._emis_cum[states] > u_tok[:, t, None]).argmax(axis=1)
            states = (self._trans_cum[states] > u_st[:, t, None]).argmax(
                axis=1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_lm_stream(vocab_size: int, seq_len: int, batch_size: int,
                   seed: int = 0) -> LMStream:
    return LMStream(vocab_size, seq_len, batch_size, seed)
