from repro.data.clickstream import ClickStream, make_clickstream
from repro.data.lm import LMStream, make_lm_stream

__all__ = ["ClickStream", "LMStream", "make_clickstream", "make_lm_stream"]
