"""Synthetic skewed click-log stream (Criteo/Alimama/Private stand-in).

The offline container cannot download Criteo-1TB / Alimama, so we generate a
stream with the properties the paper's analysis relies on:

* **Zipf-skewed ID occurrences** (Fig. 4): most IDs appear in very few
  batches, a few appear everywhere — this is what makes embedding params
  staleness-robust (Insight 2).
* A **learnable ground-truth CTR model**: labels are drawn from a logistic
  model over latent field/ID factors, so AUC is a meaningful accuracy metric
  and training curves behave like real CTR training (converging AUC < 1).
* **Day partitions** for the paper's continual-training protocol (train on
  day d, evaluate on day d+1) with mild day-to-day drift.

Deterministic: every batch is a pure function of (seed, day, batch index),
so async/sync/GBA runs consume identical data regardless of worker order.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.recsys import RecsysConfig


@dataclass
class ClickStream:
    cfg: RecsysConfig
    seed: int
    zipf_a: float
    num_days: int
    batches_per_day: int
    batch_size: int
    drift: float

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = self.cfg.hash_capacity
        D = 8  # latent dim of the ground-truth model
        self._id_factors = rng.normal(0, 1, (V, D)).astype(np.float32)
        self._field_w = rng.normal(0, 1, (self.cfg.num_fields, D)).astype(
            np.float32)
        self._beh_w = rng.normal(0, 1, (D,)).astype(np.float32)
        self._day_drift = rng.normal(0, self.drift,
                                     (self.num_days, D)).astype(np.float32)
        # Zipf ranks -> per-field ID pools (fields see disjoint slices)
        ranks = np.arange(1, V + 1, dtype=np.float64)
        probs = ranks ** (-self.zipf_a)
        self._id_probs = (probs / probs.sum()).astype(np.float64)

    def _draw_ids(self, rng, shape) -> np.ndarray:
        return rng.choice(self.cfg.hash_capacity, size=shape,
                          p=self._id_probs).astype(np.int32)

    def batch(self, day: int, index: int, batch_size: int | None = None
              ) -> dict:
        """Pure function of (seed, day, index)."""
        bs = batch_size or self.batch_size
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + day) * 1_000_003 + index)
        cfg = self.cfg
        fields = self._draw_ids(rng, (bs, cfg.num_fields))
        out = {"fields": fields}
        logit = (self._id_factors[fields] * self._field_w[None]).sum(
            axis=(1, 2)) / np.sqrt(cfg.num_fields)
        if cfg.behavior_len:
            behavior = self._draw_ids(rng, (bs, cfg.behavior_len))
            target = self._draw_ids(rng, (bs,))
            out["behavior"] = behavior
            out["target"] = target
            # behavior-target affinity drives the label, like real CTR data
            aff = (self._id_factors[behavior].mean(axis=1)
                   * self._id_factors[target]).sum(axis=-1)
            logit = logit + aff * 2.0
        drift = self._day_drift[day % self.num_days]
        logit = logit + (self._id_factors[fields[:, 0]] * drift).sum(axis=-1)
        logit = logit - 1.0  # CTR base rate < 0.5
        p = 1.0 / (1.0 + np.exp(-logit))
        out["label"] = (rng.uniform(size=bs) < p).astype(np.float32)
        return out

    def day_batches(self, day: int):
        for i in range(self.batches_per_day):
            yield self.batch(day, i)


def make_clickstream(cfg: RecsysConfig, seed: int = 0, zipf_a: float = 1.2,
                     num_days: int = 8, batches_per_day: int = 64,
                     batch_size: int = 256, drift: float = 0.05
                     ) -> ClickStream:
    return ClickStream(cfg, seed, zipf_a, num_days, batches_per_day,
                       batch_size, drift)
