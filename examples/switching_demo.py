"""The paper's headline scenario: tuning-free mode switching.

    PYTHONPATH=src python examples/switching_demo.py

Trains a base model synchronously ("vacant cluster"), switches to GBA when
the cluster becomes strained, and switches back — all with the SAME
hyper-parameters.  For contrast, also switches to pure async (the paper's
Fig. 2 failure mode).
"""
import jax
import numpy as np

from repro.configs.recsys import CRITEO_DEEPFM
from repro.core import default_setups, run_continual
from repro.data import make_clickstream
from repro.models.recsys import init_recsys
from repro.sim.cluster import ClusterSpec


def main() -> None:
    cfg = CRITEO_DEEPFM
    stream = make_clickstream(cfg, seed=0, batches_per_day=48,
                              batch_size=256, num_days=14)
    setups = default_setups(base_global=2048)
    strained = ClusterSpec(num_workers=16, straggler_frac=0.25,
                           straggler_slowdown=5.0, jitter=0.2,
                           time_varying=True, seed=0)

    base = init_recsys(jax.random.PRNGKey(0), cfg)
    print("== phase 1: vacant cluster -> synchronous training")
    base, res = run_continual(base, cfg, stream, ["sync"] * 5, setups,
                              strained, eval_batches=8)
    for d, (a, q) in enumerate(zip(res.auc_per_day, res.qps_per_day)):
        print(f"  day {d}: mode=sync auc={a:.4f} qps={q:,.0f}")

    print("== phase 2: cluster strained -> switch to GBA (no re-tuning)")
    params_gba, res_gba = run_continual(base, cfg, stream,
                                        ["gba", "gba", "gba"], setups,
                                        strained, eval_batches=8,
                                        start_day=5)
    for i, (a, q) in enumerate(zip(res_gba.auc_per_day,
                                   res_gba.qps_per_day)):
        print(f"  day {5 + i}: mode=gba auc={a:.4f} qps={q:,.0f}")

    print("== phase 2': what pure async would have done (Fig. 2)")
    _, res_async = run_continual(base, cfg, stream, ["async"] * 2,
                                 setups, strained, eval_batches=8,
                                 start_day=5)
    for i, a in enumerate(res_async.auc_per_day):
        print(f"  day {5 + i}: mode=async auc={a:.4f}")

    print("== phase 3: cluster vacant again -> switch GBA back to sync")
    _, res_back = run_continual(params_gba, cfg, stream, ["sync"] * 2,
                                setups, strained, eval_batches=8,
                                start_day=8)
    for i, a in enumerate(res_back.auc_per_day):
        print(f"  day {8 + i}: mode=sync auc={a:.4f}")

    d_gba = res.auc_per_day[-1] - res_gba.auc_per_day[0]
    d_async = res.auc_per_day[-1] - res_async.auc_per_day[0]
    print(f"\nfirst-day AUC drop after switch:  GBA {d_gba:+.4f}   "
          f"async {d_async:+.4f}")
    print(f"GBA speedup over sync under strain: "
          f"{np.mean(res_gba.qps_per_day) / np.mean(res.qps_per_day):.1f}x")


if __name__ == "__main__":
    main()
