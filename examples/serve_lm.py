"""End-to-end serving driver: batched prefill + decode of a small LM.

    PYTHONPATH=src python examples/serve_lm.py [--arch starcoder2-3b]

Uses the REDUCED variant of an assigned architecture (the full configs are
dry-run-only on CPU), serves a batch of 8 requests: prefill the prompts,
then greedy-decode 32 tokens each through the production decode step.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import make_decode_step
from repro.models import transformer as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("use a text arch for this demo")
    print(f"serving {cfg.name}: {args.batch} requests, "
          f"prompt {args.prompt_len}, generate {args.gen_len}")
    key = jax.random.PRNGKey(0)
    params = T.init_model(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    cache_len = args.prompt_len + args.gen_len
    t0 = time.perf_counter()
    prefill_jit = jax.jit(lambda p, t: T.prefill(p, cfg, t,
                                                 cache_len=cache_len))
    logits, cache = prefill_jit(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {t_prefill * 1e3:.1f} ms "
          f"({args.batch * args.prompt_len / t_prefill:,.0f} tok/s)")

    decode = jax.jit(make_decode_step(cfg))
    token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [token]
    t0 = time.perf_counter()
    for _ in range(args.gen_len - 1):
        token, _, cache = decode(params, token, cache)
        out.append(token)
    jax.block_until_ready(token)
    t_dec = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decode: {t_dec * 1e3:.1f} ms "
          f"({args.batch * (args.gen_len - 1) / t_dec:,.0f} tok/s)")
    print("first request's generated ids:", gen[0, :16].tolist(), "...")


if __name__ == "__main__":
    main()
