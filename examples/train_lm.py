"""End-to-end LM training driver with first-class GBA.

    PYTHONPATH=src python examples/train_lm.py               # ~25M, quick
    PYTHONPATH=src python examples/train_lm.py --params 100m --steps 300

Builds a granite-family dense decoder at the requested scale, streams the
synthetic LM source, and trains with the GBA train step (M-slot buffer,
token-control decay) — the same step the multi-pod dry-run lowers.  Loss
must drop visibly within a few dozen steps.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import GBAConfig
from repro.data import make_lm_stream
from repro.launch.programs import build_programs
from repro.models import transformer as T
from repro.optim import get_optimizer

SIZES = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "25m": (6, 384, 6, 2, 1536, 8192),
    "100m": (12, 768, 12, 4, 3072, 16384),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", default="25m", choices=SIZES)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--buffer", type=int, default=4, help="GBA M")
    args = ap.parse_args()

    L, D, H, KV, F, V = SIZES[args.params]
    cfg = dataclasses.replace(
        get_config("granite-8b"), name=f"granite-{args.params}",
        num_layers=L, d_model=D, num_heads=H, num_kv_heads=KV, d_ff=F,
        vocab_size=V, dtype="float32")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    n = T.param_count(params)
    print(f"model: {cfg.name}  {n / 1e6:.1f}M params  "
          f"GBA buffer M={args.buffer}")

    stream = make_lm_stream(V, args.seq, args.batch, seed=0)
    opt = get_optimizer("adam", 3e-4)
    gba = GBAConfig(local_batch=args.batch, buffer_size=args.buffer,
                    staleness_tolerance=4)
    progs = build_programs(cfg, gba, mode="pytree", params=params,
                           optimizer=opt, acc_dtype=jnp.float32)
    step_fn, state = progs.step, progs.state

    t0 = time.perf_counter()
    first = None
    for i in range(args.steps):
        batch = stream.batch(i)
        token = jnp.asarray(i // args.buffer, jnp.int32)  # fresh tokens
        state, loss = step_fn(
            state, {"tokens": jnp.asarray(batch["tokens"]),
                    "labels": jnp.asarray(batch["labels"])}, token)
        loss = float(loss)
        first = first if first is not None else loss
        if i % 10 == 0 or i == args.steps - 1:
            dt = time.perf_counter() - t0
            tput = args.batch * args.seq * (i + 1) / dt
            print(f"step {i:4d}  micro-loss {loss:.4f}  "
                  f"gstep {int(state['gstep'])}  {tput:,.0f} tok/s")
    print(f"\nloss: {first:.4f} -> {loss:.4f} "
          f"({'improved' if loss < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
