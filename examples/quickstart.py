"""Quickstart: train DeepFM with GBA on a synthetic click stream.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the public API end-to-end in ~1 minute on CPU:
  1. build a Criteo-like stream and a DeepFM model;
  2. simulate a strained shared cluster to get a GBA schedule;
  3. replay it with real gradients (PS staleness semantics);
  4. evaluate AUC on the next day.
"""
import jax

from repro.configs.recsys import CRITEO_DEEPFM
from repro.core import GBATrainer, evaluate, schedule_for_day
from repro.core.continual import ModeSetup
from repro.data import make_clickstream
from repro.models.recsys import init_recsys
from repro.optim import get_optimizer
from repro.sim.cluster import ClusterSpec


def main() -> None:
    cfg = CRITEO_DEEPFM
    stream = make_clickstream(cfg, seed=0, batch_size=128)
    params = init_recsys(jax.random.PRNGKey(0), cfg)
    optimizer = get_optimizer("adam", 1e-3)
    trainer = GBATrainer(cfg, optimizer, iota=4)

    setup = ModeSetup("gba", num_workers=16, local_batch=128,
                      buffer_size=16, iota=4)
    spec = ClusterSpec(num_workers=16, straggler_frac=0.25,
                       straggler_slowdown=5.0, jitter=0.2, seed=0)

    opt_state = optimizer.init(params)
    last_update = None
    print(f"{'day':>3} {'auc':>8} {'qps':>10} {'drops':>6} {'steps':>6}")
    for day in range(4):
        sched = schedule_for_day(setup, spec, num_batches=256)
        params, opt_state, last_update, stats = trainer.replay(
            params, opt_state, sched, stream, day, last_update=last_update)
        auc = evaluate(params, cfg, stream, day + 1, num_batches=8)
        m = sched.metrics
        print(f"{day:>3} {auc:>8.4f} {m.qps:>10.0f} "
              f"{m.dropped_batches:>6} {m.num_global_steps:>6}")
    print("done — GBA trained at async speed with sync-like accuracy.")


if __name__ == "__main__":
    main()
