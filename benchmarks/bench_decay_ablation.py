"""Beyond-paper ablation: staleness-decay strategies under heavy staleness.

The paper uses the hard threshold (Eq. 1) and notes other strategies are
possible.  We compare threshold / exponential / linear / no-decay on a GBA
run over a badly-strained cluster (deep staleness tail), measuring AUC
after switching from a sync base.
"""
from __future__ import annotations

import time
from dataclasses import replace

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.configs.recsys import CRITEO_DEEPFM
from repro.core import default_setups, run_continual
from repro.core.trainer import GBATrainer, evaluate
from repro.data import make_clickstream
from repro.models.recsys import init_recsys
from repro.optim import get_optimizer
from repro.sim.cluster import ClusterSpec, Schedule, Slot, simulate

CFG = CRITEO_DEEPFM


def run(base_days: int = 6) -> list[str]:
    t0 = time.perf_counter()
    rows = []
    stream = make_clickstream(CFG, seed=0, batches_per_day=48,
                              batch_size=256, num_days=base_days + 3)
    setups = default_setups(base_global=2048)
    # very heavy strain -> deep staleness tail
    spec = ClusterSpec(num_workers=16, straggler_frac=0.4,
                       straggler_slowdown=12.0, jitter=0.3, seed=0)
    base = init_recsys(jax.random.PRNGKey(0), CFG)
    base, _ = run_continual(base, CFG, stream, ["sync"] * base_days, setups,
                            spec, eval_batches=12)

    sched = simulate(replace(spec, seed=99), "gba", 768, 128,
                     buffer_size=16, iota=4)
    m = sched.metrics
    rows.append(csv_row("decay.scenario", 0.0,
                        f"avg_stale={m.avg_staleness:.2f};"
                        f"max_stale={m.staleness_max};"
                        f"drops={m.dropped_batches}"))

    day = base_days

    def run_strategy(strategy: str, iota: int) -> float:
        opt = get_optimizer("adam", 6e-4)
        trainer = GBATrainer(CFG, opt, iota=iota)
        # re-weight slots per strategy (sim encodes threshold@4 weights;
        # recompute from tokens)
        from repro.core.staleness import DECAY_FNS
        import jax.numpy as jnp
        steps = []
        for k, slots in enumerate(sched.steps):
            new = []
            for s in slots:
                w = float(DECAY_FNS[strategy](
                    jnp.asarray([s.token]), jnp.int32(k), iota)[0]) \
                    if strategy != "none" else 1.0
                new.append(Slot(s.batch_index, s.token, s.dispatch_step, w))
            steps.append(new)
        sched2 = Schedule("gba", 128, steps)
        params, _, _, _ = trainer.replay(base, opt.init(base), sched2,
                                         stream, day)
        return evaluate(params, CFG, stream, day + 1, 12)

    for strategy, iota in [("threshold", 4), ("exponential", 8),
                           ("linear", 8), ("none", 10**6)]:
        auc = run_strategy(strategy, iota)
        rows.append(csv_row(f"decay.{strategy}", 0.0, f"auc={auc:.4f}"))
    us = (time.perf_counter() - t0) * 1e6
    rows.append(csv_row("decay.done", us, "see EXPERIMENTS.md"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
