"""Beyond-paper: adaptive switching controller evaluation.

A 12-phase trace alternates between vacant and strained cluster phases;
each phase is split into 4 telemetry sub-windows and the controller decides
per sub-window from the previous sub-window's per-worker rates (PS-side
observable).  Policies: always-sync, always-gba, adaptive, oracle.

The finite PS service rate (``ps_throughput``) reproduces Fig. 1's
crossover: sync wins on a vacant cluster, GBA under strain — so neither
static policy is optimal and the adaptive controller must beat both.
"""
from __future__ import annotations

import time
from dataclasses import replace

import numpy as np

from benchmarks.common import csv_row
from repro.core.autoswitch import AutoSwitchController
from repro.sim.cluster import ClusterSpec, simulate

VACANT = ClusterSpec(num_workers=16, straggler_frac=0.0, jitter=0.05,
                     ps_throughput=100.0)
STRAINED = ClusterSpec(num_workers=16, straggler_frac=0.25,
                       straggler_slowdown=10.0, jitter=0.2,
                       time_varying=True, ps_throughput=100.0)
# a day in the shared cluster (Fig. 1)
PHASES = [VACANT] * 3 + [STRAINED] * 4 + [VACANT] * 2 + [STRAINED] * 2 \
    + [VACANT]
SUBWINDOWS = 4


def _window(spec: ClusterSpec, mode: str, num_batches: int, seed: int):
    sched = simulate(replace(spec, seed=seed), mode, num_batches, 256,
                     buffer_size=16, iota=4)
    return sched.metrics.wall_time, sched.metrics.worker_rates


def run(num_batches: int = 480) -> list[str]:
    t0 = time.perf_counter()
    rows = []
    nb = max(32, num_batches // SUBWINDOWS)
    totals = {"sync": 0.0, "gba": 0.0, "oracle": 0.0, "adaptive": 0.0}
    ctrl = AutoSwitchController()
    modes_log = []
    prev_rates = None
    for i, spec in enumerate(PHASES):
        for j in range(SUBWINDOWS):
            seed = 100 + i * SUBWINDOWS + j
            t_sync, r_sync = _window(spec, "sync", nb, seed)
            t_gba, r_gba = _window(spec, "gba", nb, seed)
            totals["sync"] += t_sync
            totals["gba"] += t_gba
            totals["oracle"] += min(t_sync, t_gba)
            mode = ctrl.mode if prev_rates is None else ctrl.decide(
                prev_rates)
            t_ad, prev_rates = (t_sync, r_sync) if mode == "sync" \
                else (t_gba, r_gba)
            totals["adaptive"] += t_ad
        modes_log.append(mode)
    for k, v in totals.items():
        rows.append(csv_row(f"autoswitch.total_time.{k}", 0.0,
                            f"seconds={v:.1f}"))
    regret = (totals["adaptive"] - totals["oracle"]) / totals["oracle"]
    beats_static = totals["adaptive"] < min(totals["sync"], totals["gba"])
    us = (time.perf_counter() - t0) * 1e6
    rows.append(csv_row(
        "autoswitch.claims", us,
        f"phase_end_modes="
        f"{''.join('S' if m == 'sync' else 'G' for m in modes_log)};"
        f"regret_vs_oracle={regret:.1%};beats_both_static={beats_static}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
