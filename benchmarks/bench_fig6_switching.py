"""Paper Fig. 6 / Tables 6.1-6.8: continual training with mode switching.

Protocol (scaled): pretrain a base model in sync mode for ``base_days``,
then (a) switch to each compared mode for ``eval_days`` (Fig. 6 a-c),
and (b) train each mode then switch back to sync (Fig. 6 d-f).
AUC on the next day after each training day.  Claims:

  C2a  GBA's first-day AUC after switching ~= sync (no sudden drop);
  C2b  GBA >= the semi-sync baselines on average;
  C2c  pure async with the sync hyper-parameter set collapses.

:func:`run_switching` is the GATED trajectory (suite ``switching`` in
``benchmarks.run``): it spawns ``repro.launch.switch_driver`` as a
4-host-device subprocess (the bench process's jax is already initialized
single-device, so the mesh must live in a child) and reports the
end-to-end switching rows — strained-cluster ``speedup_vs_sync`` (floor:
may not shrink), ``switch_count`` and ``time_to_switch_steps`` (monotone:
may not grow).  The sim clock is seeded-rng deterministic and independent
of jitted-step wall time, so these columns gate exactly.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.configs.recsys import CRITEO_DEEPFM
from repro.core import default_setups, run_continual
from repro.data import make_clickstream
from repro.models.recsys import init_recsys
from repro.sim.cluster import ClusterSpec

CFG = CRITEO_DEEPFM
MODES = ["gba", "hop_bs", "bsp", "hop_bw", "async", "async_setS"]

# fixed regardless of --fast: the gated columns must match the committed
# baseline bit-for-bit, and the run is already bench-cheap (tiny demo MLP)
SWITCH_WORKERS = 4
SWITCH_BATCHES = 240


def _driver_json(plan: str) -> dict:
    """One ``switch_driver`` subprocess run (auto + forced-sync legs on
    the same plan); its last stdout line is the JSON result."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)          # the driver sets its own
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.switch_driver",
         "--host-devices", str(SWITCH_WORKERS),
         "--workers", str(SWITCH_WORKERS),
         "--batches", str(SWITCH_BATCHES), "--plan", plan,
         "--mode", "auto", "--compare-sync", "--json"],
        capture_output=True, text=True, timeout=900, env=env)
    if proc.returncode != 0:
        raise RuntimeError(
            f"switch_driver --plan {plan} failed:\n{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run_switching() -> list[str]:
    """End-to-end switching trajectory rows (suite ``switching``)."""
    rows = []
    for plan in ("strained", "quiet"):
        t0 = time.perf_counter()
        out = _driver_json(plan)
        us = (time.perf_counter() - t0) * 1e6
        derived = (f"switch_count={out['switch_count']};"
                   f"deadlocked={out['deadlocked']};"
                   f"crashes={out['crashes']};rejoins={out['rejoins']};"
                   f"sync_timeouts={out['sync_timeouts']};"
                   f"lost_tokens={out['lost_batches']};"
                   f"swaps_verified={out['swaps_verified']};"
                   f"speedup_vs_sync={out['speedup_vs_sync']:.4f}")
        if out["time_to_first_switch_steps"] is not None:
            derived += (f";time_to_switch_steps="
                        f"{out['time_to_first_switch_steps']}")
        rows.append(csv_row(f"fig6.switch_driver.{plan}", us, derived))
    return rows


def run(base_days: int = 8, eval_days: int = 3) -> list[str]:
    stream = make_clickstream(CFG, seed=0, batches_per_day=48,
                              batch_size=256,
                              num_days=base_days + 2 * eval_days + 2)
    setups = default_setups(base_global=2048)
    spec = ClusterSpec(num_workers=16, straggler_frac=0.25,
                       straggler_slowdown=5.0, jitter=0.2, seed=0)
    t0 = time.perf_counter()

    base = init_recsys(jax.random.PRNGKey(0), CFG)
    base, res0 = run_continual(base, CFG, stream, ["sync"] * base_days,
                               setups, spec, eval_batches=16)
    sync_auc = res0.auc_per_day[-1]
    rows = [csv_row("fig6.base_sync", 0.0,
                    f"auc_last={sync_auc:.4f};"
                    f"curve={'|'.join(f'{a:.4f}' for a in res0.auc_per_day)}")]

    # continued sync = the reference line
    _, res_sync = run_continual(base, CFG, stream, ["sync"] * eval_days,
                                setups, spec, eval_batches=16,
                                start_day=base_days)
    ref = res_sync.auc_per_day
    rows.append(csv_row("fig6.from_sync.sync", 0.0,
                        f"first={ref[0]:.4f};avg={np.mean(ref):.4f}"))

    from_results = {}
    for mode in MODES:
        _, res = run_continual(base, CFG, stream, [mode] * eval_days,
                               setups, spec, eval_batches=16,
                               start_day=base_days)
        from_results[mode] = res.auc_per_day
        rows.append(csv_row(
            f"fig6.from_sync.{mode}", 0.0,
            f"first={res.auc_per_day[0]:.4f};"
            f"avg={np.mean(res.auc_per_day):.4f};"
            f"drop_vs_sync={ref[0] - res.auc_per_day[0]:+.4f}"))

    # switching back: mode for eval_days then sync for eval_days
    for mode in MODES:
        p, _ = run_continual(base, CFG, stream, [mode] * eval_days,
                             setups, spec, eval_batches=16,
                             start_day=base_days)
        _, res_back = run_continual(p, CFG, stream, ["sync"] * eval_days,
                                    setups, spec, eval_batches=16,
                                    start_day=base_days + eval_days)
        rows.append(csv_row(
            f"fig6.to_sync.{mode}", 0.0,
            f"first={res_back.auc_per_day[0]:.4f};"
            f"avg={np.mean(res_back.auc_per_day):.4f}"))

    gba_first = from_results["gba"][0]
    best_base = max(np.mean(from_results[m]) for m in MODES if m != "gba")
    claims = (f"gba_first_day_gap={ref[0] - gba_first:+.4f};"
              f"gba_avg={np.mean(from_results['gba']):.4f};"
              f"best_baseline_avg={best_base:.4f};"
              f"gba_beats_baselines="
              f"{np.mean(from_results['gba']) >= best_base - 1e-4}")
    us = (time.perf_counter() - t0) * 1e6
    rows.append(csv_row("fig6.claims", us, claims))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
