"""Markdown perf-trajectory report: fresh BENCH_kernels.json vs baseline.

    python -m benchmarks.report --baseline /tmp/committed.json \
        --fresh BENCH_kernels.json

CI (.github/workflows/ci.yml) pipes the output into
``$GITHUB_STEP_SUMMARY`` after ``scripts/ci.sh`` regenerates the fresh
JSON, so every commit's run page shows the per-row trajectory — the
structural columns the ``--check`` gate enforces (vmem / launch / buffer
/ peak-gather / quantized-wire bytes, ratio and dtype verdict) plus the
ungated interpret-mode wall time — instead of the
numbers living only inside a downloadable artifact.  Pure-stdlib on
purpose: the report step must not need the repro package or jax.
"""
from __future__ import annotations

import argparse
import json

# gated structural columns (benchmarks.run MONOTONE_COLS + FLOOR_COLS +
# the quantized-wire entries of EXACT_COLS), duplicated literally so
# this module stays importable without jax
COLUMNS = ("vmem_bytes", "launch_ratio", "buffer_ratio",
           "peak_gather_bytes", "bytes_on_wire", "compression_ratio",
           "audit_wire_dtype", "switch_count", "time_to_switch_steps",
           "speedup_vs_sync", "hit_rate", "freshness_lag_steps",
           "audit_cache_bytes", "audit_hit_skips_kernel")


def _fmt(v) -> str:
    if v is None:
        return "—"
    if isinstance(v, float) and v == int(v) and abs(v) >= 1000:
        return f"{int(v):,}"
    if isinstance(v, float):
        return f"{v:g}"
    return str(v)


def _cell(base, cur) -> str:
    """One table cell: value, annotated when it moved vs baseline."""
    if base is None and cur is None:
        return "—"
    if base == cur:
        return _fmt(cur)
    return f"{_fmt(base)} → **{_fmt(cur)}**"


def render(baseline: list[dict], fresh: list[dict]) -> str:
    base_by = {r["name"]: r for r in baseline}
    fresh_by = {r["name"]: r for r in fresh}
    lines = ["## Kernel bench trajectory (fresh vs committed baseline)",
             "",
             "| row | us/call (base → fresh) | " +
             " | ".join(COLUMNS) + " |",
             "|---|---|" + "---|" * len(COLUMNS)]
    for name in sorted(set(base_by) | set(fresh_by)):
        b, f = base_by.get(name), fresh_by.get(name)
        if f is None:
            status = (" *(superseded)*"
                      if (b or {}).get("status") == "superseded"
                      else " **(MISSING fresh)**")
            lines.append(f"| ~~{name}~~{status} | {_fmt(b['us_per_call'])}"
                         f" → — |" + " — |" * len(COLUMNS))
            continue
        if b is None:
            us = f"— → {_fmt(f['us_per_call'])} **(new row)**"
        else:
            b_us, f_us = b["us_per_call"], f["us_per_call"]
            ratio = f" ({f_us / b_us:.2f}x)" if b_us else ""
            us = f"{_fmt(b_us)} → {_fmt(f_us)}{ratio}"
        cells = " | ".join(
            _cell((b or {}).get(c), f.get(c)) for c in COLUMNS)
        lines.append(f"| {name} | {us} | {cells} |")
    lines += ["",
              "us/call is interpret-mode wall time (load noise; gated only "
              "at 5x). The structural columns are exact and gated: "
              "vmem/buffer/peak-gather and the quantized-wire "
              "bytes_on_wire/compression_ratio may not grow, launch_ratio "
              "may not shrink, audit_wire_dtype must equal the baseline "
              "(GBA-COLL-005 verdict: the policy dtype when the compressed "
              "trace is leak-free), on the end-to-end switching rows "
              "switch_count / time_to_switch_steps may not grow while the "
              "strained-cluster speedup_vs_sync may not shrink, and on the "
              "online-serving rows hit_rate may not shrink, "
              "freshness_lag_steps may not grow, and the cache geometry / "
              "hit-skips-kernel audit columns must equal the baseline."]
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed baseline JSON (e.g. git show "
                         "HEAD:BENCH_kernels.json)")
    ap.add_argument("--fresh", default="BENCH_kernels.json",
                    help="freshly generated JSON (scripts/ci.sh output)")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    print(render(baseline, fresh))


if __name__ == "__main__":
    main()
