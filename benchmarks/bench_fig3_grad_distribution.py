"""Paper Fig. 3 / Insight 1: the distribution of aggregated-gradient L2
norms is governed by the *aggregation size*, not by the training mode.

We compute dense-module gradient norms for:
  sync with N_s x B_s   (global batch G)
  BSP-G  (async aggregation of M = G/B_a gradients -> same G)
  BSP-half (aggregation size G/2)
  async  (single local batch B_a)

Claim validated when |mean(BSP-G) - mean(sync)| << |mean(async) - mean(sync)|
and the same for BSP-half.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.configs.recsys import CRITEO_DEEPFM
from repro.data import make_clickstream
from repro.models import recsys as R
from repro.optim import get_optimizer

CFG = CRITEO_DEEPFM


def _dense_norm(grads) -> float:
    dense = {k: v for k, v in grads.items() if k not in ("embed", "linear")}
    return float(jnp.sqrt(sum(jnp.sum(jnp.square(x))
                              for x in jax.tree.leaves(dense))))


def run(n_samples: int = 24) -> list[str]:
    stream = make_clickstream(CFG, seed=0, batch_size=256)
    params = R.init_recsys(jax.random.PRNGKey(0), CFG)
    # briefly train so gradients are not at the init saddle
    opt = get_optimizer("adam", 1e-3)
    state = opt.init(params)
    grad_fn = jax.jit(jax.grad(lambda p, b: R.bce_loss(p, CFG, b)))
    for i in range(20):
        params, state = opt.update(params, grad_fn(params, stream.batch(0, i)),
                                   state)

    t0 = time.perf_counter()

    def agg_norms(agg_size: int, count: int, tag: int) -> np.ndarray:
        out = []
        for j in range(count):
            gs = [grad_fn(params, stream.batch(1, tag * 10_000 + j * agg_size
                                               + i))
                  for i in range(agg_size)]
            mean = jax.tree.map(lambda *x: sum(x) / agg_size, *gs)
            out.append(_dense_norm(mean))
        return np.array(out)

    G = 8  # aggregation size in local batches (G*256 samples)
    sync = agg_norms(G, n_samples, 0)
    bsp_match = agg_norms(G, n_samples, 1)
    bsp_half = agg_norms(G // 2, n_samples, 2)
    async_ = agg_norms(1, n_samples, 3)
    us = (time.perf_counter() - t0) * 1e6 / (4 * n_samples)

    gap_match = abs(bsp_match.mean() - sync.mean())
    gap_half = abs(bsp_half.mean() - sync.mean())
    gap_async = abs(async_.mean() - sync.mean())
    ok = gap_match < gap_half < gap_async
    rows = [
        csv_row("fig3.grad_norm.sync_G", us,
                f"mean={sync.mean():.4f};std={sync.std():.4f}"),
        csv_row("fig3.grad_norm.bsp_same_G", us,
                f"mean={bsp_match.mean():.4f};std={bsp_match.std():.4f}"),
        csv_row("fig3.grad_norm.bsp_half_G", us,
                f"mean={bsp_half.mean():.4f};std={bsp_half.std():.4f}"),
        csv_row("fig3.grad_norm.async_B", us,
                f"mean={async_.mean():.4f};std={async_.std():.4f}"),
        csv_row("fig3.claim_same_G_same_distribution", us,
                f"validated={ok};gap_G={gap_match:.4f};"
                f"gap_halfG={gap_half:.4f};gap_async={gap_async:.4f}"),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
