"""Kernel micro-benchmarks: Pallas (interpret mode) vs jnp oracle, plus the
*derived* TPU HBM-traffic model that motivates each fusion (interpret-mode
wall time on CPU is NOT a TPU number — the derived column is the claim).

Rows cover the kernels the train path actually launches:

* ``gba_apply`` — the fused PS apply (decay-aggregate + Adagrad, one VMEM
  pass); the ref chain reads the buffer 3x (mask/mul/reduce) and round-trips
  the aggregated gradient through HBM before the optimizer pass.
* ``embedding_bag_grad`` — the sort-based segment-reduce backward; the
  derived columns record the grid parallelism (programs) vs the old
  ``grid=(1,)`` serial scatter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_call
from repro.kernels import ref
from repro.kernels.embedding_bag import (BLOCK_V, embedding_bag,
                                         embedding_bag_grad)
from repro.kernels.fused_adagrad import fused_adagrad
from repro.kernels.gba_aggregate import gba_aggregate
from repro.kernels.gba_apply import gba_apply

HBM_BW = 819e9


def run() -> list[str]:
    rows = []
    key = jax.random.PRNGKey(0)

    # gba_apply: fused aggregate+apply.  Buffer bytes moved: ref chain
    # reads the (M, N) buffer 3x (mask -> broadcast-mul -> reduce); the
    # fused kernel reads it once -> 0.33x buffer traffic, and the
    # aggregated gradient never round-trips through HBM.
    m, n = 16, 1 << 16
    p = jax.random.normal(key, (n,))
    ac = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (n,)))
    buf = jax.random.normal(jax.random.PRNGKey(2), (m, n), jnp.bfloat16)
    toks = jax.random.randint(key, (m,), 0, 8)
    step = jnp.int32(7)
    t_ref = time_call(jax.jit(lambda *a: ref.gba_apply_ref(
        *a, 0.01, iota=4)), p, ac, buf, toks, step, iters=5)
    t_ker = time_call(lambda *a: gba_apply(*a, 0.01, iota=4),
                      p, ac, buf, toks, step, iters=2)
    buf_bytes_fused = m * n * 2                 # one bf16 read of the buffer
    buf_bytes_ref = 3 * m * n * 2               # mask/mul/reduce chain
    total_fused = buf_bytes_fused + 4 * n * 4   # + p/a reads, p/a writes
    rows.append(csv_row(
        "kernel.gba_apply.16x64k", t_ker,
        f"ref_us={t_ref:.1f};buffer_bytes={buf_bytes_fused:.2e};"
        f"ref_buffer_bytes={buf_bytes_ref:.2e};"
        f"buffer_ratio={buf_bytes_fused / buf_bytes_ref:.2f};"
        f"tpu_roofline_us={total_fused / HBM_BW * 1e6:.1f};"
        f"fusion=aggregate+adagrad_one_pass"))

    # gba_aggregate: the standalone reduction (still behind
    # ops.gba_aggregate_tree); the train path now prefers gba_apply
    m, d = 16, 1 << 16
    g = jax.random.normal(key, (m, d), jnp.bfloat16)
    t_ref = time_call(jax.jit(lambda a, b, c: ref.gba_aggregate_ref(
        a, b, c, iota=4)), g, toks, step, iters=5)
    t_ker = time_call(lambda a, b, c: gba_aggregate(a, b, c, iota=4),
                      g, toks, step, iters=2)
    traffic = m * d * 2
    rows.append(csv_row(
        "kernel.gba_aggregate.16x64k.bf16", t_ker,
        f"ref_us={t_ref:.1f};buffer_bytes={traffic:.2e};"
        f"tpu_roofline_us={traffic / HBM_BW * 1e6:.1f};"
        f"superseded_by=gba_apply"))

    # embedding_bag: gather+pool fused
    b, f, v, dim = 512, 26, 100_003, 16
    ids = jax.random.randint(key, (b, f), 0, v)
    table = jax.random.normal(key, (v, dim), jnp.float32)
    t_ref = time_call(jax.jit(ref.embedding_bag_ref), ids, table, iters=5)
    t_ker = time_call(embedding_bag, ids, table, iters=2)
    traffic = b * f * dim * 4 + b * dim * 4
    rows.append(csv_row(
        "kernel.embedding_bag.512x26", t_ker,
        f"ref_us={t_ref:.1f};row_bytes={traffic:.2e};"
        f"tpu_roofline_us={traffic / HBM_BW * 1e6:.2f}"))

    # embedding_bag_grad: sorted-scatter backward.  The old kernel was a
    # single serial program; the sort-based segment reduce grids over
    # vocab blocks with disjoint outputs.
    gb, gf, gv, gd = 256, 26, 20_011, 16
    gids = jax.random.randint(key, (gb, gf), 0, gv)
    gout = jax.random.normal(key, (gb, gd), jnp.float32)
    t_ref = time_call(jax.jit(lambda i, g: ref.embedding_bag_grad_ref(
        i, g, gv)), gids, gout, iters=5)
    t_ker = time_call(lambda i, g: embedding_bag_grad(i, g, gv),
                      gids, gout, iters=2)
    e = gb * gf
    programs = (gv + BLOCK_V - 1) // BLOCK_V
    traffic = (e * (4 + gd * 4)          # sorted (id, row) stream read
               + gv * (gd * 4 + 4))      # table grads + counts written
    rows.append(csv_row(
        "kernel.embedding_bag_grad.256x26.sorted", t_ker,
        f"ref_us={t_ref:.1f};grid_programs={programs};serial=0;"
        f"scatter_bytes={traffic:.2e};"
        f"tpu_roofline_us={traffic / HBM_BW * 1e6:.1f}"))

    # fused_adagrad: 3 reads + 2 writes in one pass
    n = 1 << 18
    p = jax.random.normal(key, (n,))
    gr = jax.random.normal(jax.random.PRNGKey(1), (n,))
    a = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (n,)))
    t_ref = time_call(jax.jit(lambda x, y, z: ref.fused_adagrad_ref(
        x, y, z, 0.01)), p, gr, a, iters=5)
    t_ker = time_call(lambda x, y, z: fused_adagrad(x, y, z, 0.01),
                      p, gr, a, iters=2)
    traffic = n * 4 * 5
    rows.append(csv_row(
        "kernel.fused_adagrad.256k.f32", t_ker,
        f"ref_us={t_ref:.1f};traffic_bytes={traffic:.2e};"
        f"tpu_roofline_us={traffic / HBM_BW * 1e6:.1f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
