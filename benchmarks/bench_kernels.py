"""Kernel micro-benchmarks: Pallas (interpret mode) vs jnp oracle, plus the
*derived* TPU HBM-traffic model that motivates each fusion (interpret-mode
wall time on CPU is NOT a TPU number — the derived column is the claim).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_call
from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.fused_adagrad import fused_adagrad
from repro.kernels.gba_aggregate import gba_aggregate

HBM_BW = 819e9


def run() -> list[str]:
    rows = []
    key = jax.random.PRNGKey(0)

    # gba_aggregate: naive = read buffer 3x (mask/mul/reduce); fused = 1x
    m, d = 16, 1 << 16
    g = jax.random.normal(key, (m, d), jnp.bfloat16)
    toks = jax.random.randint(key, (m,), 0, 8)
    step = jnp.int32(7)
    t_ref = time_call(jax.jit(lambda a, b, c: ref.gba_aggregate_ref(
        a, b, c, iota=4)), g, toks, step, iters=5)
    t_ker = time_call(lambda a, b, c: gba_aggregate(a, b, c, iota=4),
                      g, toks, step, iters=2)
    traffic = m * d * 2
    rows.append(csv_row(
        "kernel.gba_aggregate.16x64k.bf16", t_ker,
        f"ref_us={t_ref:.1f};buffer_bytes={traffic:.2e};"
        f"tpu_roofline_us={traffic / HBM_BW * 1e6:.1f};"
        f"fusion_saves=2x_buffer_reads"))

    # embedding_bag: gather+pool fused
    b, f, v, dim = 512, 26, 100_003, 16
    ids = jax.random.randint(key, (b, f), 0, v)
    table = jax.random.normal(key, (v, dim), jnp.float32)
    t_ref = time_call(jax.jit(ref.embedding_bag_ref), ids, table, iters=5)
    t_ker = time_call(embedding_bag, ids, table, iters=2)
    traffic = b * f * dim * 4 + b * dim * 4
    rows.append(csv_row(
        "kernel.embedding_bag.512x26", t_ker,
        f"ref_us={t_ref:.1f};row_bytes={traffic:.2e};"
        f"tpu_roofline_us={traffic / HBM_BW * 1e6:.2f}"))

    # fused_adagrad: 3 reads + 2 writes in one pass
    n = 1 << 18
    p = jax.random.normal(key, (n,))
    gr = jax.random.normal(jax.random.PRNGKey(1), (n,))
    a = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (n,)))
    t_ref = time_call(jax.jit(lambda x, y, z: ref.fused_adagrad_ref(
        x, y, z, 0.01)), p, gr, a, iters=5)
    t_ker = time_call(lambda x, y, z: fused_adagrad(x, y, z, 0.01),
                      p, gr, a, iters=2)
    traffic = n * 4 * 5
    rows.append(csv_row(
        "kernel.fused_adagrad.256k.f32", t_ker,
        f"ref_us={t_ref:.1f};traffic_bytes={traffic:.2e};"
        f"tpu_roofline_us={traffic / HBM_BW * 1e6:.1f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
