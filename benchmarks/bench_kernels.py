"""Kernel micro-benchmarks: Pallas (interpret mode) vs jnp oracle, plus the
*derived* TPU HBM-traffic model that motivates each fusion (interpret-mode
wall time on CPU is NOT a TPU number — the derived column is the claim).

Rows cover the kernels the train path actually launches:

* ``gba_apply`` — the fused PS apply (decay-aggregate + Adagrad, one VMEM
  pass); the ref chain reads the buffer 3x (mask/mul/reduce) and round-trips
  the aggregated gradient through HBM before the optimizer pass.
* ``embedding_bag`` / ``embedding_bag_grad`` — the DMA-streamed sparse
  module.  The ``vmem_bytes`` column is the double-buffered scratch
  residency (2 table tiles + 2 entry chunks forward, 2 row chunks + 2 id
  chunks backward): block-bounded and identical at V=100k and V=1M, while
  the ``row_bytes``/``scatter_bytes`` HBM-traffic model stays at the PR-1
  level because only touched tiles / sorted runs ever move.

* ``gba_apply_sharded`` — the PS-shard rendering of the fused apply
  (``core.flat_sharded.ShardedFlatLayout``): each shard launches
  ``gba_apply`` ONCE on its contiguous tile-aligned ``(M, shard_size)``
  slice, vs one launch per leaf for the per-leaf chain.  The row times the
  shard-local launch (exactly what each device runs inside shard_map) and
  records the launch-count ratio, per-shard VMEM residency, and the
  layer-grouped schedule's ``peak_gather_bytes`` (per-device peak live
  gathered bytes = the largest layer group, vs ``full_gather_bytes`` for
  the full-vector gather) — all gated: ``vmem_bytes`` and
  ``peak_gather_bytes`` may not grow and ``launch_ratio`` may not shrink
  (``benchmarks.run --check``).

Rows whose kernel has been superseded on the train path (``gba_aggregate``
by ``gba_apply``) are skipped by default so the JSON stops reporting a dead
hot path as current; pass ``all_rows=True`` (CLI ``--all``) to include
them, tagged ``status=superseded``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_call
from repro.kernels import ref
from repro.kernels.embedding_bag import (BLOCK_D, BLOCK_V, CHUNK_E,
                                         embedding_bag, embedding_bag_grad,
                                         stream_vmem_bytes)
from repro.kernels.fused_adagrad import fused_adagrad
from repro.kernels.gba_aggregate import gba_aggregate
from repro.kernels.gba_apply import apply_vmem_bytes, gba_apply

HBM_BW = 819e9


def _sharded_apply_rows(m: int = 8) -> list[str]:
    """One row per shard count: the fused sharded apply on a real reduced
    LM layout (granite-8b smoke params), timed as the per-shard launch.

    The layout is the production default — layer-grouped under the
    model's canonical grouping — so the row also records the grouped
    collective schedule's footprint: ``peak_gather_bytes`` (per-device
    peak live gathered bytes = the LARGEST layer group, gated: may not
    grow) vs ``full_gather_bytes`` (what the PR-4 full-vector gather
    pinned = padded_total f32).  Grouping does not change the timed
    launch: the per-shard slice stays one contiguous run and the apply
    stays one ``gba_apply`` call.

    The ``audit_*`` columns come from the static auditor
    (``repro.analysis``): the fused step's collective census under an
    abstract mesh at this shard count, and the kernel VMEM recomputed
    from the exported launch meta — gated EXACTLY by ``run --check``.
    The quantized-wire columns record the int8 ``CompressionPolicy``'s
    per-worker routing cost: ``bytes_on_wire`` / ``compression_ratio``
    (gated monotone like ``gather_ratio`` — may not grow) and
    ``audit_wire_dtype`` (exact-gated; the policy dtype only when the
    compressed trace passes GBA-COLL-005, else ``leak``)."""
    from repro.analysis.audit import probe_loss, trace_fused_step
    from repro.analysis.dataflow import flow_fused_step
    from repro.analysis.jaxpr_audit import (census_counts, check_wire_dtypes,
                                            collective_census)
    from repro.core.compression import CompressionPolicy
    from repro.core.flat_sharded import ShardedFlatLayout
    from repro.configs import get_config
    from repro.kernels.gba_apply import launch_meta
    from repro.models import transformer as T

    cfg = get_config("granite-8b").reduced()
    pshapes = jax.eval_shape(
        functools.partial(T.init_model, cfg=cfg), jax.random.PRNGKey(0))
    n_leaves = len(jax.tree.leaves(pshapes))
    rows = []
    for shards in (4, 8):
        layout = ShardedFlatLayout.from_params(pshapes, shards,
                                               group_by=T.param_group_key)
        sn = layout.shard_size
        # auditor-derived structural columns, gated EXACTLY (run --check):
        # the fused step's collective census under an abstract mesh at
        # this shard count, and the kernel VMEM recomputed from the
        # exported launch meta — any drift means the collective schedule
        # or the launch geometry changed and the baseline must be
        # regenerated deliberately
        probe_batch = {"x": jax.ShapeDtypeStruct((shards * 8,), jnp.float32)}
        site = f"bench/gba_apply_sharded/{shards}shard"
        jx_plain = trace_fused_step(layout, shards, probe_loss, probe_batch)
        census = census_counts(collective_census(jx_plain))
        # quantized-wire accounting + COLL-005 verdict on the compressed
        # trace: audit_wire_dtype is the policy dtype only when the trace
        # checks clean, so a f32 leak past warmup flips an exact-gated
        # column ("leak") instead of passing silently
        pol = CompressionPolicy(scheme="int8", warmup_steps=1)
        jx_int8 = trace_fused_step(layout, shards, probe_loss, probe_batch,
                                   compress=pol)
        wire_findings = check_wire_dtypes(jx_int8, layout, shards, pol, site)
        wire_dtype = pol.wire_dtype() if not wire_findings else "leak"
        # staleness-taint verdict on the same two traces (GBA-FLOW-001/003:
        # no raw gradient or error-feedback residual reaches the update),
        # exact-gated at 0 by run --check
        wire = {name: jax.ShapeDtypeStruct(shape, jnp.float32)
                for name, shape in layout.wire_state_shapes(
                    shards, pol.scheme).items()}
        flow_findings = (
            flow_fused_step(jx_plain, probe_batch, site=site)
            + flow_fused_step(jx_int8, probe_batch, site=site, wire=wire))
        meta = launch_meta(sn, m)
        audit_vmem = meta.vmem_bytes(meta.vmem_counted)
        key = jax.random.PRNGKey(shards)
        p = jax.random.normal(key, (sn,))
        ac = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (sn,)))
        buf = jax.random.normal(jax.random.PRNGKey(2), (m, sn))
        toks = jax.random.randint(key, (m,), 0, 8)
        step = jnp.int32(7)
        t_ker = time_call(lambda *a: gba_apply(*a, 0.01, iota=4),
                          p, ac, buf, toks, step, iters=2)
        # per-leaf chain on the same module: one fused launch per leaf
        # (the most favorable per-leaf baseline; the unfused aggregate ->
        # adagrad chain doubles it) vs ONE launch per shard
        ratio = n_leaves / 1.0
        traffic = (m * sn + 4 * sn) * 4
        rows.append(csv_row(
            f"kernel.gba_apply_sharded.granite8b-smoke.{shards}shard",
            t_ker,
            f"num_shards={shards};shard_n={sn};"
            f"padded_total={layout.padded_total};tile={layout.tile};"
            f"launches_per_apply=1;per_leaf_launches={n_leaves};"
            f"launch_ratio={ratio:.1f};"
            f"vmem_bytes={apply_vmem_bytes(m)};"
            f"audit_all_gather={census.get('all_gather', 0)};"
            f"audit_all_to_all={census.get('all_to_all', 0)};"
            f"audit_vmem_bytes={audit_vmem};"
            f"layer_groups={layout.num_groups};"
            f"peak_gather_bytes={layout.peak_gather_bytes};"
            f"full_gather_bytes={layout.full_gather_bytes};"
            f"gather_ratio="
            f"{layout.peak_gather_bytes / layout.full_gather_bytes:.3f};"
            f"bytes_on_wire={pol.wire_bytes(layout)};"
            f"compression_ratio={pol.compression_ratio(layout):.3f};"
            f"audit_wire_dtype={wire_dtype};"
            f"audit_flow_findings={len(flow_findings)};"
            f"tpu_roofline_us={traffic / HBM_BW * 1e6:.1f};"
            f"fusion=one_launch_per_ps_shard"))
    return rows


def _embedding_rows(b, f, v, dim, tag, *, time_ref=True) -> list[str]:
    """Forward + backward rows for one (B, F, V, D) sparse-module shape."""
    rows = []
    key = jax.random.PRNGKey(b + v)
    ids = jax.random.randint(key, (b, f), 0, v)
    table = jax.random.normal(key, (v, dim), jnp.float32)
    vmem = stream_vmem_bytes(dim)
    e = b * f
    n_active = int(np.unique(np.asarray(ids) // BLOCK_V).size)

    # forward: gather+pool with HBM-resident table; only the n_active
    # touched (BLOCK_V, BLOCK_D) tiles are streamed (empty blocks never
    # move), so tile traffic is id-bounded, not V-bounded
    t_ref = (time_call(jax.jit(ref.embedding_bag_ref), ids, table, iters=5)
             if time_ref else 0.0)
    t_ker = time_call(embedding_bag, ids, table, iters=2)
    traffic = b * f * dim * 4 + b * dim * 4
    tile_bytes = n_active * BLOCK_V * vmem["block_d"] * 4
    # the forward's only parallel grid axis is the D tiling (1 program for
    # narrow tables); within a program vocab blocks run serially behind the
    # double-buffered DMA — recorded so the JSON doesn't hide it
    ndb = -(-dim // vmem["block_d"])
    rows.append(csv_row(
        f"kernel.embedding_bag.{tag}", t_ker,
        f"ref_us={t_ref:.1f};row_bytes={traffic:.2e};"
        f"tile_bytes={tile_bytes:.2e};vmem_bytes={vmem['fwd']};"
        f"vmem_table_ratio={vmem['fwd'] / (v * dim * 4):.2e};"
        f"grid_programs={ndb};serial_over=vocab_blocks_dma_overlapped;"
        f"tpu_roofline_us={traffic / HBM_BW * 1e6:.2f};"
        f"stream=hbm_tiles_double_buffered"))

    # backward: sorted-scatter segment reduce, sorted (id, row) runs
    # streamed in CHUNK_E chunks; traffic model unchanged from PR-1
    gout = jax.random.normal(key, (b, dim), jnp.float32)
    t_ref = (time_call(jax.jit(lambda i, g: ref.embedding_bag_grad_ref(
        i, g, v)), ids, gout, iters=5) if time_ref else 0.0)
    t_ker = time_call(lambda i, g: embedding_bag_grad(i, g, v),
                      ids, gout, iters=2)
    programs = (v + BLOCK_V - 1) // BLOCK_V
    traffic = (e * (4 + dim * 4)          # sorted (id, row) stream read
               + v * (dim * 4 + 4))       # table grads + counts written
    rows.append(csv_row(
        f"kernel.embedding_bag_grad.{tag}.sorted", t_ker,
        f"ref_us={t_ref:.1f};grid_programs={programs};serial=0;"
        f"scatter_bytes={traffic:.2e};vmem_bytes={vmem['bwd']};"
        f"tpu_roofline_us={traffic / HBM_BW * 1e6:.1f};"
        f"stream=hbm_runs_double_buffered"))
    return rows


def run(all_rows: bool = False) -> list[str]:
    rows = []
    key = jax.random.PRNGKey(0)

    # gba_apply: fused aggregate+apply.  Buffer bytes moved: ref chain
    # reads the (M, N) buffer 3x (mask -> broadcast-mul -> reduce); the
    # fused kernel reads it once -> 0.33x buffer traffic, and the
    # aggregated gradient never round-trips through HBM.
    m, n = 16, 1 << 16
    p = jax.random.normal(key, (n,))
    ac = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (n,)))
    buf = jax.random.normal(jax.random.PRNGKey(2), (m, n), jnp.bfloat16)
    toks = jax.random.randint(key, (m,), 0, 8)
    step = jnp.int32(7)
    t_ref = time_call(jax.jit(lambda *a: ref.gba_apply_ref(
        *a, 0.01, iota=4)), p, ac, buf, toks, step, iters=5)
    t_ker = time_call(lambda *a: gba_apply(*a, 0.01, iota=4),
                      p, ac, buf, toks, step, iters=2)
    buf_bytes_fused = m * n * 2                 # one bf16 read of the buffer
    buf_bytes_ref = 3 * m * n * 2               # mask/mul/reduce chain
    total_fused = buf_bytes_fused + 4 * n * 4   # + p/a reads, p/a writes
    rows.append(csv_row(
        "kernel.gba_apply.16x64k", t_ker,
        f"ref_us={t_ref:.1f};buffer_bytes={buf_bytes_fused:.2e};"
        f"ref_buffer_bytes={buf_bytes_ref:.2e};"
        f"buffer_ratio={buf_bytes_fused / buf_bytes_ref:.2f};"
        f"tpu_roofline_us={total_fused / HBM_BW * 1e6:.1f};"
        f"fusion=aggregate+adagrad_one_pass"))

    rows += _sharded_apply_rows()

    if all_rows:
        # gba_aggregate: standalone reduction (still behind
        # ops.gba_aggregate_tree) — superseded on the train path by
        # gba_apply, so reported only on request
        m, d = 16, 1 << 16
        g = jax.random.normal(key, (m, d), jnp.bfloat16)
        t_ref = time_call(jax.jit(lambda a, b, c: ref.gba_aggregate_ref(
            a, b, c, iota=4)), g, toks, step, iters=5)
        t_ker = time_call(lambda a, b, c: gba_aggregate(a, b, c, iota=4),
                          g, toks, step, iters=2)
        traffic = m * d * 2
        rows.append(csv_row(
            "kernel.gba_aggregate.16x64k.bf16", t_ker,
            f"ref_us={t_ref:.1f};buffer_bytes={traffic:.2e};"
            f"tpu_roofline_us={traffic / HBM_BW * 1e6:.1f};"
            f"status=superseded;superseded_by=gba_apply"))

    # streamed sparse module at the PR-1 shapes (baseline continuity) ...
    rows += _embedding_rows(512, 26, 100_003, 16, "512x26")
    rows += _embedding_rows(256, 26, 20_011, 16, "256x26")
    # ... and at a production-scale vocabulary: same vmem_bytes column as
    # above (block-bounded), ~50x the table size.  The jnp oracle would
    # materialize (1M, D) scatter buffers per call — timed rows only.
    rows += _embedding_rows(64, 26, 1_000_000, 16, "1M", time_ref=False)

    # fused_adagrad: 3 reads + 2 writes in one pass
    n = 1 << 18
    p = jax.random.normal(key, (n,))
    gr = jax.random.normal(jax.random.PRNGKey(1), (n,))
    a = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (n,)))
    t_ref = time_call(jax.jit(lambda x, y, z: ref.fused_adagrad_ref(
        x, y, z, 0.01)), p, gr, a, iters=5)
    t_ker = time_call(lambda x, y, z: fused_adagrad(x, y, z, 0.01),
                      p, gr, a, iters=2)
    traffic = n * 4 * 5
    rows.append(csv_row(
        "kernel.fused_adagrad.256k.f32", t_ker,
        f"ref_us={t_ref:.1f};traffic_bytes={traffic:.2e};"
        f"tpu_roofline_us={traffic / HBM_BW * 1e6:.1f}"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--all", action="store_true",
                    help="include superseded kernel rows")
    for r in run(all_rows=ap.parse_args().all):
        print(r)
