"""Benchmark harness: one module per paper table/figure (+ kernels +
roofline).  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only fig6,tab52] [--fast]
        [--json [PATH]] [--check [BASELINE]] [--all]

``--json`` additionally writes the kernel + roofline rows (with the derived
``k=v`` columns parsed into numbers) to ``BENCH_kernels.json`` so the perf
trajectory is machine-readable across PRs.

``--check`` compares the fresh kernel/roofline rows against a committed
baseline JSON (default ``BENCH_kernels.json``) and exits non-zero on a
>5x ``us_per_call`` regression (interpret-mode wall time is load noise;
only catastrophic algorithmic blowups should trip it), any growth of a
``vmem_bytes``, ``buffer_ratio``, ``peak_gather_bytes``,
``gather_ratio``, ``bytes_on_wire``, ``compression_ratio``,
``switch_count``, ``time_to_switch_steps`` or ``freshness_lag_steps``
column, any shrink of a
``launch_ratio``, ``speedup_vs_sync`` or ``hit_rate`` column (the
end-to-end switching trajectory rows from
``bench_fig6_switching.run_switching`` and the online-serving rows from
``bench_tab52_qps.run_serving`` — sim-clock/seeded
deterministic, so they gate exactly), any change at all of an ``audit_*``
column (auditor-derived collective census / launch-meta VMEM /
quantized-wire dtype verdict / serving cache geometry and
hit-skips-kernel proof), a
baseline row that disappeared, or a fresh row missing from the baseline
(uncommitted drift: adding a bench row without regenerating and
committing the JSON fails fast) — the CI perf gate (scripts/ci.sh).
``--all`` includes rows for superseded kernels.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

JSON_SUITES = ("kernels", "roofline", "switching", "serving")
# --check: max allowed us_per_call growth.  Interpret-mode wall time
# swings ~4x with container/CI load (the bench docstrings call it noise;
# the derived columns are the claims), so this only catches catastrophic
# algorithmic blowups (serialized grids, O(V) work) — the structural
# columns below are gated exactly.
US_REGRESSION = 5.0
MONOTONE_COLS = ("vmem_bytes", "buffer_ratio", "peak_gather_bytes",
                 "gather_ratio", "bytes_on_wire", "compression_ratio",
                 # end-to-end switching trajectory: more mode flaps or a
                 # later first switch on the same fault plan = regression
                 "switch_count",
                 "time_to_switch_steps",
                 # serving: the live-sync snapshot may not fall further
                 # behind the trainer on the same publish/sync plan
                 "freshness_lag_steps")          # --check: no growth at all
FLOOR_COLS = ("launch_ratio",
              # strained-cluster auto vs forced-sync, sim clock: the
              # Fig. 6 speedup claim may not shrink (deterministic —
              # seeded-rng timing, independent of jitted wall time)
              "speedup_vs_sync",
              # serving: the hot-ID cache must keep absorbing the Zipf
              # head of a seeded request stream (deterministic counters)
              "hit_rate")                        # --check: no shrink at all
# --check: must EQUAL the baseline.  Auditor-derived structural columns
# (collective census counts, launch-meta VMEM): any drift means the
# collective schedule or kernel geometry changed, which must be a
# deliberate baseline regeneration, never noise.  The serving columns:
# cache geometry (capacity * dim * 4 bytes) and the kernel-call-counter
# proof that an all-hit batch skips the streamed kernel entirely.  The
# finding-count columns (staleness-taint dataflow pass on the sharded
# apply traces, lock-discipline lint on the serving modules) are gated
# at their baseline value of 0: a raw-gradient leak or a serving race
# flips a structural column, never noise.
EXACT_COLS = ("audit_all_gather", "audit_all_to_all", "audit_vmem_bytes",
              "audit_wire_dtype", "audit_cache_bytes",
              "audit_hit_skips_kernel", "audit_flow_findings",
              "audit_race_findings")


def parse_derived(derived: str) -> dict:
    """'a=1.5;b=2e3;c=foo' -> {'a': 1.5, 'b': 2000.0, 'c': 'foo'}."""
    out: dict = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


def rows_to_json(collected: dict[str, list[str]]) -> list[dict]:
    records = []
    for suite, rows in collected.items():
        for row in rows:
            name, us, derived = row.split(",", 2)
            records.append({
                "suite": suite,
                "name": name,
                "us_per_call": float(us),
                **parse_derived(derived),
            })
    return records


def check_records(fresh: list[dict], baseline_path: str) -> list[str]:
    """Compare fresh kernel rows to the committed baseline; return the list
    of human-readable failures (empty = gate passes).

    Superseded rows absent from a fresh default run are not counted as
    disappeared when the baseline tagged them ``status=superseded``.
    Fresh rows with no baseline entry fail too — that is uncommitted
    drift: a new bench row only clears CI once the regenerated JSON is
    committed alongside it.
    """
    try:
        with open(baseline_path) as f:
            baseline = {r["name"]: r for r in json.load(f)}
    except FileNotFoundError:
        return [f"baseline {baseline_path} not found"]
    fresh_by_name = {r["name"]: r for r in fresh}
    failures = []
    for name, cur in fresh_by_name.items():
        if name not in baseline and cur.get("status") != "superseded":
            # superseded rows only appear under --all and are skipped in
            # the committed default-run baseline on purpose
            failures.append(
                f"{name}: fresh row not in committed baseline "
                f"(regenerate and commit {baseline_path})")
    for name, base in baseline.items():
        cur = fresh_by_name.get(name)
        if cur is None:
            if base.get("status") == "superseded":
                continue
            failures.append(f"{name}: present in baseline, missing fresh")
            continue
        b_us, c_us = base.get("us_per_call", 0.0), cur.get("us_per_call", 0.0)
        if b_us > 0 and c_us > US_REGRESSION * b_us:
            failures.append(
                f"{name}: us_per_call {c_us:.1f} > {US_REGRESSION}x "
                f"baseline {b_us:.1f}")
        for col in MONOTONE_COLS:
            if col in base and isinstance(base[col], float):
                c_val = cur.get(col)
                if c_val is None:
                    failures.append(f"{name}: {col} column disappeared")
                elif c_val > base[col]:
                    failures.append(
                        f"{name}: {col} grew {base[col]:g} -> {c_val:g}")
        for col in FLOOR_COLS:
            if col in base and isinstance(base[col], float):
                c_val = cur.get(col)
                if c_val is None:
                    failures.append(f"{name}: {col} column disappeared")
                elif c_val < base[col]:
                    failures.append(
                        f"{name}: {col} shrank {base[col]:g} -> {c_val:g}")
        for col in EXACT_COLS:
            # auditor columns are floats (census counts, VMEM) or strings
            # (audit_wire_dtype); both gate on exact equality
            if col in base and isinstance(base[col], (float, str)):
                c_val = cur.get(col)
                if c_val is None:
                    failures.append(f"{name}: {col} column disappeared")
                elif c_val != base[col]:
                    failures.append(
                        f"{name}: {col} changed {base[col]} -> "
                        f"{c_val} (exact-gated auditor column)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated substrings to select benchmarks")
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes for smoke runs")
    ap.add_argument("--json", nargs="?", const="BENCH_kernels.json",
                    default="",
                    help="write kernel/roofline rows as JSON "
                         "(default BENCH_kernels.json)")
    ap.add_argument("--check", nargs="?", const="BENCH_kernels.json",
                    default="",
                    help="fail on perf/footprint regressions vs a baseline "
                         "JSON (default BENCH_kernels.json)")
    ap.add_argument("--all", action="store_true",
                    help="include rows for superseded kernels")
    ap.add_argument("--summary", action="store_true",
                    help="print a one-line-per-row table of the gated "
                         "kernel/roofline rows (scripts/ci.sh)")
    args = ap.parse_args()

    from benchmarks import (bench_autoswitch, bench_convergence,
                            bench_decay_ablation,
                            bench_fig3_grad_distribution,
                            bench_fig6_switching,
                            bench_fig78_batch_ablation, bench_kernels,
                            bench_multitask, bench_tab52_qps, roofline)

    suites = [
        ("fig3", lambda: bench_fig3_grad_distribution.run(
            n_samples=8 if args.fast else 24)),
        ("fig6", lambda: bench_fig6_switching.run(
            base_days=4 if args.fast else 8,
            eval_days=2 if args.fast else 3)),
        ("tab52", lambda: bench_tab52_qps.run(
            num_batches=480 if args.fast else 1920)),
        ("fig78", lambda: bench_fig78_batch_ablation.run(
            base_days=3 if args.fast else 8,
            eval_days=1 if args.fast else 2)),
        ("convergence", bench_convergence.run),
        ("autoswitch", lambda: bench_autoswitch.run(
            num_batches=240 if args.fast else 480)),
        ("multitask", lambda: bench_multitask.run(
            base_days=3 if args.fast else 6,
            eval_days=1 if args.fast else 2)),
        ("decay", lambda: bench_decay_ablation.run(
            base_days=3 if args.fast else 6)),
        ("kernels", lambda: bench_kernels.run(all_rows=args.all)),
        ("roofline", roofline.run),
        # gated switching trajectory: fixed size regardless of --fast
        # (the gate compares the committed baseline exactly)
        ("switching", bench_fig6_switching.run_switching),
        # gated online-learning serving rows (V=1M hot-ID cache +
        # live param sync; seeded, pull-based sync → deterministic)
        ("serving", lambda: bench_tab52_qps.run_serving(
            num_batches=32 if args.fast else 64)),
    ]
    selected = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    failures = 0
    collected: dict[str, list[str]] = {}
    for name, fn in suites:
        if selected and not any(s in name for s in selected):
            continue
        t0 = time.time()
        try:
            rows = list(fn())
            for row in rows:
                print(row)
            collected[name] = rows
            print(f"suite.{name},0.0,elapsed_s={time.time() - t0:.1f}",
                  flush=True)
        except Exception:
            failures += 1
            print(f"suite.{name},0.0,FAILED", flush=True)
            traceback.print_exc()
    records = rows_to_json(
        {k: v for k, v in collected.items() if k in JSON_SUITES})
    if args.check:
        problems = check_records(records, args.check)
        for p in problems:
            print(f"check.FAIL,0.0,{p}", flush=True)
        if problems:
            sys.exit(1)
        print(f"check.ok,0.0,baseline={args.check};rows={len(records)}",
              flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=2)
        print(f"suite.json,0.0,wrote={args.json};rows={len(records)}",
              flush=True)
    if args.summary and records:
        gated = MONOTONE_COLS + FLOOR_COLS + EXACT_COLS
        print(f"{'gated row':<55} {'us/call':>10}  gated columns")
        for r in records:
            cols = " ".join(
                f"{k}={r[k]:g}" if isinstance(r[k], float) else
                f"{k}={r[k]}" for k in gated
                if isinstance(r.get(k), (float, str)))
            print(f"{r['name']:<55} {r['us_per_call']:>10.1f}  {cols}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
