"""Theorems 1/2 (Eq. 2/4): error-floor scaling on a strongly-convex
problem.

We minimize F(w) = 0.5 c ||w - w*||^2 with stochastic gradients of
per-sample variance sigma^2, via (a) sync aggregation of G samples and
(b) GBA aggregation with the same global batch under injected staleness.
Theory: floor = eta L sigma^2 / (2 c G); doubling G must halve the sync
floor, and GBA's floor with matched G must sit near sync's.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row

C = 1.0
ETA = 0.05
SIGMA = 1.0
DIM = 16


def _floor(global_batch: int, staleness: int = 0, iota: int = 10,
           steps: int = 4000, seed: int = 0) -> float:
    """Average F(w)-F* over the tail of a long run."""
    rng = np.random.default_rng(seed)
    w = np.ones(DIM)
    history = [w.copy()]
    vals = []
    for k in range(steps):
        src = history[max(0, len(history) - 1 - staleness)]
        # mean of G per-sample gradients: c*(w_src) + noise/sqrt(G)
        g = C * src + SIGMA * rng.normal(size=DIM) / np.sqrt(global_batch)
        if staleness > iota:
            g = np.zeros(DIM)  # Eq. (1) drops it
        w = w - ETA * g
        history.append(w.copy())
        if len(history) > 64:
            history.pop(0)
        if k > steps // 2:
            vals.append(0.5 * C * float(w @ w))
    return float(np.mean(vals))


def run() -> list[str]:
    t0 = time.perf_counter()
    rows = []
    floors = {}
    for g in (64, 128, 256, 512):
        floors[g] = _floor(g)
        rows.append(csv_row(f"thm.sync_floor.G{g}", 0.0,
                            f"floor={floors[g]:.3e}"))
    # floor ~ 1/G: ratio of successive floors ~ 0.5
    ratios = [floors[g2] / floors[g1] for g1, g2 in
              [(64, 128), (128, 256), (256, 512)]]
    rows.append(csv_row(
        "thm.floor_scales_inverse_G", 0.0,
        f"ratios={'|'.join(f'{r:.2f}' for r in ratios)};"
        f"expected=0.50;"
        f"pass={all(0.3 < r < 0.75 for r in ratios)}"))

    # GBA with staleness <= iota keeps ~the sync floor at matched G
    sync256 = floors[256]
    for stale in (0, 2, 4):
        f = _floor(256, staleness=stale, seed=stale + 1)
        rows.append(csv_row(
            f"thm.gba_floor.stale{stale}", 0.0,
            f"floor={f:.3e};vs_sync={f / sync256:.2f}"))
    us = (time.perf_counter() - t0) * 1e6
    rows.append(csv_row("thm.done", us, "see_EXPERIMENTS.md"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
