"""Paper Figs. 7/8: the batch-geometry ablations.

Fig. 7 — keep global batch fixed, vary worker count (and with it the local
batch): AUC must stay flat (abs diff ~1e-3 at our scale) while simulated
QPS scales with workers.

Fig. 8 — fix workers, vary local batch so the *global* batch diverges from
the sync reference: AUC after switching degrades relative to matched-G GBA.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.configs.recsys import CRITEO_DEEPFM
from repro.core import ModeSetup, default_setups, run_continual
from repro.data import make_clickstream
from repro.models.recsys import init_recsys
from repro.sim.cluster import ClusterSpec

CFG = CRITEO_DEEPFM
G = 2048  # the sync-matched global batch


def run(base_days: int = 5, eval_days: int = 2) -> list[str]:
    t0 = time.perf_counter()
    stream = make_clickstream(CFG, seed=0, batches_per_day=48,
                              batch_size=256,
                              num_days=base_days + eval_days + 2)
    setups = default_setups(base_global=G)
    spec = ClusterSpec(num_workers=16, straggler_frac=0.25,
                       straggler_slowdown=5.0, jitter=0.2, seed=0)
    base = init_recsys(jax.random.PRNGKey(0), CFG)
    base, _ = run_continual(base, CFG, stream, ["sync"] * base_days, setups,
                            spec, eval_batches=8)

    rows = []
    # Fig. 7: same G, vary workers M (local batch = G / M)
    fig7 = {}
    for m in (8, 16, 32):
        setups_m = dict(setups)
        setups_m["gba"] = ModeSetup("gba", m, G // m, buffer_size=m, iota=4)
        _, res = run_continual(base, CFG, stream, ["gba"] * eval_days,
                               setups_m, spec, eval_batches=8,
                               start_day=base_days)
        fig7[m] = (np.mean(res.auc_per_day), np.mean(res.qps_per_day))
        rows.append(csv_row(f"fig7.workers_{m}", 0.0,
                            f"auc={fig7[m][0]:.4f};qps={fig7[m][1]:.0f}"))
    aucs = [v[0] for v in fig7.values()]
    qpss = [v[1] for v in fig7.values()]
    rows.append(csv_row(
        "fig7.claims", 0.0,
        f"auc_spread={max(aucs) - min(aucs):.4f};"
        f"qps_scaling={qpss[-1] / qpss[0]:.2f}x;"
        f"steady_auc={'PASS' if max(aucs) - min(aucs) < 0.01 else 'FAIL'}"))

    # Fig. 8: fixed workers=16, vary local batch (G changes)
    fig8 = {}
    for lb in (32, 64, G // 16, 512):
        setups_b = dict(setups)
        setups_b["gba"] = ModeSetup("gba", 16, lb, buffer_size=16, iota=4)
        _, res = run_continual(base, CFG, stream, ["gba"] * eval_days,
                               setups_b, spec, eval_batches=8,
                               start_day=base_days)
        fig8[lb] = np.mean(res.auc_per_day)
        rows.append(csv_row(
            f"fig8.local_batch_{lb}", 0.0,
            f"global_batch={lb * 16};auc={fig8[lb]:.4f};"
            f"matched={'yes' if lb * 16 == G else 'no'}"))
    matched = fig8[G // 16]
    larger = min(v for k, v in fig8.items() if k * 16 > G)
    smaller = max(v for k, v in fig8.items() if k * 16 < G)
    us = (time.perf_counter() - t0) * 1e6
    # note: pre-plateau, a smaller G trains faster (more optimizer steps);
    # the paper's Fig. 8 regime is a converged base, where matched-G wins
    # outright — we assert the unambiguous direction (larger mismatched G
    # under the sync-tuned LR is worse) and report the smaller-G side.
    rows.append(csv_row(
        "fig8.claims", us,
        f"matched_auc={matched:.4f};larger_G_auc={larger:.4f};"
        f"smaller_G_auc={smaller:.4f};"
        f"matched_beats_larger={'PASS' if matched >= larger else 'FAIL'}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
