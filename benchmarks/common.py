"""Shared benchmark utilities."""
from __future__ import annotations

import time

import numpy as np


def time_call(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median microseconds per call (CPU wall time, post-warmup)."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        _block(out)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def _block(out):
    import jax
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
