"""Paper Tab. 5.1's other two tasks: Alimama/DIEN and Private/YouTubeDNN.

The headline claim (C2: switching sync->GBA is tuning-free and matches
continued sync) must hold on all three model families — DeepFM is covered
by fig6; this suite runs the GRU-attention DIEN tower and the two-tower
YouTubeDNN on their own synthetic behavior streams.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.configs.recsys import ALIMAMA_DIEN, PRIVATE_YOUTUBEDNN
from repro.core import default_setups, run_continual
from repro.data import make_clickstream
from repro.models.recsys import init_recsys
from repro.sim.cluster import ClusterSpec


def run(base_days: int = 6, eval_days: int = 2) -> list[str]:
    rows = []
    t0 = time.perf_counter()
    spec = ClusterSpec(num_workers=16, straggler_frac=0.25,
                       straggler_slowdown=5.0, jitter=0.2, seed=0)
    setups = default_setups(base_global=2048)
    for cfg in (ALIMAMA_DIEN, PRIVATE_YOUTUBEDNN):
        stream = make_clickstream(cfg, seed=0, batches_per_day=48,
                                  batch_size=256,
                                  num_days=base_days + eval_days + 2)
        base = init_recsys(jax.random.PRNGKey(0), cfg)
        base, res0 = run_continual(base, cfg, stream, ["sync"] * base_days,
                                   setups, spec, eval_batches=12)
        _, res_sync = run_continual(base, cfg, stream, ["sync"] * eval_days,
                                    setups, spec, eval_batches=12,
                                    start_day=base_days)
        _, res_gba = run_continual(base, cfg, stream, ["gba"] * eval_days,
                                   setups, spec, eval_batches=12,
                                   start_day=base_days)
        gap = res_sync.auc_per_day[0] - res_gba.auc_per_day[0]
        rows.append(csv_row(
            f"multitask.{cfg.name}", 0.0,
            f"base_auc={res0.auc_per_day[-1]:.4f};"
            f"sync_first={res_sync.auc_per_day[0]:.4f};"
            f"gba_first={res_gba.auc_per_day[0]:.4f};"
            f"first_day_gap={gap:+.4f};"
            f"gba_avg={np.mean(res_gba.auc_per_day):.4f};"
            f"sync_avg={np.mean(res_sync.auc_per_day):.4f};"
            f"tuning_free={'PASS' if abs(gap) < 0.01 else 'FAIL'}"))
    us = (time.perf_counter() - t0) * 1e6
    rows.append(csv_row("multitask.done", us, "3_of_3_tasks_covered"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
