"""Paper Tab. 5.2: global QPS of the six training modes, and Tab. 5.3's
fine-grained staleness/drop analysis, from the cluster simulator.

Scenarios mirror Sec. 5.3's "different periods of a day": vacant, moderate,
strained (Fig. 1's day cycle).  Claims:

  C3  GBA ~= async QPS; >=2.4x sync under strain; Hop-BS struggles;
  C4  GBA drops orders of magnitude fewer batches than Hop-BW while
      keeping staleness at Hop-BS levels.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row
from repro.sim.cluster import ClusterSpec, simulate

SCENARIOS = {
    "vacant": ClusterSpec(num_workers=16, straggler_frac=0.0, jitter=0.02,
                          seed=7),
    "moderate": ClusterSpec(num_workers=16, straggler_frac=0.12,
                            straggler_slowdown=3.0, jitter=0.1,
                            time_varying=True, seed=7),
    "strained": ClusterSpec(num_workers=16, straggler_frac=0.25,
                            straggler_slowdown=5.0, jitter=0.2,
                            time_varying=True, seed=7),
}

MODES = [("sync", {}), ("async", {}), ("hop_bs", dict(b1=2)),
         ("bsp", dict(b2=16)), ("hop_bw", dict(b3=4)),
         ("gba", dict(buffer_size=16, iota=4))]


def run(num_batches: int = 1920) -> list[str]:
    rows = []
    t0 = time.perf_counter()
    summary = {}
    for sc_name, spec in SCENARIOS.items():
        for mode, kw in MODES:
            reps = []
            for rep in range(3):
                m = simulate(
                    ClusterSpec(**{**spec.__dict__, "seed": spec.seed + rep}),
                    mode, num_batches, 256, **kw).metrics
                reps.append(m)
            qps = np.array([m.qps for m in reps])
            rows.append(csv_row(
                f"tab52.qps.{sc_name}.{mode}", 0.0,
                f"qps={qps.mean():.0f};std={qps.std():.0f};"
                f"avg_stale={np.mean([m.avg_staleness for m in reps]):.2f};"
                f"max_stale={max(m.staleness_max for m in reps)};"
                f"drops={int(np.mean([m.dropped_batches for m in reps]))}"))
            summary[(sc_name, mode)] = (
                qps.mean(),
                np.mean([m.avg_staleness for m in reps]),
                np.mean([m.dropped_batches for m in reps]))
    us = (time.perf_counter() - t0) * 1e6 / (len(SCENARIOS) * len(MODES) * 3)

    g, a = summary[("strained", "gba")], summary[("strained", "async")]
    s, bw = summary[("strained", "sync")], summary[("strained", "hop_bw")]
    hb = summary[("strained", "hop_bs")]
    rows.append(csv_row(
        "tab52.claims", us,
        f"gba_vs_async_qps={g[0] / a[0]:.3f};"
        f"gba_vs_sync_speedup={g[0] / s[0]:.2f}x;"
        f"claim_2.4x={'PASS' if g[0] / s[0] >= 2.4 else 'FAIL'};"
        f"hopbw_drops={bw[2]:.0f};gba_drops={g[2]:.0f};"
        f"gba_stale={g[1]:.2f};hopbs_stale={hb[1]:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
