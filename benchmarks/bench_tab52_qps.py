"""Paper Tab. 5.2: global QPS of the six training modes, and Tab. 5.3's
fine-grained staleness/drop analysis, from the cluster simulator.

Scenarios mirror Sec. 5.3's "different periods of a day": vacant, moderate,
strained (Fig. 1's day cycle).  Claims:

  C3  GBA ~= async QPS; >=2.4x sync under strain; Hop-BS struggles;
  C4  GBA drops orders of magnitude fewer batches than Hop-BW while
      keeping staleness at Hop-BS levels.

``run_serving`` benches the ONLINE-LEARNING SERVING side of the same
workload (GBA Sec. 5: the trained model is continuously redeployed) at
paper scale V=1M: Zipf-hot scoring through the
:class:`~repro.embeddings.hot_cache.HotIDCache` in front of the
DMA-streamed lookup kernel, and live param sync through
``UpdateChannel``/``LiveSource`` with touched-row invalidation.  The
``tab52.serving.*`` rows are CI-gated (benchmarks.run --check):
``hit_rate`` floored, ``freshness_lag_steps`` monotone, and the
structural ``audit_cache_bytes`` / ``audit_hit_skips_kernel`` /
``audit_race_findings`` columns exact — ``audit_hit_skips_kernel`` is
the kernel-call-counter proof that an all-hit batch never invokes the
streamed kernel, and ``audit_race_findings`` is the GBA-RACE
lock-discipline lint (``repro.analysis.race_lint``) over the serving
modules this bench drives, gated at 0.  Everything is seeded and the
sync thread is disabled (pull-based ``sync_now``), so the gated columns
are deterministic; only the latency percentiles are wall time.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row
from repro.sim.cluster import ClusterSpec, simulate

SCENARIOS = {
    "vacant": ClusterSpec(num_workers=16, straggler_frac=0.0, jitter=0.02,
                          seed=7),
    "moderate": ClusterSpec(num_workers=16, straggler_frac=0.12,
                            straggler_slowdown=3.0, jitter=0.1,
                            time_varying=True, seed=7),
    "strained": ClusterSpec(num_workers=16, straggler_frac=0.25,
                            straggler_slowdown=5.0, jitter=0.2,
                            time_varying=True, seed=7),
}

MODES = [("sync", {}), ("async", {}), ("hop_bs", dict(b1=2)),
         ("bsp", dict(b2=16)), ("hop_bw", dict(b3=4)),
         ("gba", dict(buffer_size=16, iota=4))]


def run(num_batches: int = 1920) -> list[str]:
    rows = []
    t0 = time.perf_counter()
    summary = {}
    for sc_name, spec in SCENARIOS.items():
        for mode, kw in MODES:
            reps = []
            for rep in range(3):
                m = simulate(
                    ClusterSpec(**{**spec.__dict__, "seed": spec.seed + rep}),
                    mode, num_batches, 256, **kw).metrics
                reps.append(m)
            qps = np.array([m.qps for m in reps])
            rows.append(csv_row(
                f"tab52.qps.{sc_name}.{mode}", 0.0,
                f"qps={qps.mean():.0f};std={qps.std():.0f};"
                f"avg_stale={np.mean([m.avg_staleness for m in reps]):.2f};"
                f"max_stale={max(m.staleness_max for m in reps)};"
                f"drops={int(np.mean([m.dropped_batches for m in reps]))}"))
            summary[(sc_name, mode)] = (
                qps.mean(),
                np.mean([m.avg_staleness for m in reps]),
                np.mean([m.dropped_batches for m in reps]))
    us = (time.perf_counter() - t0) * 1e6 / (len(SCENARIOS) * len(MODES) * 3)

    g, a = summary[("strained", "gba")], summary[("strained", "async")]
    s, bw = summary[("strained", "sync")], summary[("strained", "hop_bw")]
    hb = summary[("strained", "hop_bs")]
    rows.append(csv_row(
        "tab52.claims", us,
        f"gba_vs_async_qps={g[0] / a[0]:.3f};"
        f"gba_vs_sync_speedup={g[0] / s[0]:.2f}x;"
        f"claim_2.4x={'PASS' if g[0] / s[0] >= 2.4 else 'FAIL'};"
        f"hopbw_drops={bw[2]:.0f};gba_drops={g[2]:.0f};"
        f"gba_stale={g[1]:.2f};hopbs_stale={hb[1]:.2f}"))
    return rows


# -- online-learning serving (tab52.serving.*) ----------------------------

SERVE_V = 1_000_000       # embedding rows — the paper-scale vocab
SERVE_DIM = 64
SERVE_HOT = 512           # Zipf-hot head the cache should absorb
SERVE_CACHE = 4096        # cache capacity (rows)
SERVE_B, SERVE_F = 8, 16  # request geometry: (B, F) ID lists
SERVE_SYNC_EVERY = 8      # scored batches per applied sync
SERVE_PUBS_PER_SYNC = 2   # trainer publishes coalesced into each sync
SERVE_TOUCH = 16          # embedding rows each trainer update touches


def _hot_batch(rng: np.random.Generator, hot: np.ndarray) -> np.ndarray:
    """(B, F) raw ids, Zipf-skewed inside the hot pool."""
    ranks = rng.zipf(1.2, size=(SERVE_B, SERVE_F)) - 1
    return hot[np.minimum(ranks, hot.shape[0] - 1)]


def run_serving(num_batches: int = 64) -> list[str]:
    import jax
    import jax.numpy as jnp

    from repro.embeddings.table import hash_ids
    from repro.kernels import ops
    from repro.serving import (LiveSource, RecsysScoringEngine,
                               ServingConfig, StaticSource, UpdateChannel,
                               init_scoring_params)

    from repro.analysis.race_lint import lint_default

    rows = []
    params = init_scoring_params(jax.random.PRNGKey(0), SERVE_V, SERVE_DIM)
    cfg = ServingConfig(cache_capacity=SERVE_CACHE)
    hot = np.arange(SERVE_HOT, dtype=np.int64)
    # lock-discipline lint over the very modules this bench exercises
    # (serving/* + the hot-ID cache): exact-gated at 0 by run --check,
    # so an unlocked mutation / torn read / callback-under-lock in the
    # serving path flips a structural column, not just a unit test
    race_findings, _ = lint_default()

    # ---- hot-ID cache in front of the streamed kernel (frozen params) ----
    eng = RecsysScoringEngine(StaticSource(params), config=cfg)
    rng = np.random.default_rng(0)
    eng.score(hot.reshape(1, -1))          # warm: one pool over the hot set
    eng.latencies_us.clear()               # keep trace time out of p50/p99
    for _ in range(num_batches):
        eng.score(_hot_batch(rng, hot))
    # structural evidence: a batch whose ids are all resident performs
    # ZERO streamed-kernel invocations (exact-gated audit column)
    probe = _hot_batch(rng, hot)
    eng.score(probe)                       # make the probe's ids resident
    before = ops.kernel_calls["pooled_lookup"]
    eng.score(probe)
    hit_skips = int(ops.kernel_calls["pooled_lookup"] == before)
    st = eng.stats()
    rows.append(csv_row(
        "tab52.serving.hot_cache", st["p50_us"],
        f"p50_us={st['p50_us']:.0f};p99_us={st['p99_us']:.0f};"
        f"hit_rate={st['hit_rate']:.4f};vocab={SERVE_V};"
        f"cache_rows={st['cache_rows']};"
        f"audit_cache_bytes={st['cache_bytes']};"
        f"audit_hit_skips_kernel={hit_skips};"
        f"audit_race_findings={len(race_findings)}"))

    # ---- live param sync: freshness + touched-row invalidation -----------
    chan = UpdateChannel()
    live = LiveSource(chan, params, sync_interval=cfg.sync_interval,
                      start=False)         # pull-based: deterministic
    eng = RecsysScoringEngine(live, config=cfg)
    rng = np.random.default_rng(1)
    eng.score(hot.reshape(1, -1))
    eng.latencies_us.clear()
    table = params["table"]
    step = max_lag = syncs = 0
    for i in range(num_batches):
        eng.score(_hot_batch(rng, hot))
        if (i + 1) % SERVE_SYNC_EVERY == 0:
            for _ in range(SERVE_PUBS_PER_SYNC):
                step += 1
                touch = hash_ids(
                    jnp.asarray(rng.choice(SERVE_HOT, SERVE_TOUCH),
                                jnp.int32), SERVE_V)
                table = table._replace(
                    table=table.table.at[touch].add(0.01))
                chan.publish({"table": table, "mlp": params["mlp"]}, step,
                             touched_ids=np.asarray(touch))
            max_lag = max(max_lag, live.freshness_lag_steps())
            live.sync_now()
            syncs += 1
    st = eng.stats()
    rows.append(csv_row(
        "tab52.serving.live_sync", st["p50_us"],
        f"p50_us={st['p50_us']:.0f};p99_us={st['p99_us']:.0f};"
        f"hit_rate={st['hit_rate']:.4f};"
        f"freshness_lag_steps={max_lag};syncs={syncs};"
        f"coalesced={chan.coalesced};"
        f"invalidations={eng.cache.invalidations};"
        f"versions={st['param_version']};"
        f"audit_race_findings={len(race_findings)}"))
    eng.close()
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
    for r in run_serving():
        print(r)
