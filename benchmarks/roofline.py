"""Roofline analysis from the dry-run's compiled artifacts (task §Roofline).

Reads dryrun_results.json (written by repro.launch.dryrun) and derives, per
(arch x shape x mesh):

  compute term    = HLO_FLOPs / (chips * 197e12)
  memory term     = HLO_bytes / (chips * 819e9)
  collective term = collective_bytes / (chips * 50e9)

plus MODEL_FLOPS = 6*N(_active)*D_tokens and the usefulness ratio.

NOTE on cost_analysis semantics (calibrated in calibrate()): XLA-CPU
reports *per-program* (= per-device, SPMD) flops and counts while-loop
bodies ONCE, so scanned layer stacks need multiplying by trip count.  We
therefore report both the raw compiled numbers and the trip-count-corrected
estimates; the correction factor is recorded per row.
"""
from __future__ import annotations

import json
import math

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def model_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts, analytically."""
    hd = cfg.resolved_head_dim
    per_attn = (cfg.d_model * cfg.num_heads * hd * 2
                + cfg.d_model * cfg.num_kv_heads * hd * 2)
    per_mlp = 3 * cfg.d_model * cfg.d_ff
    per_moe = 3 * cfg.d_model * cfg.d_ff * cfg.num_experts \
        + cfg.d_model * cfg.num_experts
    per_moe_active = 3 * cfg.d_model * cfg.d_ff * cfg.experts_per_token \
        + cfg.d_model * cfg.num_experts
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads_ssm = d_inner // cfg.ssm_head_dim if cfg.ssm_state else 0
    per_mamba = (cfg.d_model * (2 * d_inner + 2 * cfg.ssm_state
                                + n_heads_ssm)
                 + d_inner * cfg.d_model) if cfg.ssm_state else 0
    total = active = cfg.vocab_size * cfg.d_model * (
        1 if cfg.tie_embeddings else 2)
    layers = list(cfg.prefix_layers) + list(cfg.block_pattern) \
        * cfg.num_repeats
    for kind in layers:
        if kind in ("global", "local"):
            total += per_attn + per_mlp
            active += per_attn + per_mlp
        elif kind in ("moe", "local_moe"):
            total += per_attn + per_moe
            active += per_attn + per_moe_active
        elif kind == "cross":
            total += 2 * per_attn + per_mlp
            active += 2 * per_attn + per_mlp
        elif kind == "mamba":
            total += per_mamba
            active += per_mamba
        elif kind == "mamba_attn":
            total += per_mamba
            active += per_mamba
    if "mamba_attn" in cfg.block_pattern:
        total += per_attn
        active += per_attn
    if cfg.encoder_layers:
        total += cfg.encoder_layers * (per_attn + per_mlp)
        active += cfg.encoder_layers * (per_attn + per_mlp)
    return total, active


def model_flops(cfg, shape) -> float:
    """6*N_active*D for train, 2*N_active*D for forward-only kinds."""
    total, active = model_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * active * tokens


def trip_correction(cfg, rec_kind: str) -> float:
    """XLA-CPU cost_analysis counts while-bodies once; the layer stack scans
    num_repeats times (plus encoder scan for audio)."""
    return float(cfg.num_repeats)


def analyze(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    corr = trip_correction(cfg, rec["kind"])
    flops = rec["flops"] * corr * chips          # cost is per-device
    hbm = rec["bytes_accessed"] * corr * chips
    coll = sum(rec["collective_bytes"].values()) * corr
    t_comp = flops / (chips * PEAK_FLOPS_BF16)
    t_mem = hbm / (chips * HBM_BW)
    t_coll = coll / (chips * ICI_BW)
    mf = model_flops(cfg, shape)
    dominant = max((("compute", t_comp), ("memory", t_mem),
                    ("collective", t_coll)), key=lambda kv: kv[1])
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "kind")},
        "trip_corr": corr,
        "compute_s": t_comp,
        "memory_s": t_mem,
        "collective_s": t_coll,
        "dominant": dominant[0],
        "model_flops": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "temp_bytes_per_dev": rec["memory"]["temp_bytes"],
    }


def run(path: str = "") -> list[str]:
    candidates = [path] if path else ["dryrun_results_v2.json",
                                      "dryrun_results.json"]
    recs = None
    for p in candidates:
        try:
            with open(p) as f:
                recs = json.load(f)
            break
        except FileNotFoundError:
            continue
    if recs is None:
        return ["roofline.skipped,0.0,no dryrun results — run "
                "`python -m repro.launch.dryrun --all --out "
                "dryrun_results_v2.json` first"]
    rows = []
    for rec in recs:
        if rec.get("status") != "ok":
            continue
        a = analyze(rec)
        rows.append(
            f"roofline.{a['arch']}.{a['shape']}.{a['mesh']},0.0,"
            f"compute_s={a['compute_s']:.3e};memory_s={a['memory_s']:.3e};"
            f"collective_s={a['collective_s']:.3e};"
            f"dominant={a['dominant']};"
            f"useful_ratio={a['useful_ratio']:.2f};"
            f"temp_gb_per_dev={a['temp_bytes_per_dev'] / 1e9:.1f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
