"""Online-learning serving: live param sync and the hot-ID cache.

Pins the PR's acceptance properties:

* a live-synced engine is BIT-IDENTICAL to an engine rebuilt fresh from
  the same snapshot, at every sync boundary (any hit/miss mix);
* a batch whose ids are all cache-resident performs zero streamed-kernel
  invocations (the ``kernel_calls`` counter is the structural proof);
* version bumps drop exactly the touched rows;
* the LM engine adopts a snapshot only at a decode-step boundary (one
  pinned version per step — no mixing when a sync lands mid-decode);
* LiveSource stop/grace shutdown joins the sync thread cleanly.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.embeddings import HotIDCache
from repro.embeddings.table import hash_ids
from repro.kernels import ops
from repro.serving import (LiveSource, RecsysScoringEngine, ServingConfig,
                           StaticSource, UpdateChannel, init_scoring_params)

V, DIM = 4096, 16
SCFG = ServingConfig(cache_capacity=128)


def _params(seed: int = 0):
    return init_scoring_params(jax.random.PRNGKey(seed), V, DIM)


def _ids(rng, B=4, F=8, hi=256):
    return rng.integers(0, hi, size=(B, F))


def test_live_matches_fresh_at_every_sync_boundary():
    params = _params()
    chan = UpdateChannel()
    live = LiveSource(chan, params, start=False)
    eng = RecsysScoringEngine(live, config=SCFG)
    rng = np.random.default_rng(0)
    eng.score(_ids(rng))                      # warm some cache entries
    table = params["table"]
    for step in range(1, 4):
        touch = hash_ids(jnp.asarray(rng.integers(0, 256, 8), jnp.int32), V)
        table = table._replace(table=table.table.at[touch].add(0.5))
        chan.publish({"table": table, "mlp": params["mlp"]}, step,
                     touched_ids=np.asarray(touch))
        snap = live.sync_now()
        assert snap.version == step + 1
        fresh = RecsysScoringEngine(StaticSource(snap.params), config=SCFG)
        batch = _ids(rng)
        got, want = eng.score(batch), fresh.score(batch)
        np.testing.assert_array_equal(got, want)  # bit-identical
    assert eng.stats()["syncs_adopted"] == 3
    assert eng.cache.hits > 0                 # the mix really had hits


def test_touched_row_invalidation_is_exact():
    params = _params()
    chan = UpdateChannel()
    live = LiveSource(chan, params, start=False)
    eng = RecsysScoringEngine(live, config=SCFG)
    batch = np.arange(32).reshape(4, 8)
    eng.score(batch)                          # all unique rows now cached
    touch_raw = np.arange(8)                  # touches half of row 0
    touch = np.asarray(hash_ids(jnp.asarray(touch_raw, jnp.int32), V))
    hashed = np.asarray(hash_ids(jnp.asarray(batch, jnp.int32), V))
    new_table = params["table"]._replace(
        table=params["table"].table.at[touch].add(1.0))
    chan.publish({"table": new_table, "mlp": params["mlp"]}, 1,
                 touched_ids=touch)
    live.sync_now()
    expected_refetch = np.intersect1d(np.unique(hashed), touch).size
    m0 = eng.cache.misses
    got = eng.score(batch)
    assert eng.cache.misses - m0 == expected_refetch
    fresh = RecsysScoringEngine(StaticSource(
        {"table": new_table, "mlp": params["mlp"]}), config=SCFG)
    np.testing.assert_array_equal(got, fresh.score(batch))


def test_all_hit_batch_skips_streamed_kernel():
    eng = RecsysScoringEngine(StaticSource(_params()), config=SCFG)
    rng = np.random.default_rng(1)
    batch = _ids(rng)
    eng.score(batch)                          # populates the cache
    before = ops.kernel_calls["pooled_lookup"]
    out_hit = eng.score(batch)
    assert ops.kernel_calls["pooled_lookup"] == before
    # cache disabled: same values, but the kernel IS invoked
    nocache = RecsysScoringEngine(StaticSource(_params()),
                                  config=ServingConfig(cache_capacity=0))
    out_miss = nocache.score(batch)
    assert ops.kernel_calls["pooled_lookup"] > before
    np.testing.assert_array_equal(out_hit, out_miss)


def test_channel_coalesces_and_unions_touched():
    chan = UpdateChannel()
    chan.publish("s1", 1, touched_ids=[1, 2])
    chan.publish("s2", 2, touched_ids=[2, 3])
    params, step, touched = chan.take()
    assert params == "s2" and step == 2
    assert sorted(touched.tolist()) == [1, 2, 3]
    assert chan.coalesced == 1
    assert chan.take() is None
    # one publish without touched ids poisons the window to full-clear
    chan.publish("s3", 3, touched_ids=[4])
    chan.publish("s4", 4)
    assert chan.take()[2] is None


def test_stale_put_is_ignored_and_lru_evicts():
    cache = HotIDCache(2, DIM)
    cache.bump_version(2)
    row = np.zeros((1, DIM), np.float32)
    assert not cache.put_many(np.array([1]), row, version=1)
    assert len(cache) == 0
    for i in (1, 2, 3):                       # capacity 2 -> 1 evicted
        assert cache.put_many(np.array([i]), row, version=2)
    assert len(cache) == 2 and cache.evictions == 1
    _, found = cache.get_many(np.array([1, 2, 3]))
    assert found.tolist() == [False, True, True]


def test_live_thread_adopts_and_closes_cleanly():
    params = _params()
    chan = UpdateChannel()
    live = LiveSource(chan, params, sync_interval=0.01)  # thread ON
    new_table = params["table"]._replace(table=params["table"].table + 1.0)
    chan.publish({"table": new_table, "mlp": params["mlp"]}, 5)
    deadline = time.time() + 10.0
    while live.snapshot().version == 1 and time.time() < deadline:
        time.sleep(0.005)
    assert live.snapshot().version == 2
    assert live.snapshot().step == 5
    assert live.freshness_lag_steps() == 0
    live.close(grace=5.0)
    assert live.closed
    live.close()                              # idempotent
    assert live.snapshot().version == 2       # still serves last snapshot


def test_lm_engine_adopts_only_at_step_boundary():
    import dataclasses

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serving import Request, ServingEngine
    cfg = dataclasses.replace(get_config("mamba2-780m").reduced(),
                              dtype="float32")
    p0 = T.init_model(jax.random.PRNGKey(0), cfg)
    p1 = T.init_model(jax.random.PRNGKey(7), cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)

    chan = UpdateChannel()
    live = LiveSource(chan, p0, start=False)
    eng = ServingEngine(live, cfg, num_slots=1, max_len=32)
    eng.submit(Request(uid=0, prompt=prompt.copy(), max_new_tokens=8))
    # reference: same request, params swapped BY HAND at the same step
    # boundary — equality proves the live engine pins exactly one version
    # per step and adopts only between steps
    ref = ServingEngine(p0, cfg, num_slots=1, max_len=32)
    ref.submit(Request(uid=0, prompt=prompt.copy(), max_new_tokens=8))

    for k in range(7):
        if k == 3:                            # sync lands mid-decode
            chan.publish(p1, 100)
            live.sync_now()
            ref.params = p1
        eng.step()
        ref.step()
    assert eng.completed and ref.completed
    assert eng.completed[0].output == ref.completed[0].output
    assert eng.syncs_adopted == 1
    assert eng.param_version == 2 and eng.param_step == 100
