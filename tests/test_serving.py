"""Continuous-batching serving engine: correctness vs offline decode,
ragged admission, slot reuse."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import Request, ServingEngine


def _setup(arch="starcoder2-3b"):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    if cfg.sliding_window:
        cfg = dataclasses.replace(cfg, sliding_window=16)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _offline_greedy(cfg, params, prompt, n_new):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = T.prefill(params, cfg, toks,
                              cache_len=len(prompt) + n_new + 1)
    out = [int(jnp.argmax(logits[0]))]
    tok = jnp.asarray([[out[-1]]], jnp.int32)
    for _ in range(n_new - 1):
        lg, cache = T.decode_step(params, cfg, tok, cache)
        out.append(int(jnp.argmax(lg[0, 0])))
        tok = jnp.asarray([[out[-1]]], jnp.int32)
    return out


@pytest.mark.parametrize("arch", ["starcoder2-3b", "mamba2-780m"])
def test_engine_matches_offline(arch):
    cfg, params = _setup(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 7)]
    n_new = 6
    engine = ServingEngine(params, cfg, num_slots=2, max_len=64)
    for i, p in enumerate(prompts):
        engine.submit(Request(uid=i, prompt=p, max_new_tokens=n_new))
    stats = engine.run()
    assert stats["completed"] == 3
    for req in engine.completed:
        expect = _offline_greedy(cfg, params, req.prompt, n_new)
        assert req.output == expect, (arch, req.uid)


def test_slot_reuse_and_utilization():
    cfg, params = _setup("mamba2-780m")
    rng = np.random.default_rng(1)
    engine = ServingEngine(params, cfg, num_slots=2, max_len=32)
    for i in range(5):
        engine.submit(Request(uid=i,
                              prompt=rng.integers(0, cfg.vocab_size,
                                                  size=4).astype(np.int32),
                              max_new_tokens=4))
    stats = engine.run()
    assert stats["completed"] == 5
    assert stats["decode_tokens"] == 5 * 3  # first token from prefill
    assert 0.5 <= stats["slot_utilization"] <= 1.0


def test_admission_clamp_keeps_writes_in_cache():
    """Regression: a request with prompt_len + max_new_tokens > max_len
    used to run slot_pos past the cache; admission now clamps the
    generation budget to the remaining cache room."""
    cfg, params = _setup("mamba2-780m")
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
    engine = ServingEngine(params, cfg, num_slots=1, max_len=16)
    engine.submit(Request(uid=0, prompt=prompt, max_new_tokens=50))
    stats = engine.run()
    assert stats["completed"] == 1
    assert stats["clamped_requests"] == 1
    req = engine.completed[0]
    assert len(req.output) == 16 - 10          # clamped budget
    assert int(engine.slot_pos.max()) < 16     # every write stayed inside
    # clamped output == the output of an in-budget request (pure prefix)
    ref = ServingEngine(params, cfg, num_slots=1, max_len=16)
    ref.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    ref.run()
    assert ref.clamped_requests == 0
    assert req.output == ref.completed[0].output


def test_eos_termination():
    cfg, params = _setup("mamba2-780m")
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
    ref = _offline_greedy(cfg, params, prompt, 8)
    eos = ref[2]  # force early stop at the 3rd generated token
    engine = ServingEngine(params, cfg, num_slots=1, max_len=32)
    engine.submit(Request(uid=0, prompt=prompt, max_new_tokens=8,
                          eos_id=eos))
    engine.run()
    req = engine.completed[0]
    assert req.output[-1] == eos and len(req.output) <= 3
