"""Replay-trainer integration: PS semantics, mode parity, per-ID rescue."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.recsys import CRITEO_DEEPFM
from repro.core import GBATrainer, default_setups, run_continual
from repro.core.trainer import evaluate
from repro.data import make_clickstream
from repro.models.recsys import init_recsys
from repro.optim import get_optimizer
from repro.sim.cluster import ClusterSpec, Schedule, Slot, simulate

CFG = CRITEO_DEEPFM


def _stream(bs=128):
    return make_clickstream(CFG, seed=0, batches_per_day=16, batch_size=bs)


def test_sync_replay_reduces_loss():
    stream = _stream()
    params = init_recsys(jax.random.PRNGKey(0), CFG)
    opt = get_optimizer("adam", 1e-3)
    trainer = GBATrainer(CFG, opt)
    spec = ClusterSpec(num_workers=8, seed=0)
    sched = simulate(spec, "sync", 64, 128)
    params, _, _, stats = trainer.replay(params, opt.init(params), sched,
                                         stream, day=0)
    assert stats.losses[-1] < stats.losses[0]
    assert stats.applied_steps == 8
    assert stats.dropped_slots == 0


def test_gba_zero_staleness_equals_sync():
    """A GBA schedule with all-fresh tokens must produce exactly the sync
    update sequence (same batches, same aggregation)."""
    stream = _stream()
    opt = get_optimizer("sgd", 0.1)

    def run(mode_schedule):
        params = init_recsys(jax.random.PRNGKey(1), CFG)
        trainer = GBATrainer(CFG, opt)
        p, _, _, _ = trainer.replay(params, opt.init(params), mode_schedule,
                                    stream, day=0)
        return p

    steps = [[Slot(k * 4 + i, k, k, 1.0) for i in range(4)]
             for k in range(4)]
    sync_like = Schedule("sync", 128, steps)
    gba_like = Schedule("gba", 128, steps)
    p1, p2 = run(sync_like), run(gba_like)
    for k in ("bias",):
        np.testing.assert_allclose(np.asarray(p1[k]), np.asarray(p2[k]),
                                   rtol=1e-5)
    np.testing.assert_allclose(np.asarray(p1["embed"]),
                               np.asarray(p2["embed"]), rtol=1e-4, atol=1e-7)


def test_stale_slots_change_update_and_are_counted():
    stream = _stream()
    opt = get_optimizer("sgd", 0.1)
    params = init_recsys(jax.random.PRNGKey(1), CFG)
    trainer = GBATrainer(CFG, opt, iota=1)
    # second step has one severely stale slot (token 0 applied at step 5)
    steps = [[Slot(i, 0, 0, 1.0) for i in range(4)],
             [Slot(4, 5, 0, 1.0), Slot(5, 5, 0, 1.0),
              Slot(6, 0, 0, 0.0), Slot(7, 5, 0, 1.0)]]
    sched = Schedule("gba", 128, steps)
    _, _, _, stats = trainer.replay(params, opt.init(params), sched,
                                    stream, day=0)
    assert stats.dropped_slots == 1
    assert stats.kept_slots == 7


def test_continual_switch_sync_to_gba_holds_auc():
    """The headline claim (C2): switching sync->GBA does not collapse AUC."""
    stream = _stream(256)
    setups = default_setups(base_global=2048)
    spec = ClusterSpec(num_workers=16, straggler_frac=0.25, seed=0)
    params = init_recsys(jax.random.PRNGKey(0), CFG)
    params, res = run_continual(params, CFG, stream,
                                ["sync"] * 4, setups, spec, eval_batches=6)
    base_auc = res.auc_per_day[-1]
    _, res2 = run_continual(params, CFG, stream, ["gba"], setups, spec,
                            eval_batches=6, start_day=4)
    assert res2.auc_per_day[0] > base_auc - 0.02, \
        f"GBA switch dropped AUC: {base_auc:.4f} -> {res2.auc_per_day[0]:.4f}"


def test_history_ring_clamps_counted():
    stream = _stream()
    opt = get_optimizer("sgd", 0.1)
    params = init_recsys(jax.random.PRNGKey(1), CFG)
    trainer = GBATrainer(CFG, opt, history=2)
    steps = [[Slot(0, 0, 0, 1.0)], [Slot(1, 1, 1, 1.0)],
             [Slot(2, 2, 2, 1.0)], [Slot(3, 3, 0, 1.0)]]  # dispatch 0 @ k=3
    sched = Schedule("gba", 128, steps)
    _, _, _, stats = trainer.replay(params, opt.init(params), sched,
                                    stream, day=0)
    assert stats.history_clamps >= 1


def test_streamed_presence_counts_match_default_path():
    """GBATrainer(embed_stream=...) routes the per-slot presence counts
    through the DMA-streamed sorted-scatter kernel; the replayed parameters
    must match the XLA one-hot-scatter path exactly (same counts, same
    masks, same updates)."""
    import dataclasses
    from repro.embeddings import StreamConfig

    cfg = dataclasses.replace(CRITEO_DEEPFM, name="criteo-deepfm-tiny",
                              hash_capacity=2048, mlp_dims=(32, 16))
    stream = make_clickstream(cfg, seed=0, batches_per_day=16, batch_size=32)
    opt = get_optimizer("sgd", 0.05)
    # a schedule with real staleness so the per-ID relaxation path runs
    steps = [[Slot(k * 3 + i, max(0, k - i), k, 1.0 if i < 2 else 0.0)
              for i in range(3)] for k in range(4)]
    sched = Schedule("gba", 32, steps)

    def run(embed_stream):
        params = init_recsys(jax.random.PRNGKey(2), cfg)
        trainer = GBATrainer(cfg, opt, iota=1, embed_stream=embed_stream)
        p, _, last_update, stats = trainer.replay(
            params, opt.init(params), sched, stream, day=0)
        return p, last_update, stats

    p1, lu1, st1 = run(None)
    p2, lu2, st2 = run(StreamConfig())
    assert st1.embed_rows_rescued == st2.embed_rows_rescued
    np.testing.assert_array_equal(np.asarray(lu1), np.asarray(lu2))
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(p1),
            jax.tree_util.tree_leaves_with_path(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7,
                                   err_msg=str(path))
