"""Quantized gradient routing on the layer-grouped fused-psum wire.

Fast-lane host tests cover the `CompressionPolicy` accounting
(route/wire bytes, compression ratio, state shapes, group_table wire
columns), the quantize/dequantize Pallas kernels (int8 min-max and sign
modes, per-tile sidebands, the error-feedback invariant
``residual + dequantize(quantize(x)) == x`` to float rounding), their
exported launch metas, and the GBA-COLL-005 expected-census helper.

The slow subprocess tests are the tentpole acceptance: on a forced
4-device host mesh, (a) the f32 warmup phase of BOTH lossy schemes is
bit-exact with the uncompressed PR-5 step — params, accum, AND loss over
3 global steps including an Eq.-(1)-decayed slot and non-tile-multiple
leaves; (b) the compressed traces pass GBA-COLL-005 (int8 payload + f32
sidebands only on the wire) and the warmup trace reproduces the PR-5
schedule exactly; (c) onebit sign-of-momentum training converges on a
seeded tiny-DeepFM recsys smoke within a tolerance band of full
precision.
"""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import CompressionPolicy
from repro.core.flat_sharded import ShardedFlatLayout
from repro.kernels import quantize as Q

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
        "JAX_PLATFORMS": "cpu"}


def _layout(num_shards=4, tile=256, grouped=True):
    params = {"embed": jnp.zeros((33, 9)),
              "blocks": {"l0": {"w": jnp.zeros((41,)),
                                "b": jnp.zeros((7, 5))}},
              "head": jnp.zeros((700,))}
    return ShardedFlatLayout.from_params(
        params, num_shards, tile=tile,
        group_by=(lambda n: n[0]) if grouped else None)


# ---------------------------------------------------------------------------
# policy accounting
# ---------------------------------------------------------------------------

def test_policy_validation():
    with pytest.raises(ValueError):
        CompressionPolicy(scheme="fp4")
    with pytest.raises(ValueError):
        CompressionPolicy(scheme="int8", warmup_steps=-1)
    with pytest.raises(ValueError):
        CompressionPolicy(scheme="onebit", momentum=1.5)
    assert not CompressionPolicy().stateful
    assert CompressionPolicy(scheme="int8").state_names() == ("residual",)
    assert CompressionPolicy(scheme="onebit").state_names() \
        == ("residual", "momentum")


def test_policy_route_and_wire_bytes():
    lay = _layout()
    none, i8, ob = (CompressionPolicy(scheme=s)
                    for s in ("none", "int8", "onebit"))
    g, tile = lay.group_sizes[0], lay.tile
    assert none.route_bytes(g, tile) == g * 4
    # int8: 1 byte/element + (scale, zero-point) f32 per tile
    assert i8.route_bytes(g, tile) == g + 2 * (g // tile) * 4
    assert ob.route_bytes(g, tile) == g + 1 * (g // tile) * 4
    # warmup routes full f32 regardless of scheme
    assert i8.route_bytes(g, tile, warm=True) == g * 4
    assert none.wire_bytes(lay) == lay.padded_total * 4
    assert i8.wire_bytes(lay) == sum(
        i8.route_bytes(gs, tile) for gs in lay.group_sizes)
    assert none.compression_ratio(lay) == 1.0
    # acceptance bound: int8 wire is <= 0.30x of f32
    assert i8.compression_ratio(lay) <= 0.30
    assert ob.compression_ratio(lay) < i8.compression_ratio(lay)
    assert i8.wire_dtype() == "int8" and i8.wire_dtype(warm=True) \
        == "float32"


def test_wire_state_shapes_and_init():
    lay = _layout()
    assert lay.wire_state_shapes(4, "none") == {}
    assert lay.wire_state_shapes(4, "int8") \
        == {"residual": (4, lay.padded_total)}
    assert lay.wire_state_shapes(4, "onebit") \
        == {"residual": (4, lay.padded_total),
            "momentum": (4, lay.padded_total)}
    with pytest.raises(ValueError):
        lay.wire_state_shapes(4, "fp8")
    wire = CompressionPolicy(scheme="onebit").init_wire_state(lay, 4)
    assert set(wire) == {"residual", "momentum"}
    for v in wire.values():
        assert v.shape == (4, lay.padded_total) and v.dtype == jnp.float32
        assert float(jnp.abs(v).max()) == 0.0


def test_group_table_wire_columns():
    lay = _layout()
    i8 = CompressionPolicy(scheme="int8")
    plain = lay.group_table()
    comp = lay.group_table(compress=i8)
    assert [r["key"] for r in plain] == [r["key"] for r in comp]
    for rp, rc in zip(plain, comp):
        assert rp["wire_bytes"] == rp["bytes"]
        assert rp["wire_dtype"] == "float32"
        assert rc["wire_dtype"] == "int8"
        assert rc["wire_bytes"] \
            == i8.route_bytes(rp["bytes"] // 4, lay.tile)
        assert rc["wire_bytes"] < rp["wire_bytes"]


def test_wire_state_specs():
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as S
    lay = _layout(num_shards=1)
    mesh = jax.make_mesh((1,), ("data",))
    assert S.wire_state_specs(lay, mesh, "none") == {}
    specs = S.wire_state_specs(lay, mesh, "onebit")
    assert specs == {"residual": P("data", None),
                     "momentum": P("data", None)}


# ---------------------------------------------------------------------------
# quantize / dequantize kernels (interpret mode)
# ---------------------------------------------------------------------------

def test_minmax_error_feedback_invariant():
    """Per tile: residual + dequantize(quantize(x)) == x to float
    rounding — the error-feedback residual captures exactly what the
    int8 code dropped."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 512)) * 3.0
    q, sc, zp, res = Q.quantize_minmax(x, tile=128)
    assert q.dtype == jnp.int8 and q.shape == x.shape
    assert sc.shape == zp.shape == (4, 4)
    deq = Q.dequantize(q, sc, zp, tile=128, mode="minmax")
    np.testing.assert_allclose(np.asarray(res + deq), np.asarray(x),
                               atol=1e-6, rtol=0)
    # the code really is lossy (residual nonzero) but tile-bounded
    assert float(jnp.abs(res).max()) > 0.0
    span = (x.reshape(4, 4, 128).max(-1) - x.reshape(4, 4, 128).min(-1))
    assert float(jnp.abs(res).max()) <= float(span.max()) / 255.0 * 0.51


def test_minmax_constant_tile_exact():
    """A constant tile has span 0 -> scale 0 -> dequant returns the
    zero-point bit-exactly and the residual is exactly zero."""
    x = jnp.full((2, 256), 1.7, jnp.float32)
    q, sc, zp, res = Q.quantize_minmax(x, tile=128)
    deq = Q.dequantize(q, sc, zp, tile=128, mode="minmax")
    np.testing.assert_array_equal(np.asarray(deq), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(res), 0.0)
    np.testing.assert_array_equal(np.asarray(sc), 0.0)


def test_sign_error_feedback_invariant():
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 384))
    q, sc, res = Q.quantize_sign(x, tile=128)
    assert q.dtype == jnp.int8
    vals = np.unique(np.asarray(q))
    assert set(vals.tolist()) <= {-1, 1}
    # per-tile scale is mean |x|
    np.testing.assert_allclose(
        np.asarray(sc),
        np.abs(np.asarray(x)).reshape(3, 3, 128).mean(-1),
        rtol=1e-6)
    deq = Q.dequantize(q, sc, tile=128, mode="sign")
    np.testing.assert_allclose(np.asarray(res + deq), np.asarray(x),
                               atol=1e-5, rtol=0)


def test_quantize_launch_meta_vmem():
    for mode in Q.MODES:
        for meta, formula in (
                (Q.quantize_launch_meta(8, 1 << 14, 2048, mode),
                 Q.quantize_vmem_bytes(8, 1 << 14, 2048, mode)),
                (Q.dequant_launch_meta(8, 1 << 14, 2048, mode),
                 Q.dequant_vmem_bytes(8, 1 << 14, 2048, mode))):
            assert meta.vmem_bytes(meta.vmem_counted) == formula
            assert meta.grid == ((1 << 14) // 2048,)
    with pytest.raises(ValueError):
        Q.quantize_launch_meta(4, 130, 128, "minmax")
    with pytest.raises(ValueError):
        Q.quantize_minmax(jnp.zeros((2, 130)), tile=128)


# ---------------------------------------------------------------------------
# GBA-COLL-005 expected census (unit)
# ---------------------------------------------------------------------------

def test_expected_wire_collectives():
    from repro.analysis.jaxpr_audit import expected_wire_collectives
    lay = _layout()
    m = lay.num_shards
    i8 = CompressionPolicy(scheme="int8", warmup_steps=1)
    ob = CompressionPolicy(scheme="onebit", warmup_steps=1)
    for g, (gsh, ops) in enumerate(zip(
            lay.group_shard_sizes,
            expected_wire_collectives(lay, m, i8))):
        assert ops == [((m, gsh), "int8"),
                       ((m, gsh // lay.tile), "float32"),
                       ((m, gsh // lay.tile), "float32")]
    for gsh, ops in zip(lay.group_shard_sizes,
                        expected_wire_collectives(lay, m, ob)):
        assert ops == [((m, gsh), "int8"),
                       ((m, gsh // lay.tile), "float32")]
    # warmup and none: one f32 operand per group, PR-5 exactly
    for pol in (i8, CompressionPolicy()):
        for gsh, ops in zip(
                lay.group_shard_sizes,
                expected_wire_collectives(lay, m, pol,
                                          warm=pol.stateful)):
            assert ops == [((m, gsh), "float32")]


# ---------------------------------------------------------------------------
# slow: 4-device warmup parity + compressed census (subprocess)
# ---------------------------------------------------------------------------

_WIRE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.compression import CompressionPolicy
from repro.core.flat_sharded import ShardedFlatLayout
from repro.core.gba_shard_map import make_gba_fused_psum_step
from repro.analysis import jaxpr_audit as JA

out = {"devices": jax.device_count()}
mesh = jax.make_mesh((4,), ("data",))
key = jax.random.PRNGKey(7)
params = {"embed": jax.random.normal(key, (33, 9)),
          "blocks": {"l0": {"w": jax.random.normal(
                                jax.random.PRNGKey(8), (41,)),
                            "b": jax.random.normal(
                                jax.random.PRNGKey(9), (7, 5))}},
          "head": jax.random.normal(jax.random.PRNGKey(10), (700,))}
iota, lr, m = 2, 0.05, 4
lay = ShardedFlatLayout.from_params(params, m, tile=256,
                                    group_by=lambda n: n[0])

def loss_fn(p, batch):
    s = sum(jnp.sum(l.astype(jnp.float32) ** 2)
            for l in jax.tree.leaves(p))
    return jnp.mean(batch["x"]) * s

def run(pol, warm, steps=3):
    step = jax.jit(make_gba_fused_psum_step(
        mesh, loss_fn, lay, iota=iota, lr=lr, compress=pol, warm=warm))
    pf = lay.ravel(params)
    af = jnp.full((lay.padded_total,), 0.1, jnp.float32)
    wire = pol.init_wire_state(lay, m) if pol and pol.stateful else None
    losses = []
    with mesh:
        for t in range(steps):
            x = jax.random.normal(jax.random.PRNGKey(50 + t), (32,))
            bsh = jax.device_put({"x": x}, NamedSharding(mesh, P("data")))
            # worker 2's slot is 3 steps stale: Eq. (1) decays it to zero
            toks = jnp.array([t, t, t - 3, t], jnp.int32)
            tsh = jax.device_put(toks, NamedSharding(mesh, P("data")))
            if wire is None:
                pf, af, loss = step(pf, af, bsh, tsh, jnp.int32(t))
            else:
                pf, af, loss, wire = step(pf, af, bsh, tsh, jnp.int32(t),
                                          wire)
            losses.append(float(loss))
    return pf, af, losses, wire

def maxdiff(a, b):
    return float(jnp.max(jnp.abs(a - b)))

bp, ba, bl, _ = run(CompressionPolicy(), False)
for scheme in ("int8", "onebit"):
    pol = CompressionPolicy(scheme=scheme, warmup_steps=10)
    wp, wa, wl, wire = run(pol, True)
    out[f"warm_{scheme}_param_err"] = maxdiff(wp, bp)
    out[f"warm_{scheme}_accum_err"] = maxdiff(wa, ba)
    out[f"warm_{scheme}_loss_err"] = max(
        abs(a - b) for a, b in zip(wl, bl))
    out[f"warm_{scheme}_residual_max"] = float(
        jnp.abs(wire["residual"]).max())
    if scheme == "onebit":
        out["warm_momentum_max"] = float(jnp.abs(wire["momentum"]).max())

# compressed runs: error feedback engaged, params stay near baseline
for scheme in ("int8", "onebit"):
    pol = CompressionPolicy(scheme=scheme, warmup_steps=0)
    cp, ca, cl, wire = run(pol, False)
    out[f"{scheme}_param_dev"] = maxdiff(cp, bp)
    out[f"{scheme}_residual_max"] = float(jnp.abs(wire["residual"]).max())
    out[f"{scheme}_finite"] = bool(jnp.isfinite(cp).all())

# census: compressed + warmup traces against GBA-COLL-005 / COLL-001
pol = CompressionPolicy(scheme="int8", warmup_steps=1)
wire0 = pol.init_wire_state(lay, m)
x0 = jax.random.normal(jax.random.PRNGKey(50), (32,))
args = (lay.ravel(params), jnp.full((lay.padded_total,), 0.1),
        {"x": x0}, jnp.zeros((4,), jnp.int32), jnp.int32(0), wire0)
with mesh:
    jc = jax.make_jaxpr(make_gba_fused_psum_step(
        mesh, loss_fn, lay, iota=iota, lr=lr, compress=pol))(*args)
    jw = jax.make_jaxpr(make_gba_fused_psum_step(
        mesh, loss_fn, lay, iota=iota, lr=lr, compress=pol,
        warm=True))(*args)
out["compressed_findings"] = [
    str(f) for f in JA.check_wire_dtypes(jc, lay, m, pol, "t/c")]
out["warm_findings"] = [
    str(f) for f in JA.check_wire_dtypes(jw, lay, m, pol, "t/w",
                                         warm=True)
    ] + [str(f) for f in JA.check_fused_psum_schedule(jw, lay, m, "t/w")]
# a f32 wire past warmup MUST trip the rule (census not vacuous here)
out["leak_findings"] = [
    str(f) for f in JA.check_wire_dtypes(jw, lay, m, pol, "t/leak")]
counts = JA.census_counts(JA.collective_census(jc))
out["compressed_all_to_all"] = counts.get("all_to_all", 0)
out["n_groups"] = lay.num_groups
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def wire_results():
    out = subprocess.run(
        [sys.executable, "-c", _WIRE_SCRIPT], capture_output=True,
        text=True, env=dict(_ENV), cwd="/root/repo", timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_warmup_bit_exact_with_pr5(wire_results):
    """Acceptance: the f32 warmup phase of BOTH schemes is bit-exact
    with the uncompressed PR-5 step — params, accum, loss — over 3
    global steps with an Eq.-(1)-decayed slot and non-tile-multiple
    leaves.  Residuals stay exactly zero through warmup; the onebit
    momentum EMA is already accumulating."""
    res = wire_results
    assert res["devices"] == 4
    for scheme in ("int8", "onebit"):
        assert res[f"warm_{scheme}_param_err"] == 0.0, res
        assert res[f"warm_{scheme}_accum_err"] == 0.0, res
        assert res[f"warm_{scheme}_loss_err"] == 0.0, res
        assert res[f"warm_{scheme}_residual_max"] == 0.0, res
    assert res["warm_momentum_max"] > 0.0


@pytest.mark.slow
def test_compressed_wire_error_feedback_active(wire_results):
    """Past warmup the lossy wire engages: residuals are nonzero (error
    feedback carries the dropped code), the trained params stay finite
    and near the full-precision trajectory on the quadratic probe."""
    res = wire_results
    for scheme in ("int8", "onebit"):
        assert res[f"{scheme}_finite"], res
        assert res[f"{scheme}_residual_max"] > 0.0, res
    assert res["int8_param_dev"] < 1e-2, res
    assert res["onebit_param_dev"] < 0.5, res


@pytest.mark.slow
def test_compressed_census_coll_005(wire_results):
    """GBA-COLL-005 on the real traces: the compressed program routes
    int8 payload + f32 sidebands only (3 all_to_all per group for int8);
    the warmup program routes f32 and reproduces the PR-5 schedule
    exactly; and a f32 wire checked as past-warmup DOES trip the rule —
    full-precision leakage is a CI failure, not a silent pass."""
    res = wire_results
    assert res["compressed_findings"] == [], res["compressed_findings"]
    assert res["warm_findings"] == [], res["warm_findings"]
    assert res["compressed_all_to_all"] == 3 * res["n_groups"]
    assert res["leak_findings"], "f32 leak past warmup must be flagged"
    assert all("GBA-COLL-005" in f for f in res["leak_findings"])


# ---------------------------------------------------------------------------
# slow: onebit convergence on the tiny-DeepFM recsys smoke (subprocess)
# ---------------------------------------------------------------------------

_RECSYS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs.recsys import RecsysConfig
from repro.core.compression import CompressionPolicy
from repro.core.flat_sharded import ShardedFlatLayout
from repro.core.gba_shard_map import make_gba_fused_psum_step
from repro.models import recsys as R

cfg = RecsysConfig(name="tiny-deepfm", model="deepfm", num_fields=4,
                   hash_capacity=523, embed_dim=8, mlp_dims=(16,))
params = R.init_deepfm(jax.random.PRNGKey(0), cfg)
mesh = jax.make_mesh((4,), ("data",))
m, iota, lr, B, steps = 4, 4, 0.4, 64, 40
lay = ShardedFlatLayout.from_params(params, m, tile=256)
teacher = jax.random.normal(jax.random.PRNGKey(99), (cfg.hash_capacity,))

def batch_at(t):
    k = jax.random.PRNGKey(1000 + t)
    ids = jax.random.randint(k, (B, cfg.num_fields), 0, cfg.hash_capacity)
    label = (teacher[ids].sum(axis=1) > 0.0).astype(jnp.float32)
    return {"fields": ids, "label": label}

def loss_fn(p, batch):
    return R.bce_loss(p, cfg, batch)

def run(pol):
    pf = lay.ravel(params)
    af = jnp.full((lay.padded_total,), 0.1, jnp.float32)
    wire = pol.init_wire_state(lay, m) if pol.stateful else None
    steps_fns = {}
    losses = []
    with mesh:
        for t in range(steps):
            warm = pol.stateful and t < pol.warmup_steps
            key = ("warm" if warm else "main", pol.scheme)
            if key not in steps_fns:
                steps_fns[key] = jax.jit(make_gba_fused_psum_step(
                    mesh, loss_fn, lay, iota=iota, lr=lr, compress=pol,
                    warm=warm))
            b = jax.device_put(batch_at(t), NamedSharding(mesh, P("data")))
            toks = jax.device_put(jnp.full((m,), t, jnp.int32),
                                  NamedSharding(mesh, P("data")))
            if wire is None:
                pf, af, loss = steps_fns[key](pf, af, b, toks, jnp.int32(t))
            else:
                pf, af, loss, wire = steps_fns[key](pf, af, b, toks,
                                                    jnp.int32(t), wire)
            losses.append(float(loss))
    return losses

base = run(CompressionPolicy())
ob = run(CompressionPolicy(scheme="onebit", warmup_steps=2, momentum=0.9))
out = {"devices": jax.device_count(), "base": base, "onebit": ob}
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def recsys_results():
    out = subprocess.run(
        [sys.executable, "-c", _RECSYS_SCRIPT], capture_output=True,
        text=True, env=dict(_ENV), cwd="/root/repo", timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_onebit_converges_on_recsys_smoke(recsys_results):
    """Seeded statistical acceptance: onebit sign-of-momentum training
    (2-step f32 warmup, error feedback) still LEARNS the tiny-DeepFM
    click task — final-window loss clearly below the initial loss — and
    lands within a tolerance band of the full-precision run."""
    res = recsys_results
    assert res["devices"] == 4
    base, ob = res["base"], res["onebit"]
    assert all(np.isfinite(ob)), ob
    # warmup is bit-exact with full precision by construction
    assert ob[0] == base[0] and ob[1] == base[1]
    start, b_end = base[0], float(np.mean(base[-5:]))
    o_end = float(np.mean(ob[-5:]))
    assert b_end < start - 0.03, (start, b_end)      # baseline learns
    assert o_end < start - 0.02, (start, o_end)      # onebit learns too
    assert abs(o_end - b_end) < 0.05, (o_end, b_end)  # tolerance band
