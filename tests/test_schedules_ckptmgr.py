"""LR schedules + checkpoint manager."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.optim.schedules import (constant, inverse_sqrt, step_decay,
                                   warmup_cosine)


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, warmup_steps=10, total_steps=110)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(5)) == pytest.approx(0.5)
    mid = float(s(60))
    assert 0.1 < mid < 1.0
    assert float(s(110)) == pytest.approx(0.1, abs=1e-6)
    # monotone decay after warmup
    vals = [float(s(t)) for t in range(10, 111, 10)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


def test_inverse_sqrt():
    s = inverse_sqrt(2.0, warmup_steps=16)
    assert abs(float(s(16)) - 2.0) < 1e-6
    assert float(s(64)) == pytest.approx(1.0)


def test_step_decay():
    s = step_decay(1.0, boundaries=(10, 20), factors=(0.5, 0.1))
    assert float(s(5)) == 1.0
    assert float(s(15)) == 0.5
    assert float(s(25)) == pytest.approx(0.1)


def test_constant():
    assert float(constant(0.3)(1234)) == pytest.approx(0.3)


def test_schedule_with_optimizer():
    from repro.optim import sgd
    opt = sgd(999.0)  # base lr overridden
    params = {"w": jnp.array([1.0])}
    state = opt.init(params)
    sched = step_decay(0.1, (1,), (0.5,))
    p1, state = opt.update(params, {"w": jnp.array([1.0])}, state,
                           lr_override=sched(0))
    np.testing.assert_allclose(np.asarray(p1["w"]), [0.9], rtol=1e-6)


def test_checkpoint_manager_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 5, 9):
        mgr.save(step, {"params": {"w": jnp.full((3,), float(step))},
                        "step": jnp.int32(step)})
    assert mgr.steps() == [5, 9]
    step, state = mgr.restore_latest()
    assert step == 9
    np.testing.assert_allclose(np.asarray(state["params"]["w"]),
                               np.full(3, 9.0))
    state5 = mgr.restore(5)
    assert int(state5["step"]) == 5


def test_checkpoint_manager_roundtrip_train_state(tmp_path):
    import jax
    from repro.configs import get_config
    from repro.launch.steps import init_train_state
    from repro.models import transformer as T
    from repro.optim import get_optimizer
    cfg = get_config("mamba2-780m").reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    opt = get_optimizer("adagrad", 1e-3)
    state = init_train_state(params, opt)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(0, state)
    _, restored = mgr.restore_latest()
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-6)
