"""Cluster-simulator behaviour: the qualitative claims of Tab. 5.2/5.3."""
import numpy as np
import pytest

from repro.sim.cluster import ClusterSpec, simulate

STRAINED = ClusterSpec(num_workers=16, straggler_frac=0.25,
                       straggler_slowdown=5.0, jitter=0.2,
                       time_varying=True, seed=1)
VACANT = ClusterSpec(num_workers=16, straggler_frac=0.0, jitter=0.02,
                     ps_throughput=150.0, seed=1)


def test_gba_matches_async_qps_under_strain():
    a = simulate(STRAINED, "async", 960, 256).metrics
    g = simulate(STRAINED, "gba", 960, 256, buffer_size=16, iota=4).metrics
    assert abs(g.qps - a.qps) / a.qps < 0.05


def test_gba_speedup_over_sync_under_strain():
    s = simulate(STRAINED, "sync", 960, 256).metrics
    g = simulate(STRAINED, "gba", 960, 256, buffer_size=16, iota=4).metrics
    assert g.qps / s.qps >= 2.4, "paper claims >=2.4x under strain"


def test_sync_wins_when_vacant():
    """Fig. 1: with a finite PS, sync HPC is the faster mode on a vacant
    cluster — the reason mode switching exists at all."""
    s = simulate(VACANT, "sync", 960, 256).metrics
    g = simulate(VACANT, "gba", 960, 256, buffer_size=16, iota=4).metrics
    assert g.qps < s.qps


def test_hop_bs_struggles_with_stragglers():
    h = simulate(STRAINED, "hop_bs", 960, 256, b1=2).metrics
    a = simulate(STRAINED, "async", 960, 256).metrics
    assert h.qps < 0.5 * a.qps


def test_hop_bw_drops_most():
    h = simulate(STRAINED, "hop_bw", 960, 256, b3=2).metrics
    g = simulate(STRAINED, "gba", 960, 256, buffer_size=16, iota=4).metrics
    assert h.dropped_batches > 20 * max(g.dropped_batches, 1)


def test_gba_staleness_bounded_by_iota():
    g = simulate(STRAINED, "gba", 960, 256, buffer_size=16, iota=4).metrics
    assert g.staleness_max <= 4


def test_hop_bs_staleness_bounded_by_b1():
    h = simulate(STRAINED, "hop_bs", 960, 256, b1=2).metrics
    assert h.staleness_max <= 2


def test_sync_zero_staleness():
    s = simulate(STRAINED, "sync", 960, 256).metrics
    assert s.avg_staleness == 0.0 and s.staleness_max == 0


def test_bsp_unbounded_staleness_exceeds_gba():
    b = simulate(STRAINED, "bsp", 960, 256, b2=16).metrics
    g = simulate(STRAINED, "gba", 960, 256, buffer_size=16, iota=4).metrics
    assert b.staleness_max >= g.staleness_max


def test_deterministic():
    m1 = simulate(STRAINED, "gba", 480, 128, buffer_size=16, iota=4).metrics
    m2 = simulate(STRAINED, "gba", 480, 128, buffer_size=16, iota=4).metrics
    assert m1.qps == m2.qps and m1.dropped_batches == m2.dropped_batches


def test_worker_failures_tolerated():
    """Alg. 1: a crashed worker's token disappears; GBA keeps its staleness
    bound and every surviving batch is scheduled exactly once."""
    spec = ClusterSpec(num_workers=16, straggler_frac=0.25, jitter=0.2,
                       failure_rate=0.05, seed=3)
    s = simulate(spec, "gba", 960, 256, buffer_size=16, iota=4)
    m = s.metrics
    assert m.lost_batches > 0
    seen = set()
    for k, slots in enumerate(s.steps):
        for sl in slots:
            assert sl.batch_index not in seen
            seen.add(sl.batch_index)
            if sl.weight > 0:
                assert k - sl.token <= 4
    assert len(seen) + m.lost_batches <= 960
    assert len(seen) >= 960 - m.lost_batches - 16  # at most N in flight


# ---------------------------------------------------------------------------
# failure_rate / recovery_time crash path (Alg. 1)
# ---------------------------------------------------------------------------

def test_crashed_token_never_aggregated():
    """Alg. 1: a crashed worker's gradient AND its token disappear.
    With buffer_size=1 every surviving dispatch lands in exactly one
    global step, so the lost batches are precisely the dispatched
    indices missing from the schedule."""
    spec = ClusterSpec(num_workers=4, jitter=0.1, failure_rate=0.15,
                       recovery_time=2.0, seed=7)
    s = simulate(spec, "gba", 200, 64, buffer_size=1, iota=4)
    m = s.metrics
    assert m.lost_batches > 0
    seen = [sl.batch_index for slots in s.steps for sl in slots]
    assert len(seen) == len(set(seen))              # each at most once
    assert len(seen) == 200 - m.lost_batches        # lost ones NEVER land
    assert set(seen) | (set(range(200)) - set(seen)) == set(range(200))
    # SimMetrics reflects it: samples count only scheduled batches
    assert m.samples == (200 - m.lost_batches) * 64


def test_crashed_worker_rejoins_after_recovery_time():
    """The crashed worker redispatches at t + recovery_time: with one
    worker and zero jitter the rng stream (and so the crash pattern) is
    identical across recovery_time values, and every crash with work
    remaining delays the makespan by exactly the recovery delta."""
    def run(recovery):
        spec = ClusterSpec(num_workers=1, jitter=0.0, straggler_frac=0.0,
                           failure_rate=0.1, recovery_time=recovery,
                           seed=2)
        return simulate(spec, "gba", 120, 64, buffer_size=1,
                        iota=4).metrics

    m1, m9 = run(1.0), run(9.0)
    assert m1.lost_batches == m9.lost_batches > 0
    diff = m9.wall_time - m1.wall_time
    n = diff / 8.0                       # crashes that had work remaining
    assert n > 0 and abs(n - round(n)) < 1e-6
    assert round(n) <= m1.lost_batches


def test_failure_rate_zero_loses_nothing():
    spec = ClusterSpec(num_workers=4, jitter=0.1, failure_rate=0.0, seed=7)
    m = simulate(spec, "gba", 200, 64, buffer_size=4, iota=4).metrics
    assert m.lost_batches == 0
    assert m.samples == 200 * 64


def test_crash_losses_scale_with_failure_rate():
    """More crash probability, more lost tokens — and the drop counters
    stay separate: lost_batches (crashes) vs dropped_batches (Eq. 1)."""
    def run(rate):
        spec = ClusterSpec(num_workers=8, jitter=0.1, failure_rate=rate,
                           recovery_time=1.0, seed=11)
        return simulate(spec, "gba", 400, 64, buffer_size=8,
                        iota=4).metrics

    lo, hi = run(0.02), run(0.25)
    assert 0 < lo.lost_batches < hi.lost_batches
    # crash losses are NOT double-counted as staleness drops
    assert lo.lost_batches + lo.dropped_batches <= 400
