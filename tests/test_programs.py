"""build_programs: the unified train-program builder, and the
deprecation shims in repro.launch.steps that forward to it.

The equivalence tests build the SAME program twice — once through the
legacy factory names (which must emit DeprecationWarning) and once
through build_programs — and require bit-identical losses and updated
parameters.  Both paths jit with donate_argnums=0, so each path gets its
own freshly initialized (identical-by-PRNG) state.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import GBAConfig
from repro.launch import steps as steps_mod
from repro.launch.programs import build_programs
from repro.models import transformer as T
from repro.optim import get_optimizer

B, S = 2, 16
ARCH = "mamba2-780m"


def _setup():
    cfg = get_config(ARCH).reduced()
    gba = GBAConfig(local_batch=B, buffer_size=1, staleness_tolerance=4)
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    return cfg, gba, batch


def _params(cfg):
    return T.init_model(jax.random.PRNGKey(1), cfg)


def _assert_trees_equal(a, b):
    flat_a, flat_b = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(flat_a) == len(flat_b)
    for la, lb in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_pytree_shim_equivalence():
    cfg, gba, batch = _setup()
    token = jnp.zeros((), jnp.int32)
    opt = get_optimizer("adam", 1e-3)

    with pytest.deprecated_call():
        legacy_step = steps_mod.make_train_step(cfg, opt, gba)
    with pytest.deprecated_call():
        legacy_state = steps_mod.init_train_state(_params(cfg), opt)
    legacy_state2, legacy_loss = jax.jit(legacy_step)(
        legacy_state, batch, token)

    progs = build_programs(cfg, gba, mode="pytree", optimizer=opt,
                           params=_params(cfg))
    state2, loss = progs.step(progs.state, batch, token)

    assert float(loss) == float(legacy_loss)
    _assert_trees_equal(state2["params"], legacy_state2["params"])
    assert int(state2["gstep"]) == int(legacy_state2["gstep"]) == 1


def test_fused_shim_equivalence():
    cfg, gba, batch = _setup()
    token = jnp.zeros((), jnp.int32)

    with pytest.deprecated_call():
        layout, legacy_state = steps_mod.init_fused_train_state(
            _params(cfg), gba)
    with pytest.deprecated_call():
        legacy_step = steps_mod.jit_fused_train_step(cfg, gba, layout)
    legacy_state2, legacy_loss = legacy_step(legacy_state, batch, token)

    progs = build_programs(cfg, gba, mode="fused", params=_params(cfg))
    state2, loss = progs.step(progs.state, batch, token)

    assert float(loss) == float(legacy_loss)
    _assert_trees_equal(state2["params"], legacy_state2["params"])
    np.testing.assert_array_equal(np.asarray(state2["accum"]),
                                  np.asarray(legacy_state2["accum"]))


def test_shim_warning_points_at_builder():
    cfg, gba, _ = _setup()
    opt = get_optimizer("adam", 1e-3)
    with pytest.warns(DeprecationWarning, match="build_programs"):
        steps_mod.make_train_step(cfg, opt, gba)


def test_build_programs_validation():
    _, gba, _ = _setup()
    with pytest.raises(ValueError, match="mesh"):
        build_programs(None, gba, mode="wire", loss_fn=lambda p, b: 0.0)
    with pytest.raises(ValueError, match="params or an explicit layout"):
        build_programs(None, gba, mode="fused", loss_fn=lambda p, b: 0.0)
    with pytest.raises(ValueError, match="unknown mode"):
        build_programs(None, gba, mode="nope", loss_fn=lambda p, b: 0.0)
    with pytest.raises(ValueError, match="ModelConfig or a loss_fn"):
        build_programs(None, gba, mode="sync_psum",
                       mesh=jax.sharding.Mesh(
                           np.array(jax.devices()[:1]), ("data",)))
