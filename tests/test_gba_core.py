"""Unit tests for the GBA core: token list, decay, aggregation semantics,
per-ID embedding treatment, buffer-as-train-step-transform."""
import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (TokenList, TokenListExhausted, aggregate_dense,
                        aggregate_embedding, buffer_push_and_maybe_apply,
                        decay_weights, init_buffer, num_global_steps,
                        token_for_batch, token_list)


def test_token_list_construction():
    # Q=10, M=3 -> K=4 steps; tokens ascend, each value repeats M times
    tl = token_list(10, 3)
    assert list(np.asarray(tl)) == [0, 0, 0, 1, 1, 1, 2, 2, 2, 3]
    assert num_global_steps(10, 3) == 4
    assert token_for_batch(7, 3) == 2


def test_token_list_stateful():
    tl = TokenList(6, 2)
    assert [tl.fetch() for _ in range(6)] == [0, 0, 1, 1, 2, 2]


def test_token_list_exhaustion_is_not_stop_iteration():
    """fetch past the end raises TokenListExhausted (an IndexError) — NOT
    StopIteration, which PEP 479 silently mutates into RuntimeError when
    it escapes a generator frame, making the exhaustion signal
    uncatchable by name inside generator-based dispatch loops."""
    tl = TokenList(2, 1)
    tl.fetch(), tl.fetch()
    with pytest.raises(TokenListExhausted):
        tl.fetch()
    assert not issubclass(TokenListExhausted, StopIteration)
    assert issubclass(TokenListExhausted, IndexError)

    # the PEP 479 trap this guards against: a generator draining the list
    # must see the real exception, not a RuntimeError
    def dispatch(tlist):
        while True:
            yield tlist.fetch()

    gen = dispatch(TokenList(2, 1))
    got = []
    try:
        for tok in gen:
            got.append(tok)
    except TokenListExhausted:
        pass                      # catchable under its own name
    assert got == [0, 1]


def test_decay_threshold():
    tokens = jnp.array([0, 1, 2, 3], jnp.int32)
    w = decay_weights(tokens, jnp.int32(4), iota=2)
    np.testing.assert_allclose(np.asarray(w), [0, 0, 1, 1])


def test_aggregate_dense_divides_by_m():
    """Paper Alg.2 line 22: weighted sum / N_a — dropped slots shrink the
    gradient, they do not renormalize."""
    grads = {"w": jnp.stack([jnp.ones((4,)), 3 * jnp.ones((4,))])}
    tokens = jnp.array([0, 10], jnp.int32)
    out = aggregate_dense(grads, tokens, jnp.int32(10), iota=1)
    # slot 0 dropped (stale 10), slot 1 kept: (0 + 3)/2
    np.testing.assert_allclose(np.asarray(out["w"]), 1.5)


def test_aggregate_dense_equals_sync_when_fresh():
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (8, 32))}
    tokens = jnp.full((8,), 7, jnp.int32)
    out = aggregate_dense(grads, tokens, jnp.int32(7), iota=0)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(grads["w"].mean(0)), rtol=1e-6)


def test_aggregate_embedding_per_id():
    # 2 slots, capacity 4: slot 0 fresh, slot 1 severely stale
    ids = jnp.array([[0, 1], [1, 2]], jnp.int32)
    rows = jnp.ones((2, 2, 3), jnp.float32)
    tokens = jnp.array([10, 0], jnp.int32)       # slot1 stale by 10
    # id2 untouched since step 0 -> rescued; id1 updated at step 5 -> dropped
    last_update = jnp.array([0, 5, 0, 0], jnp.int32)
    dense, counts = aggregate_embedding(ids, rows, tokens,
                                        last_update, jnp.int32(10), iota=2,
                                        capacity=4)
    np.testing.assert_allclose(np.asarray(counts), [1, 1, 1, 0])
    np.testing.assert_allclose(np.asarray(dense[0]), np.ones(3))   # slot0
    np.testing.assert_allclose(np.asarray(dense[1]), np.ones(3))   # slot0 only
    np.testing.assert_allclose(np.asarray(dense[2]), np.ones(3))   # rescued
    np.testing.assert_allclose(np.asarray(dense[3]), np.zeros(3))


def test_aggregate_embedding_contributor_normalization():
    # both slots fresh, both touch id 0 -> divided by 2 (Alg.2 line 23)
    ids = jnp.array([[0], [0]], jnp.int32)
    rows = jnp.stack([jnp.full((1, 3), 2.0), jnp.full((1, 3), 4.0)])
    tokens = jnp.array([5, 5], jnp.int32)
    last_update = jnp.zeros((2,), jnp.int32)
    dense, counts = aggregate_embedding(ids, rows, tokens, last_update,
                                        jnp.int32(5), iota=1, capacity=2)
    np.testing.assert_allclose(np.asarray(counts[0]), 2.0)
    np.testing.assert_allclose(np.asarray(dense[0]), np.full(3, 3.0))


def test_aggregate_embedding_padded_batch():
    """Regression: padded/sentinel slots must not inflate the per-ID
    contributor counts (Alg. 2 line 23's divisor) or scatter ghost rows.
    Uses the kernels' sentinel convention — any ID outside [0, capacity)
    is padding (repro.kernels.embedding_bag maps padding to an
    out-of-range sentinel); negative IDs used to wrap around and pollute
    real rows."""
    capacity = 4
    # slot 0: real id 0 + sentinel (== capacity); slot 1: real id 0 + -1 pad
    ids = jnp.array([[0, capacity], [0, -1]], jnp.int32)
    rows = jnp.stack([jnp.stack([jnp.full((3,), 2.0), jnp.full((3,), 9.0)]),
                      jnp.stack([jnp.full((3,), 4.0), jnp.full((3,), 9.0)])])
    tokens = jnp.array([5, 5], jnp.int32)
    last_update = jnp.zeros((capacity,), jnp.int32)
    dense, counts = aggregate_embedding(ids, rows, tokens, last_update,
                                        jnp.int32(5), iota=1,
                                        capacity=capacity)
    # id 0: exactly the two real contributors -> mean (2+4)/2, count 2
    np.testing.assert_allclose(np.asarray(counts), [2, 0, 0, 0])
    np.testing.assert_allclose(np.asarray(dense[0]), np.full(3, 3.0))
    # the -1 pad must NOT wrap to the last row, the sentinel row must not
    # exist at all
    np.testing.assert_allclose(np.asarray(dense[1:]), np.zeros((3, 3)))


def test_aggregate_embedding_explicit_valid_mask():
    """An explicit valid mask excludes in-range slots too (e.g. a worker
    marking half a batch invalid after a data error)."""
    ids = jnp.array([[0], [0]], jnp.int32)
    rows = jnp.stack([jnp.full((1, 3), 2.0), jnp.full((1, 3), 4.0)])
    tokens = jnp.array([5, 5], jnp.int32)
    last_update = jnp.zeros((2,), jnp.int32)
    dense, counts = aggregate_embedding(
        ids, rows, tokens, last_update, jnp.int32(5), iota=1, capacity=2,
        valid=jnp.array([[True], [False]]))
    np.testing.assert_allclose(np.asarray(counts), [1, 0])
    np.testing.assert_allclose(np.asarray(dense[0]), np.full(3, 2.0))


def test_buffer_push_and_apply():
    """Both cond branches are traced, so apply/noop return data (the
    aggregate or zeros) rather than performing side effects."""
    params = {"w": jnp.zeros((4,))}
    buf = init_buffer(params, buffer_size=3)

    def apply_fn(agg):
        return (jnp.int32(1), agg["w"])

    def noop_fn():
        return (jnp.int32(0), jnp.zeros((4,)))

    applied = []
    for i in range(6):
        grads = {"w": jnp.full((4,), float(i))}
        (flag, agg_w), buf = buffer_push_and_maybe_apply(
            buf, grads, jnp.int32(0), 100, apply_fn, noop_fn)
        if int(flag):
            applied.append(np.asarray(agg_w))
    assert int(buf["step"]) == 2
    assert len(applied) == 2
    # first apply: mean(0,1,2) = 1; second: mean(3,4,5) = 4
    np.testing.assert_allclose(applied[0], np.full(4, 1.0))
    np.testing.assert_allclose(applied[1], np.full(4, 4.0))
