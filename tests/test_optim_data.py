"""Optimizers vs hand-computed math; data pipeline determinism + skew."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.recsys import CRITEO_DEEPFM
from repro.data import make_clickstream, make_lm_stream
from repro.optim import adagrad, adam, sgd


def test_sgd():
    opt = sgd(0.1)
    params = {"w": jnp.array([1.0, 2.0])}
    state = opt.init(params)
    new, _ = opt.update(params, {"w": jnp.array([1.0, -1.0])}, state)
    np.testing.assert_allclose(np.asarray(new["w"]), [0.9, 2.1])


def test_adagrad_math():
    opt = adagrad(0.5, initial_accum=0.0)
    params = {"w": jnp.array([1.0])}
    state = opt.init(params)
    g = {"w": jnp.array([2.0])}
    new, state = opt.update(params, g, state)
    # accum = 4; step = 0.5 * 2/2 = 0.5
    np.testing.assert_allclose(np.asarray(new["w"]), [0.5], rtol=1e-5)
    np.testing.assert_allclose(np.asarray(state["accum"]["w"]), [4.0])


def test_adam_math():
    opt = adam(0.1, b1=0.9, b2=0.99)
    params = {"w": jnp.array([0.0])}
    state = opt.init(params)
    g = {"w": jnp.array([1.0])}
    new, state = opt.update(params, g, state)
    # bias-corrected first step = -lr * g/|g| = -0.1
    np.testing.assert_allclose(np.asarray(new["w"]), [-0.1], rtol=1e-4)
    new2, state = opt.update(new, g, state)
    assert float(new2["w"][0]) < float(new["w"][0])


def test_clickstream_deterministic():
    s = make_clickstream(CRITEO_DEEPFM, seed=3)
    b1 = s.batch(2, 5)
    b2 = s.batch(2, 5)
    np.testing.assert_array_equal(b1["fields"], b2["fields"])
    np.testing.assert_array_equal(b1["label"], b2["label"])
    b3 = s.batch(2, 6)
    assert not np.array_equal(b1["fields"], b3["fields"])


def test_clickstream_zipf_skew():
    """Fig. 4: ID occurrences are heavily skewed."""
    s = make_clickstream(CRITEO_DEEPFM, seed=0, batch_size=512)
    ids = np.concatenate([s.batch(0, i)["fields"].ravel()
                          for i in range(8)])
    _, counts = np.unique(ids, return_counts=True)
    counts = np.sort(counts)[::-1]
    top1pct = counts[:max(1, len(counts) // 100)].sum() / counts.sum()
    assert top1pct > 0.2, f"top-1% IDs carry {top1pct:.1%}, expected skew"


def test_clickstream_learnable_labels():
    """Labels correlate with the latent model -> AUC target exists."""
    s = make_clickstream(CRITEO_DEEPFM, seed=0, batch_size=4096)
    b = s.batch(0, 0)
    assert 0.05 < b["label"].mean() < 0.5   # CTR-like base rate


def test_lm_stream_shapes_and_determinism():
    s = make_lm_stream(vocab_size=128, seq_len=32, batch_size=4, seed=1)
    b1, b2 = s.batch(0), s.batch(0)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert b1["tokens"].max() < 128


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import load_pytree, save_pytree
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": [jnp.ones((2,)), None],
            "c": {"d": (jnp.int32(3), jnp.zeros(()))}}
    p = str(tmp_path / "ck.npz")
    save_pytree(p, tree)
    out = load_pytree(p)
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"][1] is None
    assert isinstance(out["c"]["d"], tuple)
    assert int(out["c"]["d"][0]) == 3
