"""Hypothesis property tests on model-level invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import layers as L
from repro.models import transformer as T

settings.register_profile("models", deadline=None, max_examples=8)
settings.load_profile("models")


def _tiny(arch="granite-8b", **kw):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32",
                              **kw)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


@given(t=st.integers(1, 14), seed=st.integers(0, 2**16))
def test_causality(t, seed):
    """Logits at position t are independent of tokens after t."""
    cfg, params = _tiny()
    rng = np.random.default_rng(seed)
    a = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    b = a.copy()
    b[t + 1:] = rng.integers(0, cfg.vocab_size, 16 - t - 1)
    la, _ = T.forward(params, cfg, jnp.asarray(a)[None])
    lb, _ = T.forward(params, cfg, jnp.asarray(b)[None])
    np.testing.assert_allclose(np.asarray(la[0, :t + 1]),
                               np.asarray(lb[0, :t + 1]),
                               rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 2**16))
def test_ssm_causality(seed):
    cfg, params = _tiny("mamba2-780m")
    rng = np.random.default_rng(seed)
    t = 8
    a = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    b = a.copy()
    b[t + 1:] = rng.integers(0, cfg.vocab_size, 16 - t - 1)
    la, _ = T.forward(params, cfg, jnp.asarray(a)[None])
    lb, _ = T.forward(params, cfg, jnp.asarray(b)[None])
    np.testing.assert_allclose(np.asarray(la[0, :t + 1]),
                               np.asarray(lb[0, :t + 1]),
                               rtol=1e-4, atol=1e-4)


@given(shift=st.integers(1, 64), seed=st.integers(0, 2**16))
def test_rope_relative_shift_invariance(shift, seed):
    """RoPE attention scores depend only on relative positions: shifting
    all positions by a constant leaves q.k' inner products unchanged."""
    rng = np.random.default_rng(seed)
    hd = 32
    q = jnp.asarray(rng.normal(size=(1, 4, 2, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 4, 2, hd)), jnp.float32)
    pos = jnp.arange(4)[None]
    q1 = L.rope(q, pos, 10_000.0)
    k1 = L.rope(k, pos, 10_000.0)
    q2 = L.rope(q, pos + shift, 10_000.0)
    k2 = L.rope(k, pos + shift, 10_000.0)
    s1 = jnp.einsum("bsnh,btnh->bnst", q1, k1)
    s2 = jnp.einsum("bsnh,btnh->bnst", q2, k2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


@given(seed=st.integers(0, 2**16))
def test_rope_preserves_norm(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 6, 3, 16)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, 1000, (2, 6)))
    y = L.rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)


@given(seed=st.integers(0, 2**16))
def test_batch_permutation_equivariance(seed):
    """Permuting the batch permutes the logits (no cross-example leaks)."""
    cfg, params = _tiny()
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (4, 8)).astype(np.int32)
    perm = rng.permutation(4)
    l1, _ = T.forward(params, cfg, jnp.asarray(toks))
    l2, _ = T.forward(params, cfg, jnp.asarray(toks[perm]))
    np.testing.assert_allclose(np.asarray(l1)[perm], np.asarray(l2),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drop_monotone():
    """Shrinking capacity_factor only ever drops tokens (output moves
    toward zero contribution), never invents them."""
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y_full, _ = L.moe_fwd(params, cfg, x)
    cfg_tight = dataclasses.replace(cfg, moe_capacity_factor=0.25)
    y_tight, _ = L.moe_fwd(params, cfg_tight, x)
    # tokens kept in both configs agree; dropped rows are exactly zero in
    # the tight config's per-token contribution
    diff_rows = np.abs(np.asarray(y_full - y_tight)).sum(-1).reshape(-1)
    tight_rows = np.abs(np.asarray(y_tight)).sum(-1).reshape(-1)
    changed = diff_rows > 1e-6
    # every changed token lost at least one expert -> its tight output is
    # a strict subset-sum, with norm <= full (weights are convex)
    full_rows = np.abs(np.asarray(y_full)).sum(-1).reshape(-1)
    assert (tight_rows[changed] <= full_rows[changed] + 1e-5).all()
