"""End-to-end tuning-free sync<->async switching harness
(launch.switch_driver).

Fast-lane host tests cover the carryover math (pad_mask /
tree_to_flat / flat_to_tree round trips on non-tile-multiple leaves),
SwitchConfig validation, constructor geometry checks, and a 1-worker
event-driven smoke.

The slow subprocess tests are the tentpole acceptance on a forced
4-device host mesh: (a) params AND accum are bit-exact across a forced
sync->async->sync swap versus an unswitched run replaying the SAME
global-step schedule — non-tile-multiple leaves, one Eq.-(1)-decayed
slot, one tombstone slot included — with the psum sync implementation
verified to kernel tolerance plus bit-exact swap round-trips; (b) the
strained-cluster FaultPlan (25% stragglers at 4x + one transient crash)
switches sync->async within the first telemetry window, reaches >=2x
sim-clock speedup over forced-sync on the same plan, and never
deadlocks on the crashed worker (timeouts fire, the worker rejoins in
BOTH legs); (c) chaos degradations — the fallback-to-sync circuit
breaker after repeated async apply failures, telemetry-scrape dropouts
holding the mode, and compression-warmup re-entry across repeated
async entries.
"""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flat_sharded import ShardedFlatLayout
from repro.launch.switch_driver import (GlobalStep, SwitchConfig,
                                        SwitchDriver, demo_batch_fn,
                                        demo_model, demo_plan,
                                        flat_to_tree, pad_mask,
                                        tree_to_flat)
from repro.sim.cluster import ClusterSpec
from repro.sim.faults import FaultPlan

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
        "JAX_PLATFORMS": "cpu"}


def _params():
    # deliberately non-tile-multiple leaf sizes vs tile=256
    k = jax.random.PRNGKey(0)
    return {"emb": jax.random.normal(k, (37, 33)),
            "mlp": {"w": jax.random.normal(jax.random.PRNGKey(1), (33,)),
                    "b": jax.random.normal(jax.random.PRNGKey(2), (7, 5))},
            "head": jax.random.normal(jax.random.PRNGKey(3), (111,))}


# ---------------------------------------------------------------------------
# carryover math (host, fast)
# ---------------------------------------------------------------------------

def test_pad_mask_marks_real_positions():
    p = _params()
    lay = ShardedFlatLayout.from_params(p, 4, tile=256,
                                        group_by=lambda n: n[0])
    mask = pad_mask(lay)
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p))
    assert mask.shape == (lay.padded_total,)
    assert float(mask.sum()) == total
    assert set(np.unique(np.asarray(mask))) <= {0.0, 1.0}


def test_tree_flat_round_trip_bit_exact():
    """tree -> flat -> tree reproduces params and accum bit-for-bit,
    and the flat accum carries initial_accum at every PAD position —
    exactly the state an unswitched fused run holds there."""
    p = _params()
    lay = ShardedFlatLayout.from_params(p, 4, tile=256,
                                        group_by=lambda n: n[0])
    accum = jax.tree.map(
        lambda l: jax.random.uniform(jax.random.PRNGKey(9), l.shape) + 0.1,
        p)
    pf, af = tree_to_flat(lay, p, accum, initial_accum=0.1)
    p2, opt2 = flat_to_tree(lay, pf, af)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p2)):
        assert jnp.array_equal(a, b)
    for a, b in zip(jax.tree.leaves(accum), jax.tree.leaves(opt2["accum"])):
        assert jnp.array_equal(a, b)
    # padding: param 0, accum exactly initial_accum
    mask = np.asarray(pad_mask(lay))
    assert np.all(np.asarray(pf)[mask == 0.0] == 0.0)
    assert np.all(np.asarray(af)[mask == 0.0] == np.float32(0.1))
    # flat -> tree -> flat also closes (f32 end to end)
    pf2, af2 = tree_to_flat(lay, p2, opt2["accum"], initial_accum=0.1)
    assert jnp.array_equal(pf, pf2) and jnp.array_equal(af, af2)


def test_accum_unravel_keeps_f32_for_bf16_params():
    """flat_to_tree must unravel the Adagrad accum as f32 even when the
    PARAM leaves are bf16 (layout.unravel would cast to leaf dtype)."""
    p = {"w": jnp.ones((300,), jnp.bfloat16)}
    lay = ShardedFlatLayout.from_params(p, 2, tile=128)
    accum = {"w": jnp.full((300,), 0.1234567, jnp.float32)}
    pf, af = tree_to_flat(lay, p, accum, initial_accum=0.1)
    _, opt = flat_to_tree(lay, pf, af)
    leaf = jax.tree.leaves(opt["accum"])[0]
    assert leaf.dtype == jnp.float32
    assert jnp.array_equal(leaf, accum["w"])


def test_switch_config_validation():
    with pytest.raises(ValueError):
        SwitchConfig(sync_impl="allreduce")
    with pytest.raises(ValueError):
        SwitchConfig(local_batch=0)
    with pytest.raises(ValueError):
        SwitchConfig(decide_every=0)
    with pytest.raises(ValueError):
        SwitchConfig(breaker_threshold=0)
    with pytest.raises(ValueError):
        SwitchConfig(max_retries=-1)
    assert SwitchConfig().push_timeout is None      # auto-resolved


def test_demo_plan_strained_shape():
    plan = demo_plan("strained", 4)
    assert len(plan.straggler_workers()) == 1       # 25% of 4
    assert len(plan.crashes) == 1
    with pytest.raises(ValueError):
        demo_plan("hurricane", 4)


# ---------------------------------------------------------------------------
# driver geometry + 1-worker smoke (host, fast)
# ---------------------------------------------------------------------------

def _driver_1w(cfg=None, plan=None, spec=None):
    mesh = jax.make_mesh((1,), ("data",))
    params, loss_fn, group_by = demo_model()
    cfg = cfg or SwitchConfig(local_batch=8, sync_impl="fused")
    return SwitchDriver(
        mesh, loss_fn, params,
        spec=spec or ClusterSpec(num_workers=1, jitter=0.0, seed=0),
        plan=plan or FaultPlan.quiet(1), cfg=cfg,
        batch_fn=demo_batch_fn(cfg.local_batch), group_by=group_by,
        tile=128)


def test_driver_rejects_mismatched_workers():
    mesh = jax.make_mesh((1,), ("data",))
    params, loss_fn, group_by = demo_model()
    with pytest.raises(ValueError):
        SwitchDriver(mesh, loss_fn, params,
                     spec=ClusterSpec(num_workers=2),
                     plan=FaultPlan.quiet(2),
                     cfg=SwitchConfig(local_batch=8, sync_impl="fused"),
                     batch_fn=demo_batch_fn(8), group_by=group_by,
                     tile=128)


def test_driver_rejects_bad_batch_fn():
    """batch_fn yielding a different leading dim than cfg.local_batch."""
    mesh = jax.make_mesh((1,), ("data",))
    params, loss_fn, group_by = demo_model()
    with pytest.raises(ValueError):
        SwitchDriver(mesh, loss_fn, params,
                     spec=ClusterSpec(num_workers=1, jitter=0.0, seed=0),
                     plan=FaultPlan.quiet(1),
                     cfg=SwitchConfig(local_batch=16, sync_impl="fused"),
                     batch_fn=demo_batch_fn(8), group_by=group_by,
                     tile=128)


def test_one_worker_auto_smoke():
    """1-worker quiet cluster: speedup is exactly 1.0, so auto mode
    never leaves sync; the run drains every batch and measures."""
    drv = _driver_1w()
    res = drv.run(6, mode="auto", seed=0)
    assert res.num_global_steps == 6
    assert res.switch_count == 0 and res.mode_steps == {"sync": 6}
    assert res.samples == 6 * 8 and res.qps > 0
    assert all(np.isfinite(l) for l in res.losses)
    assert res.controller_summary is not None


def test_run_rejects_unknown_mode_and_bad_schedule():
    drv = _driver_1w()
    with pytest.raises(ValueError):
        drv.run(2, mode="warp")
    with pytest.raises(ValueError):
        drv.run_schedule([GlobalStep((0,), (0,))], ["sync", "gba"])
    with pytest.raises(ValueError):
        drv.run_schedule([GlobalStep((0, 0), (0, 1))], ["sync"])


# ---------------------------------------------------------------------------
# slow: 4-device swap parity (subprocess)
# ---------------------------------------------------------------------------

_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax
import numpy as np
from repro.launch.switch_driver import (SwitchDriver, SwitchConfig,
                                        GlobalStep, demo_model,
                                        demo_batch_fn)
from repro.sim.cluster import ClusterSpec
from repro.sim.faults import FaultPlan

out = {"devices": jax.device_count()}
mesh = jax.make_mesh((4,), ("data",))
params, loss_fn, group_by = demo_model()
spec = ClusterSpec(num_workers=4)
plan = FaultPlan.quiet(4)

# 8-step schedule; step 5 carries an Eq.-(1)-decayed slot (token 0 at
# gstep 5, staleness > iota) AND a tombstone slot (batch -1)
IOTA = 4
steps, b = [], 0
for k in range(8):
    toks, bats = [k] * 4, []
    for s in range(4):
        bats.append(b); b += 1
    if k == 5:
        toks[1] = 0
        toks[2] = k - IOTA - 1; bats[2] = -1
    steps.append(GlobalStep(tuple(toks), tuple(bats)))
MODES_SW = ["sync"] * 3 + ["gba"] * 3 + ["sync"] * 2

def build(sync_impl):
    cfg = SwitchConfig(local_batch=8, iota=IOTA, sync_impl=sync_impl)
    return SwitchDriver(mesh, loss_fn, params, spec=spec, plan=plan,
                        cfg=cfg, batch_fn=demo_batch_fn(8),
                        group_by=group_by)

drv = build("fused")
r_sw = drv.run_schedule(steps, MODES_SW)
r_un_gba = drv.run_schedule(steps, ["gba"] * 8)
r_un_sync = drv.run_schedule(steps, ["sync"] * 8)
out["fused_switches"] = r_sw.switch_count
out["fused_dropped"] = r_sw.dropped_batches
out["fused_tombstones"] = r_sw.tombstones
out["p_bitexact_vs_gba"] = bool(
    np.array_equal(r_sw.param_flat, r_un_gba.param_flat))
out["a_bitexact_vs_gba"] = bool(
    np.array_equal(r_sw.accum_flat, r_un_gba.accum_flat))
out["p_bitexact_vs_sync"] = bool(
    np.array_equal(r_sw.param_flat, r_un_sync.param_flat))
out["a_bitexact_vs_sync"] = bool(
    np.array_equal(r_sw.accum_flat, r_un_sync.accum_flat))
out["losses_match"] = bool(np.allclose(r_sw.losses, r_un_gba.losses,
                                       rtol=0, atol=0))

# psum sync impl: every swap round-trips bit-exactly (verify_swap
# raises otherwise) and the end state matches the fused oracle to
# kernel tolerance (XLA psum vs sequential kernel sum: last-ulp)
drv2 = build("psum")
r2 = drv2.run_schedule(steps, MODES_SW)
out["psum_swaps_verified"] = r2.swaps_verified
out["psum_param_dev"] = float(
    np.max(np.abs(r2.param_flat - r_sw.param_flat)))
out["psum_accum_dev"] = float(
    np.max(np.abs(r2.accum_flat - r_sw.accum_flat)))
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def parity_results():
    out = subprocess.run(
        [sys.executable, "-c", _PARITY_SCRIPT], capture_output=True,
        text=True, env=dict(_ENV), cwd="/root/repo", timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_swap_bit_exact_vs_unswitched(parity_results):
    """Acceptance: forced sync->async->sync swaps on the fused state are
    bit-exact against BOTH unswitched replays of the same schedule —
    params, accum, and every per-step loss — including the decayed slot,
    the tombstone, and the non-tile-multiple leaves."""
    r = parity_results
    assert r["devices"] == 4
    assert r["fused_switches"] == 2
    assert r["fused_dropped"] == 1 and r["fused_tombstones"] == 1
    assert r["p_bitexact_vs_gba"] and r["a_bitexact_vs_gba"]
    assert r["p_bitexact_vs_sync"] and r["a_bitexact_vs_sync"]
    assert r["losses_match"]


@pytest.mark.slow
def test_psum_sync_impl_swaps_verified(parity_results):
    """The pytree-psum sync implementation: both swap directions
    round-trip bit-exactly (verified in-driver), and the final state
    agrees with the fused oracle to float32 kernel tolerance."""
    r = parity_results
    assert r["psum_swaps_verified"] == 2
    assert r["psum_param_dev"] < 1e-5
    assert r["psum_accum_dev"] < 1e-5


# ---------------------------------------------------------------------------
# slow: strained-cluster acceptance through the CLI (subprocess)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def strained_results():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.switch_driver",
         "--host-devices", "4", "--workers", "4", "--batches", "240",
         "--plan", "strained", "--mode", "auto", "--compare-sync",
         "--json"],
        capture_output=True, text=True, env=dict(_ENV), cwd="/root/repo",
        timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_strained_switches_within_window(strained_results):
    r = strained_results
    assert r["switch_count"] >= 1
    assert r["time_to_first_switch_steps"] <= 4     # first decision
    assert r["mode_timeline"][0][2] == "gba"
    assert r["swaps_verified"] >= 1


@pytest.mark.slow
def test_strained_speedup_at_least_2x(strained_results):
    assert strained_results["speedup_vs_sync"] >= 2.0


@pytest.mark.slow
def test_strained_no_deadlock_crash_and_rejoin(strained_results):
    """Both legs live through the transient crash: the async leg loses
    the in-flight token (Alg. 1) and sees the rejoin; the forced-sync
    leg discovers the dead worker by timeout (never hangs the barrier)
    and re-admits it after recovery.  A stalled run raises instead of
    returning, so completion itself is the no-deadlock claim."""
    r = strained_results
    assert r["deadlocked"] == 0
    assert r["crashes"] == 1 and r["rejoins"] == 1
    assert r["lost_batches"] == 1
    assert r["sync_timeouts"] >= 1 and r["sync_rejoins"] >= 1
    assert r["num_global_steps"] > 0 and r["final_loss"] is not None


# ---------------------------------------------------------------------------
# slow: chaos degradations (subprocess)
# ---------------------------------------------------------------------------

_CHAOS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
from repro.core.compression import CompressionPolicy
from repro.launch.switch_driver import (SwitchDriver, SwitchConfig,
                                        demo_model, demo_batch_fn)
from repro.sim.cluster import ClusterSpec
from repro.sim.faults import FaultPlan, ScrapeDropout, StragglerWindow

out = {}
mesh = jax.make_mesh((4,), ("data",))
params, loss_fn, group_by = demo_model()
spec = ClusterSpec(num_workers=4, jitter=0.05, seed=0)

# (a) circuit breaker: the first 3 async applies fail -> fallback to
# sync, run still drains every batch
plan = FaultPlan(4, apply_failures=(0, 1, 2))
cfg = SwitchConfig(local_batch=8, sync_impl="fused", breaker_threshold=3)
drv = SwitchDriver(mesh, loss_fn, params, spec=spec, plan=plan, cfg=cfg,
                   batch_fn=demo_batch_fn(8), group_by=group_by)
r = drv.run(48, mode="gba", seed=0)
out["breaker_trips"] = r.breaker_trips
out["breaker_apply_failures"] = r.apply_failures
out["breaker_end_mode_steps"] = r.mode_steps
out["breaker_finished_steps"] = r.num_global_steps
out["breaker_drained"] = r.drained

# (b) scrape dropout: telemetry blind the whole run -> the controller
# holds sync even on a straggling cluster
plan2 = FaultPlan(4, stragglers=(StragglerWindow(0, 4.0),),
                  dropouts=(ScrapeDropout(0.0, float("inf")),))
drv2 = SwitchDriver(mesh, loss_fn, params, spec=spec, plan=plan2,
                    cfg=SwitchConfig(local_batch=8, sync_impl="fused"),
                    batch_fn=demo_batch_fn(8), group_by=group_by)
r2 = drv2.run(48, mode="auto", seed=0)
out["dropout_switches"] = r2.switch_count
out["dropout_dropped_scrapes"] = r2.dropped_scrapes

# (c) compression warmup re-entry: two separate async entries each
# replay warmup_steps warm steps before the compressed program
pol = CompressionPolicy(scheme="int8", warmup_steps=2)
drv3 = SwitchDriver(mesh, loss_fn, params, spec=spec,
                    plan=FaultPlan.quiet(4),
                    cfg=SwitchConfig(local_batch=8, sync_impl="fused"),
                    batch_fn=demo_batch_fn(8), group_by=group_by,
                    compress=pol)
sched = lambda g: "sync" if g < 2 or 6 <= g < 8 else "gba"
r3 = drv3.run(48, mode_schedule=sched, seed=0)
out["warm_steps"] = r3.warm_steps
out["reentry_switches"] = r3.switch_count
out["reentry_mode_steps"] = r3.mode_steps
out["reentry_finite"] = bool(all(np.isfinite(l) for l in r3.losses))
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def chaos_results():
    out = subprocess.run(
        [sys.executable, "-c", _CHAOS_SCRIPT], capture_output=True,
        text=True, env=dict(_ENV), cwd="/root/repo", timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_breaker_falls_back_to_sync(chaos_results):
    r = chaos_results
    assert r["breaker_apply_failures"] == 3
    assert r["breaker_trips"] == 1
    # the 3 failed async rounds consume 12 batches (PS write dropped,
    # gradients lost); after the trip the surviving 36 run sync — and
    # the 4 in-flight tokens at the swap are drained + requeued
    assert r["breaker_end_mode_steps"].get("gba", 0) == 0
    assert r["breaker_end_mode_steps"]["sync"] == 9
    assert r["breaker_finished_steps"] == 9
    assert r["breaker_drained"] == 4


@pytest.mark.slow
def test_scrape_dropout_holds_mode(chaos_results):
    r = chaos_results
    assert r["dropout_switches"] == 0
    assert r["dropout_dropped_scrapes"] > 0


@pytest.mark.slow
def test_compression_warmup_reentered_per_async_entry(chaos_results):
    r = chaos_results
    assert r["reentry_switches"] == 3       # sync->gba->sync->gba
    assert r["warm_steps"] == 4             # 2 warm steps per entry
    assert r["reentry_finite"]