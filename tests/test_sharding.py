"""Sharding rule engine: spec trees match param trees, divisibility guards
degrade to replication, and reduced configs jit end-to-end on a tiny mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import GBAConfig, InputShape
from repro.distributed import sharding as S
from repro.launch.mesh import make_smoke_mesh
from repro.launch.steps import abstract_cache, abstract_params, build_step


def _mesh22():
    if jax.device_count() < 4:
        pytest.skip("needs >=4 devices (run under forced host devices)")
    return jax.make_mesh((2, 2), ("data", "model"))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_structure_and_rank(arch):
    cfg = get_config(arch)
    mesh = make_smoke_mesh()
    shapes = abstract_params(cfg)
    specs = S.param_specs(shapes, mesh)
    flat_s, tree_s = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_p, tree_p = jax.tree_util.tree_flatten(shapes)
    assert tree_s == tree_p
    for spec, leaf in zip(flat_s, flat_p):
        assert len(spec) <= leaf.ndim, (spec, leaf.shape)
        for d, ax in zip(leaf.shape, spec):
            if ax is not None:
                size = np.prod([mesh.shape[a] for a in
                                (ax if isinstance(ax, tuple) else (ax,))])
                assert d % size == 0, (arch, spec, leaf.shape)


def test_divisibility_guard_replicates():
    """starcoder2's 24 heads don't divide model=16: heads spec must fall
    back to head_dim (or None), never an invalid axis."""
    cfg = get_config("starcoder2-3b")
    mesh = jax.make_mesh((1, 16), ("data", "model")) \
        if jax.device_count() >= 16 else None
    if mesh is None:
        pytest.skip("needs 16 devices")
    shapes = abstract_params(cfg)
    specs = S.param_specs(shapes, mesh)
    wq = specs["blocks"]["l0"]["attn"]["wq"]
    assert wq[2] != "model" or cfg.resolved_head_dim % 16 == 0


def test_batch_partition_fallback():
    mesh = make_smoke_mesh()
    p = S.batch_partition(mesh, 4, 2)
    assert p[0] in ("data", ("data",))  # P normalizes 1-tuples
    p1 = S.batch_partition(mesh, 3, 2)  # indivisible under >1 devices is ok
    assert isinstance(p1, P)


@pytest.mark.parametrize("kind,shape", [
    ("train", InputShape("t", 64, 8, "train")),
    ("prefill", InputShape("p", 64, 4, "prefill")),
    ("decode", InputShape("d", 64, 8, "decode")),
])
@pytest.mark.parametrize("arch", ["granite-8b", "mamba2-780m",
                                  "phi3.5-moe-42b-a6.6b"])
def test_build_step_lowers_on_smoke_mesh(arch, kind, shape):
    cfg = get_config(arch).reduced()
    mesh = make_smoke_mesh()
    with mesh:
        fn, args = build_step(cfg, shape, mesh)
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    assert compiled.cost_analysis() is not None


def test_cache_specs_long_context_seq_sharding():
    """long_500k (batch=1): KV seq dim takes the data axis.  Uses an
    AbstractMesh so the production (16,16) geometry is testable on 1 CPU
    device (cache_specs only reads mesh.shape)."""
    import inspect
    from jax.sharding import AbstractMesh
    cfg = get_config("gemma2-27b")
    params = inspect.signature(AbstractMesh).parameters
    if "shape_tuple" in params:      # jax<=0.4.x: one ((name, size), ...) arg
        mesh = AbstractMesh((("data", 16), ("model", 16)))
    else:                            # jax>=0.5: (sizes, names)
        mesh = AbstractMesh((16, 16), ("data", "model"))
    cache = abstract_cache(cfg, 1, 1024)
    specs = S.cache_specs(cache, cfg, mesh, batch=1)
    k_spec = specs["blocks"]["l1"]["attn"]["k"]  # global layer
    assert k_spec[0] is None          # stacked repeats
    assert k_spec[1] is None          # batch=1 unshardable
    assert k_spec[2] == "data"        # sequence-parallel cache
