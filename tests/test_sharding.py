"""Sharding rule engine: spec trees match param trees, divisibility guards
degrade to replication, and reduced configs jit end-to-end on a tiny mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import GBAConfig, InputShape
from repro.distributed import sharding as S
from repro.launch.mesh import make_smoke_mesh
from repro.launch.steps import abstract_cache, abstract_params, build_step


def _mesh22():
    if jax.device_count() < 4:
        pytest.skip("needs >=4 devices (run under forced host devices)")
    return jax.make_mesh((2, 2), ("data", "model"))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_structure_and_rank(arch):
    cfg = get_config(arch)
    mesh = make_smoke_mesh()
    shapes = abstract_params(cfg)
    specs = S.param_specs(shapes, mesh)
    flat_s, tree_s = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_p, tree_p = jax.tree_util.tree_flatten(shapes)
    assert tree_s == tree_p
    for spec, leaf in zip(flat_s, flat_p):
        assert len(spec) <= leaf.ndim, (spec, leaf.shape)
        for d, ax in zip(leaf.shape, spec):
            if ax is not None:
                size = np.prod([mesh.shape[a] for a in
                                (ax if isinstance(ax, tuple) else (ax,))])
                assert d % size == 0, (arch, spec, leaf.shape)


def test_divisibility_guard_replicates():
    """starcoder2's 24 heads don't divide model=16: heads spec must fall
    back to head_dim (or None), never an invalid axis."""
    cfg = get_config("starcoder2-3b")
    mesh = jax.make_mesh((1, 16), ("data", "model")) \
        if jax.device_count() >= 16 else None
    if mesh is None:
        pytest.skip("needs 16 devices")
    shapes = abstract_params(cfg)
    specs = S.param_specs(shapes, mesh)
    wq = specs["blocks"]["l0"]["attn"]["wq"]
    assert wq[2] != "model" or cfg.resolved_head_dim % 16 == 0


def test_batch_partition_fallback():
    mesh = make_smoke_mesh()
    p = S.batch_partition(mesh, 4, 2)
    assert p[0] in ("data", ("data",))  # P normalizes 1-tuples
    p1 = S.batch_partition(mesh, 3, 2)  # indivisible under >1 devices is ok
    assert isinstance(p1, P)


@pytest.mark.parametrize("kind,shape", [
    ("train", InputShape("t", 64, 8, "train")),
    ("prefill", InputShape("p", 64, 4, "prefill")),
    ("decode", InputShape("d", 64, 8, "decode")),
])
@pytest.mark.parametrize("arch", ["granite-8b", "mamba2-780m",
                                  "phi3.5-moe-42b-a6.6b"])
def test_build_step_lowers_on_smoke_mesh(arch, kind, shape):
    cfg = get_config(arch).reduced()
    mesh = make_smoke_mesh()
    with mesh:
        fn, args = build_step(cfg, shape, mesh)
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    assert compiled.cost_analysis() is not None


# ---------------------------------------------------------------------------
# ShardedFlatLayout: leaf-/tile-aligned slice geometry + spec construction
# (host-side only — no multi-device mesh needed)
# ---------------------------------------------------------------------------

def _odd_params():
    """Deliberately non-tile-multiple leaf sizes."""
    k = jax.random.PRNGKey(0)
    return {"w": jax.random.normal(k, (33, 9)),          # 297
            "b": {"c": jnp.arange(41, dtype=jnp.float32),
                  "d": jax.random.normal(k, (700,))}}


@pytest.mark.parametrize("num_shards,tile", [(1, 256), (4, 256), (4, 128),
                                             (8, 256)])
def test_sharded_flat_layout_geometry(num_shards, tile):
    """Every leaf starts on a tile boundary, every shard slice is a whole
    number of tiles, and padded_total splits exactly across shards."""
    from repro.core.flat_sharded import ShardedFlatLayout
    params = _odd_params()
    layout = ShardedFlatLayout.from_params(params, num_shards, tile=tile)
    assert layout.total == sum(layout.sizes)
    assert layout.padded_total == num_shards * layout.shard_size
    assert layout.shard_size % tile == 0
    for off, size, padded in zip(layout.offsets, layout.sizes,
                                 layout.padded_sizes):
        assert off % tile == 0
        assert padded % tile == 0
        assert padded >= size
    for s in range(num_shards):
        lo, hi = layout.shard_bounds(s)
        assert lo % tile == 0 and hi % tile == 0
        assert hi - lo == layout.shard_size
    covered = sorted(j for s in range(num_shards)
                     for j in layout.leaves_in_shard(s))
    assert set(covered) == set(range(len(layout.sizes)))


def test_sharded_flat_layout_roundtrip_and_padding():
    """ravel zero-fills leaf/tail padding; unravel(ravel(x)) == x
    bitwise for non-tile-multiple leaves."""
    from repro.core.flat_sharded import ShardedFlatLayout
    params = _odd_params()
    layout = ShardedFlatLayout.from_params(params, 4, tile=256)
    flat = layout.ravel(params)
    assert flat.shape == (layout.padded_total,)
    for a, b in zip(jax.tree.leaves(layout.unravel(flat)),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # padding columns are exactly zero (so Adagrad on them is the identity)
    mask = np.ones(layout.padded_total, bool)
    for off, size in zip(layout.offsets, layout.sizes):
        mask[off:off + size] = False
    assert not np.any(np.asarray(flat)[mask])


def test_flat_slice_specs_and_validation():
    """Spec construction from the layout: flat vectors split over the PS
    axis, buffer columns likewise, scalars replicated; geometry mismatch
    fails loudly at spec-build time."""
    from repro.core.flat_sharded import ShardedFlatLayout
    mesh = make_smoke_mesh()     # (data=1, model=1)
    params = _odd_params()
    layout = ShardedFlatLayout.from_params(params, 1, tile=256)
    specs = S.flat_slice_specs(layout, mesh, "data")
    assert specs["flat"] == P("data")
    assert specs["buffer"]["grads"] == P(None, "data")
    assert specs["buffer"]["tokens"] == P()
    assert specs["buffer"]["fill"] == P()
    bad = ShardedFlatLayout.from_params(params, 4, tile=256)
    with pytest.raises(ValueError, match="shards"):
        S.flat_slice_specs(bad, mesh, "data")
    with pytest.raises(ValueError, match="axis"):
        S.flat_slice_specs(layout, mesh, "ps")


def test_fused_state_specs_tree():
    """fused_state_specs keeps per-leaf model rules for params and slices
    the flat accum/buffer."""
    from repro.core.flat_sharded import ShardedFlatLayout
    mesh = make_smoke_mesh()
    params = _odd_params()
    layout = ShardedFlatLayout.from_params(params, 1, tile=256)
    pshapes = jax.eval_shape(lambda t: t, params)
    pspecs = S.param_specs(pshapes, mesh)
    specs = S.fused_state_specs(layout, mesh, pspecs, "data")
    assert specs["accum"] == P("data")
    assert specs["buffer"]["grads"] == P(None, "data")
    flat_p, tree_p = jax.tree_util.tree_flatten(
        specs["params"], is_leaf=lambda x: isinstance(x, P))
    assert tree_p == jax.tree_util.tree_flatten(pshapes)[1]


def test_cache_specs_long_context_seq_sharding():
    """long_500k (batch=1): KV seq dim takes the data axis.  Uses an
    AbstractMesh so the production (16,16) geometry is testable on 1 CPU
    device (cache_specs only reads mesh.shape)."""
    import inspect
    from jax.sharding import AbstractMesh
    cfg = get_config("gemma2-27b")
    params = inspect.signature(AbstractMesh).parameters
    if "shape_tuple" in params:      # jax<=0.4.x: one ((name, size), ...) arg
        mesh = AbstractMesh((("data", 16), ("model", 16)))
    else:                            # jax>=0.5: (sizes, names)
        mesh = AbstractMesh((16, 16), ("data", "model"))
    cache = abstract_cache(cfg, 1, 1024)
    specs = S.cache_specs(cache, cfg, mesh, batch=1)
    k_spec = specs["blocks"]["l1"]["attn"]["k"]  # global layer
    assert k_spec[0] is None          # stacked repeats
    assert k_spec[1] is None          # batch=1 unshardable
    assert k_spec[2] == "data"        # sequence-parallel cache
