"""FaultPlan / FaultInjector: the deterministic fault scripts the
end-to-end switching harness (launch.switch_driver) replays."""
import numpy as np
import pytest

from repro.sim.cluster import ClusterSpec
from repro.sim.faults import (CrashEvent, FaultInjector, FaultPlan,
                              ScrapeDropout, StragglerWindow)


# ---------------------------------------------------------------------------
# plan construction / validation
# ---------------------------------------------------------------------------

def test_plan_validation():
    with pytest.raises(ValueError):
        StragglerWindow(worker=-1)
    with pytest.raises(ValueError):
        StragglerWindow(worker=0, slowdown=0.0)
    with pytest.raises(ValueError):
        StragglerWindow(worker=0, start=5.0, end=1.0)
    with pytest.raises(ValueError):
        CrashEvent(worker=0, at=1.0, recovery=-1.0)
    with pytest.raises(ValueError):
        ScrapeDropout(start=3.0, end=1.0)
    with pytest.raises(ValueError):
        FaultPlan(num_workers=0)
    with pytest.raises(ValueError):
        FaultPlan(2, stragglers=(StragglerWindow(worker=5),))
    with pytest.raises(ValueError):
        FaultPlan(2, crashes=(CrashEvent(worker=2, at=1.0),))


def test_plan_crashes_sorted_by_time():
    p = FaultPlan(4, crashes=(CrashEvent(1, 9.0), CrashEvent(0, 2.0),
                              CrashEvent(2, 5.0)))
    assert [c.at for c in p.crashes] == [2.0, 5.0, 9.0]


def test_slowdown_windows_compose():
    p = FaultPlan(4, stragglers=(
        StragglerWindow(1, 4.0),                    # whole run
        StragglerWindow(1, 2.0, start=10.0, end=20.0),
        StragglerWindow(2, 3.0, start=0.0, end=5.0)))
    assert p.slowdown(0, 1.0) == 1.0
    assert p.slowdown(1, 1.0) == 4.0
    assert p.slowdown(1, 15.0) == 8.0               # overlapping multiply
    assert p.slowdown(2, 4.9) == 3.0
    assert p.slowdown(2, 5.0) == 1.0                # end-exclusive
    assert p.straggler_workers() == (1, 2)


def test_scrape_dropout_window():
    p = FaultPlan(2, dropouts=(ScrapeDropout(1.0, 2.0),))
    assert not p.scrape_lost(0.5)
    assert p.scrape_lost(1.0)
    assert p.scrape_lost(1.99)
    assert not p.scrape_lost(2.0)


def test_strained_plan_deterministic_and_shaped():
    """The acceptance scenario: 25% stragglers at 4x + one transient
    crash of a HEALTHY worker."""
    a = FaultPlan.strained(8, seed=3)
    b = FaultPlan.strained(8, seed=3)
    assert a == b
    assert len(a.straggler_workers()) == 2          # 25% of 8
    assert all(w.slowdown == 4.0 for w in a.stragglers)
    assert len(a.crashes) == 1
    assert a.crashes[0].worker not in a.straggler_workers()
    assert a.crashes[0].at == 2.0 * a.crashes[0].recovery


def test_from_cluster_spec_matches_worker_speeds():
    """Stragglers come from the SAME rng stream as ``worker_speeds``, so
    the plan slows exactly the workers the sim slows."""
    spec = ClusterSpec(num_workers=8, straggler_frac=0.25,
                       straggler_slowdown=4.0, failure_rate=0.02,
                       recovery_time=3.0, seed=5)
    plan = FaultPlan.from_cluster_spec(spec, horizon=200.0)
    speeds = spec.worker_speeds(np.random.default_rng(spec.seed))
    slow = tuple(w for w in range(8) if speeds[w] < spec.base_speed)
    assert plan.straggler_workers() == slow
    assert all(c.recovery == 3.0 for c in plan.crashes)
    assert all(0 <= c.at < 200.0 for c in plan.crashes)
    # replayable: same spec -> identical plan
    assert plan == FaultPlan.from_cluster_spec(spec, horizon=200.0)


def test_from_cluster_spec_no_failure_rate_no_crashes():
    spec = ClusterSpec(num_workers=4, failure_rate=0.0, seed=1)
    assert FaultPlan.from_cluster_spec(spec, horizon=100.0).crashes == ()


# ---------------------------------------------------------------------------
# injector runtime
# ---------------------------------------------------------------------------

def _quiet_spec(n=4):
    return ClusterSpec(num_workers=n, base_speed=1000.0, jitter=0.0,
                       straggler_frac=0.0, seed=0)


def test_injector_worker_count_mismatch():
    with pytest.raises(ValueError):
        FaultInjector(FaultPlan.quiet(4), _quiet_spec(2))


def test_injector_duration_applies_slowdown():
    plan = FaultPlan(4, stragglers=(StragglerWindow(1, 4.0),))
    inj = FaultInjector(plan, _quiet_spec(), seed=0)
    base = inj.duration(0, 1.0, 100)
    assert base == pytest.approx(0.1)
    assert inj.duration(1, 1.0, 100) == pytest.approx(4 * base)


def test_injector_crash_fires_once_then_rejoins():
    plan = FaultPlan(4, crashes=(CrashEvent(2, at=5.0, recovery=3.0),))
    inj = FaultInjector(plan, _quiet_spec(), seed=0)
    assert inj.crash_between(2, 0.0, 4.0) is None
    assert inj.crash_between(1, 0.0, 10.0) is None  # other workers fine
    ev = inj.crash_between(2, 4.0, 6.0)
    assert ev is not None and ev.at == 5.0
    assert inj.lost_tokens == 1
    assert inj.is_down(2, 7.9) and not inj.is_down(2, 8.0)
    # a crash event fires exactly once
    assert inj.crash_between(2, 4.0, 6.0) is None
    assert inj.lost_tokens == 1


def test_injector_scrape_dropout_counted():
    plan = FaultPlan(2, dropouts=(ScrapeDropout(1.0, 2.0),))
    inj = FaultInjector(plan, _quiet_spec(2), seed=0)
    rates = [1.0, 2.0]
    assert inj.scrape(0.5, rates) == rates
    assert inj.scrape(1.5, rates) is None
    assert inj.dropped_scrapes == 1


def test_injector_apply_failures():
    plan = FaultPlan(2, apply_failures=(3, 4, 5))
    inj = FaultInjector(plan, _quiet_spec(2), seed=0)
    assert not inj.apply_fails(2)
    assert inj.apply_fails(3) and inj.apply_fails(5)


def test_injector_deterministic_across_instances():
    """Two injectors on the same (plan, spec, seed) draw identical
    durations — what makes the auto vs forced-sync legs comparable."""
    spec = ClusterSpec(num_workers=4, jitter=0.2, seed=0)
    plan = FaultPlan.strained(4)
    a = FaultInjector(plan, spec, seed=7)
    b = FaultInjector(plan, spec, seed=7)
    for w in range(4):
        for t in (0.0, 1.0, 2.5):
            assert a.duration(w, t, 64) == b.duration(w, t, 64)
