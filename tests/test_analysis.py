"""Static auditor: every rule trips on its seeded known-bad fixture
(exactly that rule, nothing else) and every shipped hot path audits
clean — so no rule is vacuous and no hot path regresses silently."""
from __future__ import annotations

import functools
import itertools

import jax
import jax.numpy as jnp
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.analysis import audit as AU
from repro.analysis import jaxpr_audit as JA
from repro.analysis import pallas_check as PC
from repro.analysis import retrace_guard as RG
from repro.analysis import rules as R
from repro.core.flat_sharded import ShardedFlatLayout
from repro.core.gba_shard_map import make_gba_psum_step
from repro.kernels.launch_meta import BlockMeta, LaunchMeta, ScratchMeta
from repro.optim import get_optimizer

SDS = jax.ShapeDtypeStruct
M = 2


def rules_of(findings):
    return sorted({f.rule for f in findings})


def tiny_layout(dtype=jnp.float32, m: int = M):
    params = {"emb": SDS((32,), dtype),
              "layers": {"w": SDS((16, 8), dtype)}}
    layout = ShardedFlatLayout.from_params(
        params, m, tile=8, group_by=lambda path: path[0])
    return params, layout


def fused_trace(dtype=jnp.float32, m: int = M):
    _, layout = tiny_layout(dtype, m)
    batch = {"x": SDS((m * 4,), jnp.float32)}
    return layout, AU.trace_fused_step(layout, m, AU.probe_loss, batch)


# ---------------------------------------------------------------------------
# rule registry + suppressions
# ---------------------------------------------------------------------------

def test_finding_requires_known_rule():
    with pytest.raises(KeyError):
        R.finding("GBA-NOPE-999", "s", "d")
    with pytest.raises(KeyError):
        R.parse_suppressions(["GBA-NOPE-999"])


def test_suppressions_global_and_per_site():
    f1 = R.finding("GBA-TILE-001", "a/k", "x")
    f2 = R.finding("GBA-TILE-001", "b/k", "x")
    f3 = R.finding("GBA-VMEM-002", "a/k", "x")
    sup = R.parse_suppressions(["GBA-TILE-001@a/k"])
    kept, dropped = R.apply_suppressions([f1, f2, f3], sup)
    assert kept == [f2, f3] and dropped == [f1]
    kept, dropped = R.apply_suppressions(
        [f1, f2, f3], R.parse_suppressions(["GBA-TILE-001"]))
    assert kept == [f3] and dropped == [f1, f2]


# ---------------------------------------------------------------------------
# collective census (GBA-COLL-*)
# ---------------------------------------------------------------------------

def test_fused_schedule_clean_and_census_shapes():
    layout, jx = fused_trace()
    assert JA.check_fused_psum_schedule(jx, layout, M, "t") == []
    census = JA.collective_census(jx)
    gathers = [c.in_shapes[0] for c in census if c.op == "all_gather"]
    exp, routes, token = JA.expected_fused_collectives(layout, M)
    assert gathers == exp + [token]
    assert [c.in_shapes[0] for c in census
            if c.op == "all_to_all"] == routes


def test_coll_001_trips_on_mismatched_layout():
    # audit the 2-group trace against a single-group layout: the declared
    # schedule (one gather/route per group, exact shapes) no longer matches
    _, jx = fused_trace()
    params = {"emb": SDS((32,), jnp.float32),
              "layers": {"w": SDS((16, 8), jnp.float32)}}
    other = ShardedFlatLayout.from_params(params, M, tile=8)
    fs = JA.check_fused_psum_schedule(jx, other, M, "t")
    assert rules_of(fs) == ["GBA-COLL-001"]


def test_coll_002_trips_on_vector_psum():
    mesh = AU.abstract_mesh(M)

    @functools.partial(shard_map, mesh=mesh, in_specs=(P("data"),),
                       out_specs=P(), check_rep=False)
    def bad(x):
        return lax.psum(x, "data")

    jx = jax.make_jaxpr(bad)(SDS((M * 4,), jnp.float32))
    assert rules_of(JA.check_scalar_psum_only(jx, "t")) == ["GBA-COLL-002"]


def test_coll_003_trips_on_any_collective():
    _, jx = fused_trace()
    assert rules_of(JA.check_no_collectives(jx, "t")) == ["GBA-COLL-003"]
    clean = jax.make_jaxpr(lambda x: x * 2)(SDS((4,), jnp.float32))
    assert JA.check_no_collectives(clean, "t") == []


def sync_trace():
    params, _ = tiny_layout()
    opt = get_optimizer("adagrad", 1e-3)
    step = make_gba_psum_step(AU.abstract_mesh(M), AU.probe_loss, opt, 4)
    return params, jax.make_jaxpr(step)(
        params, jax.eval_shape(opt.init, params),
        {"x": SDS((M * 4,), jnp.float32)},
        SDS((M,), jnp.int32), SDS((), jnp.int32))


def test_coll_004_sync_clean_and_trips_on_wrong_leaves():
    params, jx = sync_trace()
    leaf_shapes = [l.shape for l in jax.tree.leaves(params)]
    assert JA.check_sync_psum_schedule(jx, leaf_shapes, "t") == []
    fs = JA.check_sync_psum_schedule(jx, [(7, 7)], "t")
    assert rules_of(fs) == ["GBA-COLL-004"]
    # the fused trace is NOT a valid sync schedule (it gathers + routes)
    _, jfused = fused_trace()
    assert "GBA-COLL-004" in rules_of(
        JA.check_sync_psum_schedule(jfused, leaf_shapes, "t"))


def test_coll_005_clean_and_trips_on_f32_leak():
    """The compressed trace checks clean against its own policy; the
    UNCOMPRESSED (f32-wire) trace checked as past-warmup trips
    GBA-COLL-005 exactly — full-precision leakage after warmup is a
    finding, and the warm check accepts the same f32 trace."""
    from repro.core.compression import CompressionPolicy
    _, layout = tiny_layout()
    batch = {"x": SDS((M * 4,), jnp.float32)}
    pol = CompressionPolicy(scheme="int8", warmup_steps=1)
    jc = AU.trace_fused_step(layout, M, AU.probe_loss, batch,
                             compress=pol)
    assert JA.check_wire_dtypes(jc, layout, M, pol, "t") == []
    # known-bad: f32 routing where the policy says the wire is int8
    _, jleak = fused_trace()
    fs = JA.check_wire_dtypes(jleak, layout, M, pol, "t")
    assert rules_of(fs) == ["GBA-COLL-005"]
    # ... but the SAME f32 trace is exactly what warmup must look like
    assert JA.check_wire_dtypes(jleak, layout, M, pol, "t",
                                warm=True) == []


# ---------------------------------------------------------------------------
# dtype lints (GBA-DTYPE-*)
# ---------------------------------------------------------------------------

def test_dtype_001_budget_exact_on_probe_trace():
    layout, jx = fused_trace(jnp.bfloat16)
    budget = AU.widening_budget(layout)
    assert budget == 2 * len(layout.dtypes)     # every leaf is bf16
    assert JA.check_widening_budget(jx, budget, "t") == []
    # one sanctioned cast fewer -> the leaked upcast trips
    fs = JA.check_widening_budget(jx, budget - 1, "t")
    assert rules_of(fs) == ["GBA-DTYPE-001"]


def test_dtype_001_ignores_f32_layouts():
    layout, jx = fused_trace(jnp.float32)
    assert AU.widening_budget(layout) == 0
    assert JA.check_widening_budget(jx, 0, "t") == []


def test_dtype_002_trips_under_x64():
    with jax.experimental.enable_x64():
        jx = jax.make_jaxpr(
            lambda x: x.astype(jnp.float64) * 2.0)(SDS((8,), jnp.float32))
    assert rules_of(JA.check_no_f64(jx, "t")) == ["GBA-DTYPE-002"]
    clean = jax.make_jaxpr(lambda x: x * 2.0)(SDS((8,), jnp.float32))
    assert JA.check_no_f64(clean, "t") == []


# ---------------------------------------------------------------------------
# donation + retrace (GBA-DON-001 / GBA-RETRACE-001)
# ---------------------------------------------------------------------------

def _toy_step(state, x):
    return jax.tree.map(lambda s: s + jnp.sum(x), state), jnp.sum(x)


def test_don_001_trips_without_donate_argnums():
    state = {"p": jnp.zeros((8,)), "acc": jnp.zeros((8,))}
    x = SDS((4,), jnp.float32)
    bad = jax.jit(_toy_step).lower(state, x).args_info[0][0]
    assert rules_of(JA.check_donation(bad, "t")) == ["GBA-DON-001"]
    good = jax.jit(_toy_step, donate_argnums=0).lower(state, x)
    assert JA.check_donation(good.args_info[0][0], "t") == []


def test_retrace_001_trips_on_weak_type_alternation():
    # a python scalar traces weak-typed; alternating it with a strong
    # jnp scalar of the same shape/dtype is exactly the leak this guards
    vals = itertools.cycle([jnp.float32(1.0), 1.0])
    fs = RG.check_retrace(lambda x: x * 2, lambda: ((next(vals),), {}), "t")
    assert rules_of(fs) == ["GBA-RETRACE-001"]
    stable = RG.check_retrace(
        lambda x: x * 2, lambda: ((jnp.float32(1.0),), {}), "t")
    assert stable == []


# ---------------------------------------------------------------------------
# Pallas launch rules (GBA-TILE / GBA-VMEM / GBA-GRID)
# ---------------------------------------------------------------------------

def _fixture_meta(inputs, **kw):
    return LaunchMeta(kernel="fixture", grid=kw.pop("grid", (4,)),
                      inputs=inputs, outputs=(), **kw)


def test_tile_001_trips_on_misaligned_block():
    meta = _fixture_meta((
        BlockMeta("x", (64, 1024), jnp.float32, (8, 96),
                  lambda i: (0, i)),))
    assert rules_of(PC.check_launch(meta, "t")) == ["GBA-TILE-001"]


def test_tile_001_bf16_sublane():
    meta = _fixture_meta((
        BlockMeta("x", (64, 256), jnp.bfloat16, (8, 128),
                  lambda i: (0, 0)),))
    # 8 rows is a legal f32 sublane but NOT a legal bf16 one (min 16)
    assert rules_of(PC.check_tiles(meta, "t")) == ["GBA-TILE-001"]
    f32 = _fixture_meta((
        BlockMeta("x", (64, 256), jnp.float32, (8, 128),
                  lambda i: (0, 0)),))
    assert PC.check_tiles(f32, "t") == []


def test_tile_001_whole_axis_exempt():
    # block covers the full (padded) axis -> Mosaic pads internally, legal
    meta = _fixture_meta((
        BlockMeta("x", (4, 100), jnp.float32, (4, 100),
                  lambda i: (0, 0)),))
    assert PC.check_tiles(meta, "t") == []


def test_grid_001_trips_on_out_of_bounds_map():
    meta = _fixture_meta(
        (BlockMeta("x", (64, 1024), jnp.float32, (8, 128),
                   lambda i: (i, 8)),), grid=(8,))
    assert rules_of(PC.check_launch(meta, "t")) == ["GBA-GRID-001"]


def test_vmem_001_trips_on_declared_drift():
    meta = _fixture_meta(
        (BlockMeta("x", (64, 128), jnp.float32, (8, 128),
                   lambda i: (i, 0)),),
        declared_vmem_bytes=123, vmem_counted=("x",), grid=(8,))
    assert rules_of(PC.check_launch(meta, "t")) == ["GBA-VMEM-001"]


def test_vmem_002_trips_on_oversized_residency():
    meta = _fixture_meta((
        BlockMeta("x", (2048, 4096), jnp.float32),))   # 32MiB resident
    assert rules_of(PC.check_launch(meta, "t")) == ["GBA-VMEM-002"]


def test_vmem_counts_scratch():
    meta = _fixture_meta(
        (), scratch=(ScratchMeta("s", (2048, 4096), jnp.float32),))
    assert rules_of(PC.check_vmem(meta, "t")) == ["GBA-VMEM-002"]


# ---------------------------------------------------------------------------
# shipped hot paths audit clean
# ---------------------------------------------------------------------------

def test_shipped_kernels_audit_clean():
    rep = AU.audit_kernels()
    assert rep.ok, [str(f) for f in rep.findings]
    for meta in AU.kernel_metas():
        assert meta.total_vmem_bytes() <= PC.VMEM_BUDGET_BYTES


def test_granite_full_matrix_clean():
    rep = AU.audit_arch("granite-8b")
    assert rep.ok, [str(f) for f in rep.findings]
    # census columns the bench gates on exactly
    assert rep.stats["all_gather"] == rep.stats["num_groups"] + 1
    assert rep.stats["all_to_all"] == rep.stats["num_groups"]
    assert rep.stats["psum"] == 1
