"""Static auditor: every rule trips on its seeded known-bad fixture
(exactly that rule, nothing else) and every shipped hot path audits
clean — so no rule is vacuous and no hot path regresses silently."""
from __future__ import annotations

import functools
import itertools
import types
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.analysis import audit as AU
from repro.analysis import dataflow as DF
from repro.analysis import jaxpr_audit as JA
from repro.analysis import pallas_check as PC
from repro.analysis import race_lint as RL
from repro.analysis import retrace_guard as RG
from repro.analysis import rules as R
from repro.analysis.__main__ import (_parse_minimal_toml, load_baseline,
                                     unused_baseline_entries)
from repro.core.flat_sharded import ShardedFlatLayout
from repro.core.gba_shard_map import make_gba_psum_step
from repro.kernels.launch_meta import BlockMeta, LaunchMeta, ScratchMeta
from repro.optim import get_optimizer

SDS = jax.ShapeDtypeStruct
M = 2


def rules_of(findings):
    return sorted({f.rule for f in findings})


def tiny_layout(dtype=jnp.float32, m: int = M):
    params = {"emb": SDS((32,), dtype),
              "layers": {"w": SDS((16, 8), dtype)}}
    layout = ShardedFlatLayout.from_params(
        params, m, tile=8, group_by=lambda path: path[0])
    return params, layout


def fused_trace(dtype=jnp.float32, m: int = M):
    _, layout = tiny_layout(dtype, m)
    batch = {"x": SDS((m * 4,), jnp.float32)}
    return layout, AU.trace_fused_step(layout, m, AU.probe_loss, batch)


# ---------------------------------------------------------------------------
# rule registry + suppressions
# ---------------------------------------------------------------------------

def test_finding_requires_known_rule():
    with pytest.raises(KeyError):
        R.finding("GBA-NOPE-999", "s", "d")
    with pytest.raises(KeyError):
        R.parse_suppressions(["GBA-NOPE-999"])


def test_suppressions_global_and_per_site():
    f1 = R.finding("GBA-TILE-001", "a/k", "x")
    f2 = R.finding("GBA-TILE-001", "b/k", "x")
    f3 = R.finding("GBA-VMEM-002", "a/k", "x")
    sup = R.parse_suppressions(["GBA-TILE-001@a/k"])
    kept, dropped = R.apply_suppressions([f1, f2, f3], sup)
    assert kept == [f2, f3] and dropped == [f1]
    kept, dropped = R.apply_suppressions(
        [f1, f2, f3], R.parse_suppressions(["GBA-TILE-001"]))
    assert kept == [f3] and dropped == [f1, f2]


# ---------------------------------------------------------------------------
# collective census (GBA-COLL-*)
# ---------------------------------------------------------------------------

def test_fused_schedule_clean_and_census_shapes():
    layout, jx = fused_trace()
    assert JA.check_fused_psum_schedule(jx, layout, M, "t") == []
    census = JA.collective_census(jx)
    gathers = [c.in_shapes[0] for c in census if c.op == "all_gather"]
    exp, routes, token = JA.expected_fused_collectives(layout, M)
    assert gathers == exp + [token]
    assert [c.in_shapes[0] for c in census
            if c.op == "all_to_all"] == routes


def test_coll_001_trips_on_mismatched_layout():
    # audit the 2-group trace against a single-group layout: the declared
    # schedule (one gather/route per group, exact shapes) no longer matches
    _, jx = fused_trace()
    params = {"emb": SDS((32,), jnp.float32),
              "layers": {"w": SDS((16, 8), jnp.float32)}}
    other = ShardedFlatLayout.from_params(params, M, tile=8)
    fs = JA.check_fused_psum_schedule(jx, other, M, "t")
    assert rules_of(fs) == ["GBA-COLL-001"]


def test_coll_002_trips_on_vector_psum():
    mesh = AU.abstract_mesh(M)

    @functools.partial(shard_map, mesh=mesh, in_specs=(P("data"),),
                       out_specs=P(), check_rep=False)
    def bad(x):
        return lax.psum(x, "data")

    jx = jax.make_jaxpr(bad)(SDS((M * 4,), jnp.float32))
    assert rules_of(JA.check_scalar_psum_only(jx, "t")) == ["GBA-COLL-002"]


def test_coll_003_trips_on_any_collective():
    _, jx = fused_trace()
    assert rules_of(JA.check_no_collectives(jx, "t")) == ["GBA-COLL-003"]
    clean = jax.make_jaxpr(lambda x: x * 2)(SDS((4,), jnp.float32))
    assert JA.check_no_collectives(clean, "t") == []


def sync_trace():
    params, _ = tiny_layout()
    opt = get_optimizer("adagrad", 1e-3)
    step = make_gba_psum_step(AU.abstract_mesh(M), AU.probe_loss, opt, 4)
    return params, jax.make_jaxpr(step)(
        params, jax.eval_shape(opt.init, params),
        {"x": SDS((M * 4,), jnp.float32)},
        SDS((M,), jnp.int32), SDS((), jnp.int32))


def test_coll_004_sync_clean_and_trips_on_wrong_leaves():
    params, jx = sync_trace()
    leaf_shapes = [l.shape for l in jax.tree.leaves(params)]
    assert JA.check_sync_psum_schedule(jx, leaf_shapes, "t") == []
    fs = JA.check_sync_psum_schedule(jx, [(7, 7)], "t")
    assert rules_of(fs) == ["GBA-COLL-004"]
    # the fused trace is NOT a valid sync schedule (it gathers + routes)
    _, jfused = fused_trace()
    assert "GBA-COLL-004" in rules_of(
        JA.check_sync_psum_schedule(jfused, leaf_shapes, "t"))


def test_coll_005_clean_and_trips_on_f32_leak():
    """The compressed trace checks clean against its own policy; the
    UNCOMPRESSED (f32-wire) trace checked as past-warmup trips
    GBA-COLL-005 exactly — full-precision leakage after warmup is a
    finding, and the warm check accepts the same f32 trace."""
    from repro.core.compression import CompressionPolicy
    _, layout = tiny_layout()
    batch = {"x": SDS((M * 4,), jnp.float32)}
    pol = CompressionPolicy(scheme="int8", warmup_steps=1)
    jc = AU.trace_fused_step(layout, M, AU.probe_loss, batch,
                             compress=pol)
    assert JA.check_wire_dtypes(jc, layout, M, pol, "t") == []
    # known-bad: f32 routing where the policy says the wire is int8
    _, jleak = fused_trace()
    fs = JA.check_wire_dtypes(jleak, layout, M, pol, "t")
    assert rules_of(fs) == ["GBA-COLL-005"]
    # ... but the SAME f32 trace is exactly what warmup must look like
    assert JA.check_wire_dtypes(jleak, layout, M, pol, "t",
                                warm=True) == []


# ---------------------------------------------------------------------------
# dtype lints (GBA-DTYPE-*)
# ---------------------------------------------------------------------------

def test_dtype_001_budget_exact_on_probe_trace():
    layout, jx = fused_trace(jnp.bfloat16)
    budget = AU.widening_budget(layout)
    assert budget == 2 * len(layout.dtypes)     # every leaf is bf16
    assert JA.check_widening_budget(jx, budget, "t") == []
    # one sanctioned cast fewer -> the leaked upcast trips
    fs = JA.check_widening_budget(jx, budget - 1, "t")
    assert rules_of(fs) == ["GBA-DTYPE-001"]


def test_dtype_001_ignores_f32_layouts():
    layout, jx = fused_trace(jnp.float32)
    assert AU.widening_budget(layout) == 0
    assert JA.check_widening_budget(jx, 0, "t") == []


def test_dtype_002_trips_under_x64():
    with jax.experimental.enable_x64():
        jx = jax.make_jaxpr(
            lambda x: x.astype(jnp.float64) * 2.0)(SDS((8,), jnp.float32))
    assert rules_of(JA.check_no_f64(jx, "t")) == ["GBA-DTYPE-002"]
    clean = jax.make_jaxpr(lambda x: x * 2.0)(SDS((8,), jnp.float32))
    assert JA.check_no_f64(clean, "t") == []


# ---------------------------------------------------------------------------
# donation + retrace (GBA-DON-001 / GBA-RETRACE-001)
# ---------------------------------------------------------------------------

def _toy_step(state, x):
    return jax.tree.map(lambda s: s + jnp.sum(x), state), jnp.sum(x)


def test_don_001_trips_without_donate_argnums():
    state = {"p": jnp.zeros((8,)), "acc": jnp.zeros((8,))}
    x = SDS((4,), jnp.float32)
    bad = jax.jit(_toy_step).lower(state, x).args_info[0][0]
    assert rules_of(JA.check_donation(bad, "t")) == ["GBA-DON-001"]
    good = jax.jit(_toy_step, donate_argnums=0).lower(state, x)
    assert JA.check_donation(good.args_info[0][0], "t") == []


def test_retrace_001_trips_on_weak_type_alternation():
    # a python scalar traces weak-typed; alternating it with a strong
    # jnp scalar of the same shape/dtype is exactly the leak this guards
    vals = itertools.cycle([jnp.float32(1.0), 1.0])
    fs = RG.check_retrace(lambda x: x * 2, lambda: ((next(vals),), {}), "t")
    assert rules_of(fs) == ["GBA-RETRACE-001"]
    stable = RG.check_retrace(
        lambda x: x * 2, lambda: ((jnp.float32(1.0),), {}), "t")
    assert stable == []


# ---------------------------------------------------------------------------
# Pallas launch rules (GBA-TILE / GBA-VMEM / GBA-GRID)
# ---------------------------------------------------------------------------

def _fixture_meta(inputs, **kw):
    return LaunchMeta(kernel="fixture", grid=kw.pop("grid", (4,)),
                      inputs=inputs, outputs=(), **kw)


def test_tile_001_trips_on_misaligned_block():
    meta = _fixture_meta((
        BlockMeta("x", (64, 1024), jnp.float32, (8, 96),
                  lambda i: (0, i)),))
    assert rules_of(PC.check_launch(meta, "t")) == ["GBA-TILE-001"]


def test_tile_001_bf16_sublane():
    meta = _fixture_meta((
        BlockMeta("x", (64, 256), jnp.bfloat16, (8, 128),
                  lambda i: (0, 0)),))
    # 8 rows is a legal f32 sublane but NOT a legal bf16 one (min 16)
    assert rules_of(PC.check_tiles(meta, "t")) == ["GBA-TILE-001"]
    f32 = _fixture_meta((
        BlockMeta("x", (64, 256), jnp.float32, (8, 128),
                  lambda i: (0, 0)),))
    assert PC.check_tiles(f32, "t") == []


def test_tile_001_whole_axis_exempt():
    # block covers the full (padded) axis -> Mosaic pads internally, legal
    meta = _fixture_meta((
        BlockMeta("x", (4, 100), jnp.float32, (4, 100),
                  lambda i: (0, 0)),))
    assert PC.check_tiles(meta, "t") == []


def test_grid_001_trips_on_out_of_bounds_map():
    meta = _fixture_meta(
        (BlockMeta("x", (64, 1024), jnp.float32, (8, 128),
                   lambda i: (i, 8)),), grid=(8,))
    assert rules_of(PC.check_launch(meta, "t")) == ["GBA-GRID-001"]


def test_vmem_001_trips_on_declared_drift():
    meta = _fixture_meta(
        (BlockMeta("x", (64, 128), jnp.float32, (8, 128),
                   lambda i: (i, 0)),),
        declared_vmem_bytes=123, vmem_counted=("x",), grid=(8,))
    assert rules_of(PC.check_launch(meta, "t")) == ["GBA-VMEM-001"]


def test_vmem_002_trips_on_oversized_residency():
    meta = _fixture_meta((
        BlockMeta("x", (2048, 4096), jnp.float32),))   # 32MiB resident
    assert rules_of(PC.check_launch(meta, "t")) == ["GBA-VMEM-002"]


def test_vmem_counts_scratch():
    meta = _fixture_meta(
        (), scratch=(ScratchMeta("s", (2048, 4096), jnp.float32),))
    assert rules_of(PC.check_vmem(meta, "t")) == ["GBA-VMEM-002"]


# ---------------------------------------------------------------------------
# dataflow taint pass (GBA-FLOW-*)
# ---------------------------------------------------------------------------

IOTA = 4
GSTEP = 9
TOKENS = np.array([9, 8, 4, 0], dtype=np.int32)   # slots 2, 3 are stale
STALE = (GSTEP - TOKENS) > IOTA


def _flow_trace(step_fn, p_dtype=jnp.float32):
    return jax.make_jaxpr(step_fn)(
        SDS((8,), p_dtype), SDS((4, 8), jnp.float32),
        SDS((4,), jnp.int32), SDS((), jnp.int32))


def _flow_seeds(concrete=True):
    return [DF.taint(DF.PARAM), DF.taint(DF.RAW),
            DF.taint(DF.TOKEN, val=TOKENS if concrete else None),
            DF.taint(DF.STEP, val=np.int32(GSTEP) if concrete else None)]


def _decay_weight(tokens, step):
    return ((step - tokens) <= IOTA).astype(jnp.float32)


def test_flow_001_trips_on_decay_bypass():
    def bad(p, g, tokens, step):
        return p - 0.01 * jnp.mean(g, axis=0)       # no Eq. (1) weighting

    outs, _ = DF.analyze(_flow_trace(bad), _flow_seeds(), site="t")
    fs = DF.check_no_raw(outs, ["p"], lambda _: True, "t")
    assert rules_of(fs) == ["GBA-FLOW-001"]

    def good(p, g, tokens, step):
        w = _decay_weight(tokens, step)
        return p - 0.01 * jnp.sum(g * w[:, None], axis=0)

    outs, ctx = DF.analyze(_flow_trace(good), _flow_seeds(), site="t")
    assert DF.check_no_raw(outs, ["p"], lambda _: True, "t") == []
    # the concretely-evaluated mask proves the tombstone weights too
    assert DF.check_tombstone(ctx, STALE, "t") == []


def test_flow_002_trips_on_soft_tombstone_weight():
    def soft(p, g, tokens, step):
        # decays stale slots to 0.01 instead of dropping them: close
        # enough to fool a numeric diff, rejected by the exact-zero rule
        w = jnp.where((step - tokens) <= IOTA, 0.25, 0.01)
        return p - jnp.sum(g * w[:, None], axis=0)

    _, ctx = DF.analyze(_flow_trace(soft), _flow_seeds(), site="t")
    fs = DF.check_tombstone(ctx, STALE, "t")
    assert rules_of(fs) == ["GBA-FLOW-002"]
    assert "EXACTLY" in fs[0].detail
    # without concrete token seeds the mask is unprovable -> also a finding
    _, ctx = DF.analyze(_flow_trace(soft), _flow_seeds(concrete=False),
                        site="t")
    assert rules_of(DF.check_tombstone(ctx, STALE, "t")) == ["GBA-FLOW-002"]


def test_flow_003_trips_when_residual_reaches_apply():
    def bad(p, g, r, tokens, step):
        w = _decay_weight(tokens, step)
        upd = jnp.sum((g + r) * w[:, None], axis=0)   # residual in update
        return p - 0.01 * upd, r

    def good(p, g, r, tokens, step):
        w = _decay_weight(tokens, step)
        upd = jnp.sum(g * w[:, None], axis=0)
        return p - 0.01 * upd, r + upd    # residual -> next quantize only

    args = (SDS((8,), jnp.float32), SDS((4, 8), jnp.float32),
            SDS((4, 8), jnp.float32), SDS((4,), jnp.int32),
            SDS((), jnp.int32))
    seeds = [DF.taint(DF.PARAM), DF.taint(DF.RAW), DF.taint(DF.RESIDUAL),
             DF.taint(DF.TOKEN, val=TOKENS),
             DF.taint(DF.STEP, val=np.int32(GSTEP))]
    outs, _ = DF.analyze(jax.make_jaxpr(bad)(*args), seeds, site="t")
    fs = DF.check_no_residual(outs[:1], ["p"], lambda _: True, "t")
    assert rules_of(fs) == ["GBA-FLOW-003"]
    outs, _ = DF.analyze(jax.make_jaxpr(good)(*args), seeds, site="t")
    assert DF.check_no_residual(outs[:1], ["p"], lambda _: True, "t") == []


def test_flow_004_trips_on_narrow_update_chain():
    bf = jnp.bfloat16

    def bad_arith(p, g, tokens, step):
        w = _decay_weight(tokens, step)
        upd = jnp.sum(g * w[:, None], axis=0)
        return p - (0.01 * upd).astype(bf)            # bf16 subtract

    def bad_nonterminal(p, g, tokens, step):
        w = _decay_weight(tokens, step)
        upd = jnp.sum(g * w[:, None], axis=0)
        return (p.astype(jnp.float32) - 0.01 * upd).astype(bf) * 2

    def good(p, g, tokens, step):
        w = _decay_weight(tokens, step)
        upd = jnp.sum(g * w[:, None], axis=0)
        return (p.astype(jnp.float32) - 0.01 * upd).astype(bf)

    for fn in (bad_arith, bad_nonterminal):
        _, ctx = DF.analyze(_flow_trace(fn, bf), _flow_seeds(),
                            site="t", f32_chain=True)
        assert rules_of(ctx.findings) == ["GBA-FLOW-004"], fn.__name__
    _, ctx = DF.analyze(_flow_trace(good, bf), _flow_seeds(),
                        site="t", f32_chain=True)
    assert ctx.findings == []


def test_flow_005_trips_on_constant_divisor():
    def bad(ids, g, tokens, step):
        w = _decay_weight(tokens, step)
        return jnp.sum(g * w[:, None], axis=0) / 4.0   # mean over M, not
        #                                                over contributors

    def missing(ids, g, tokens, step):
        w = _decay_weight(tokens, step)
        return jnp.sum(g * w[:, None], axis=0)         # no mean at all

    def good(ids, g, tokens, step):
        valid = (ids >= 0).astype(jnp.float32)
        w = _decay_weight(tokens, step) * valid
        num = jnp.sum(g * w[:, None], axis=0)
        return num / jnp.maximum(jnp.sum(w), 1.0)

    args = (SDS((4,), jnp.int32), SDS((4, 8), jnp.float32),
            SDS((4,), jnp.int32), SDS((), jnp.int32))
    seeds = [DF.taint(DF.IDS), DF.taint(DF.RAW), DF.taint(DF.TOKEN),
             DF.taint(DF.STEP)]
    for fn in (bad, missing):
        _, ctx = DF.analyze(jax.make_jaxpr(fn)(*args), seeds, site="t")
        assert rules_of(DF.check_divisor(ctx, "t")) == ["GBA-FLOW-005"], \
            fn.__name__
    _, ctx = DF.analyze(jax.make_jaxpr(good)(*args), seeds, site="t")
    assert DF.check_divisor(ctx, "t") == []


def test_flow_seed_arity_mismatch_raises():
    with pytest.raises(ValueError):
        DF.analyze(_flow_trace(lambda p, g, t, s: p), _flow_seeds()[:2],
                   site="t")


# ---------------------------------------------------------------------------
# serving-thread race lint (GBA-RACE-*)
# ---------------------------------------------------------------------------

RACE_BAD1 = '''
import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.total = 0

    def locked_add(self, n):
        with self._lock:
            self.total += n

    def unlocked_add(self, n):
        self.total += n
'''

RACE_BAD2 = '''
import threading


class Versioned:
    def __init__(self):
        self._lock = threading.Lock()
        self.version = 0
        self.step = 0

    def bump(self):
        with self._lock:
            self.version = self.version + 1
            self.step = self.step + 2

    def view(self):
        return (self.version, self.step)
'''

RACE_BAD3 = '''
import threading


class Publisher:
    def __init__(self):
        self._lock = threading.Lock()
        self._listeners = []
        self.value = 0

    def subscribe(self, fn):
        with self._lock:
            self._listeners.append(fn)

    def _notify(self, v):
        for fn in list(self._listeners):
            fn(v)

    def publish(self, v):
        with self._lock:
            self.value = v
            self._notify(v)
'''

RACE_GOOD_SNAPSHOT = '''
import threading


class Source:
    def __init__(self):
        self._lock = threading.Lock()
        self._snap = (0, 0)

    def update(self, v, s):
        self._snap = (v, s)     # plain rebind of an immutable snapshot

    def view(self):
        snap = self._snap       # ONE unlocked read: consistent by design
        return snap
'''


def test_race_001_trips_on_unlocked_mutation():
    fs, _ = RL.lint_sources({"bad1": RACE_BAD1})
    assert rules_of(fs) == ["GBA-RACE-001"]
    assert "unlocked_add" in fs[0].site


def test_race_002_trips_on_torn_pair():
    fs, _ = RL.lint_sources({"bad2": RACE_BAD2})
    assert rules_of(fs) == ["GBA-RACE-002"]
    assert "view" in fs[0].site and "version" in fs[0].detail


def test_race_003_trips_on_callback_under_lock():
    fs, _ = RL.lint_sources({"bad3": RACE_BAD3})
    assert rules_of(fs) == ["GBA-RACE-003"]
    assert "publish" in fs[0].site


def test_race_snapshot_swap_is_blessed():
    fs, stats = RL.lint_sources({"good": RACE_GOOD_SNAPSHOT})
    assert fs == []
    assert stats["race_classes"] == 1


# ---------------------------------------------------------------------------
# audit baseline file (--baseline .gba-audit.toml)
# ---------------------------------------------------------------------------

def test_baseline_parse_roundtrip(tmp_path):
    text = "\n".join([
        "# comment",
        "[[suppress]]",
        'rule = "GBA-TILE-001"',
        'site = "a/k"   # trailing comment',
        'reason = "deliberate"',
        "[[suppress]]",
        'rule = "GBA-VMEM-002"',
        'reason = "fleet-wide"',
    ])
    p = tmp_path / "b.toml"
    p.write_text(text)
    assert load_baseline(p) == [("GBA-TILE-001", "a/k", "deliberate"),
                                ("GBA-VMEM-002", None, "fleet-wide")]
    # the 3.10 fallback parser agrees with tomllib on the format
    assert _parse_minimal_toml(text)["suppress"][0]["rule"] == "GBA-TILE-001"
    with pytest.raises(ValueError):
        _parse_minimal_toml("rule = unquoted")


def test_baseline_requires_rule_reason_and_file(tmp_path):
    p = tmp_path / "b.toml"
    p.write_text('[[suppress]]\nrule = "GBA-TILE-001"\n')
    with pytest.raises(SystemExit):
        load_baseline(p)                       # reason is mandatory
    p.write_text('[[suppress]]\nreason = "no rule"\n')
    with pytest.raises(SystemExit):
        load_baseline(p)                       # rule is mandatory
    with pytest.raises(SystemExit):
        load_baseline(tmp_path / "missing.toml")


def test_baseline_unused_entries_and_checked_in_file():
    rep = types.SimpleNamespace(
        suppressed=[R.finding("GBA-TILE-001", "a/k", "x")])
    entries = [("GBA-TILE-001", "a/k", "r"), ("GBA-TILE-001", "b/k", "r"),
               ("GBA-VMEM-002", None, "r")]
    assert unused_baseline_entries(entries, [rep]) == entries[1:]
    # the checked-in baseline parses and is (deliberately) empty
    repo_baseline = Path(__file__).resolve().parent.parent / ".gba-audit.toml"
    assert load_baseline(repo_baseline) == []


# ---------------------------------------------------------------------------
# shipped hot paths audit clean
# ---------------------------------------------------------------------------

def test_shipped_kernels_audit_clean():
    rep = AU.audit_kernels()
    assert rep.ok, [str(f) for f in rep.findings]
    for meta in AU.kernel_metas():
        assert meta.total_vmem_bytes() <= PC.VMEM_BUDGET_BYTES


def test_shipped_dataflow_audit_clean():
    rep = AU.audit_dataflow()
    assert rep.ok, [str(f) for f in rep.findings]


def test_shipped_serving_race_free():
    rep = AU.audit_serving()
    assert rep.ok, [str(f) for f in rep.findings]
    # the lint actually saw the serving thread machinery, not an empty set
    assert rep.stats["race_entries"] >= 1
    assert rep.stats["race_guarded_attrs"] >= 1
    assert rep.stats["race_locked_regions"] >= 1


def test_granite_full_matrix_clean():
    rep = AU.audit_arch("granite-8b")
    assert rep.ok, [str(f) for f in rep.findings]
    # census columns the bench gates on exactly
    assert rep.stats["all_gather"] == rep.stats["num_groups"] + 1
    assert rep.stats["all_to_all"] == rep.stats["num_groups"]
    assert rep.stats["psum"] == 1
