"""Per-architecture smoke tests (deliverable f): REDUCED variant of each
family runs one forward + one train step + one decode step on CPU; output
shapes asserted, no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import GBAConfig, InputShape
from repro.launch.steps import (init_train_state, make_train_step,
                                model_inputs)
from repro.models import transformer as T
from repro.optim import get_optimizer

B, S = 2, 32


def _memory_for(cfg, key):
    if cfg.family == "vlm":
        return jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_decode(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = T.init_model(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    memory = _memory_for(cfg, key)
    if cfg.family == "audio":
        frames = jax.random.normal(
            key, (B, cfg.encoder_frames, cfg.d_model), jnp.float32)
        memory = T.encode_audio(params, cfg, frames)
        assert not jnp.isnan(memory).any()
    logits, aux = T.forward(params, cfg, toks, memory=memory)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not jnp.isnan(logits).any()
    assert jnp.isfinite(aux)
    cache = T.init_cache(cfg, B, S + 4, memory=memory)
    lg, cache2 = T.decode_step(params, cfg, toks[:, :1], cache)
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert not jnp.isnan(lg).any()
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    """One GBA train step on the reduced config: loss finite, params move."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = T.init_model(key, cfg)
    opt = get_optimizer("adam", 1e-3)
    gba = GBAConfig(local_batch=B, buffer_size=1, staleness_tolerance=4)
    step_fn = jax.jit(make_train_step(cfg, opt, gba))
    state = init_train_state(params, opt)
    shape = InputShape("smoke", S, B, "train")
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_frames, cfg.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
    state2, loss = step_fn(state, batch, jnp.zeros((), jnp.int32))
    assert jnp.isfinite(loss), (arch, loss)
    # buffer_size=1 -> apply happened; embed must have moved
    moved = jnp.abs(state2["params"]["embed"] - params["embed"]).max()
    assert moved > 0
    assert int(state2["gstep"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_model_inputs_shapes(arch):
    cfg = get_config(arch)
    tr = model_inputs(cfg, InputShape("train_4k", 4096, 256, "train"))
    assert tr["tokens"].shape == (256, 4096)
    dec = model_inputs(cfg, InputShape("decode_32k", 32768, 128, "decode"))
    assert dec["tokens"].shape == (128, 1)
    assert "frames" not in dec and "image_embeds" not in dec
