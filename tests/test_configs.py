"""Config registry: all 10 assigned architectures resolve, patterns divide,
reduced variants obey the smoke-test contract."""
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, all_configs, get_config

EXPECTED = {
    "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168, num_heads=64,
                            num_kv_heads=8, d_ff=2048, vocab_size=163840,
                            num_experts=384, experts_per_token=8),
    "granite-8b": dict(num_layers=36, d_model=4096, num_heads=32,
                       num_kv_heads=8, d_ff=14336, vocab_size=49152),
    "zamba2-2.7b": dict(num_layers=54, d_model=2560, num_heads=32,
                        num_kv_heads=32, d_ff=10240, vocab_size=32000,
                        ssm_state=64),
    "gemma3-12b": dict(num_layers=48, d_model=3840, num_heads=16,
                       num_kv_heads=8, d_ff=15360, vocab_size=262144),
    "mamba2-780m": dict(num_layers=48, d_model=1536, d_ff=0,
                        vocab_size=50280, ssm_state=128),
    "starcoder2-3b": dict(num_layers=30, d_model=3072, num_heads=24,
                          num_kv_heads=2, d_ff=12288, vocab_size=49152),
    "phi3.5-moe-42b-a6.6b": dict(num_layers=32, d_model=4096, num_heads=32,
                                 num_kv_heads=8, d_ff=6400, vocab_size=32064,
                                 num_experts=16, experts_per_token=2),
    "seamless-m4t-medium": dict(num_layers=12, d_model=1024, num_heads=16,
                                num_kv_heads=16, d_ff=4096,
                                vocab_size=256206),
    "llama-3.2-vision-11b": dict(num_layers=40, d_model=4096, num_heads=32,
                                 num_kv_heads=8, d_ff=14336,
                                 vocab_size=128256),
    "gemma2-27b": dict(num_layers=46, d_model=4608, num_heads=32,
                       num_kv_heads=16, d_ff=36864, vocab_size=256000),
}


def test_all_archs_present():
    assert set(ARCH_IDS) == set(EXPECTED)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_exact_assigned_numbers(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_pattern_divides(arch):
    cfg = get_config(arch)
    assert cfg.num_repeats * len(cfg.block_pattern) \
        + len(cfg.prefix_layers) == cfg.num_layers


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_contract(arch):
    r = get_config(arch).reduced()
    assert r.num_layers <= len(r.block_pattern) + len(r.prefix_layers)
    assert r.d_model <= 512
    assert (r.num_experts or 0) <= 4
    assert r.num_repeats >= 1


def test_input_shapes():
    s = INPUT_SHAPES
    assert s["train_4k"].seq_len == 4096 and s["train_4k"].global_batch == 256
    assert s["prefill_32k"].seq_len == 32768
    assert s["prefill_32k"].global_batch == 32
    assert s["decode_32k"].seq_len == 32768
    assert s["decode_32k"].global_batch == 128
    assert s["long_500k"].seq_len == 524288
    assert s["long_500k"].global_batch == 1


def test_long_context_qualification():
    ok = {a for a in ARCH_IDS if get_config(a).supports_long_context}
    assert ok == {"mamba2-780m", "zamba2-2.7b", "gemma3-12b", "gemma2-27b",
                  "starcoder2-3b"}
