"""Perf-variant knobs must be numerically exact vs the baseline path
(chunked attention, block remat, chunked loss, mamba split projections)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models import transformer as T


def _grads(cfg, params, toks):
    return jax.grad(lambda p: T.lm_loss(p, cfg, toks, toks))(params)


@pytest.mark.parametrize("arch", ["granite-8b", "gemma2-27b"])
def test_chunked_remat_loss_exact(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    if cfg.sliding_window:
        cfg = dataclasses.replace(cfg, sliding_window=8)
    opt = dataclasses.replace(cfg, attn_q_chunk=8, remat_blocks=True,
                              loss_seq_chunk=8)
    params = T.init_model(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                              cfg.vocab_size)
    l1 = T.lm_loss(params, cfg, toks, toks)
    l2 = T.lm_loss(params, opt, toks, toks)
    assert abs(float(l1) - float(l2)) < 1e-5
    g1, g2 = _grads(cfg, params, toks), _grads(opt, params, toks)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_mamba_split_proj_exact():
    cfg = dataclasses.replace(get_config("mamba2-780m").reduced(),
                              dtype="float32")
    cfg_s = dataclasses.replace(cfg, mamba_split_proj=True)
    params = T.init_model(jax.random.PRNGKey(1), cfg)

    def split_from_fused(mix):
        d_inner, H, N = L._ssm_dims(cfg_s)
        W = mix["in_proj"]
        z, xw, Bw, Cw, dtw = jnp.split(
            W, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
            axis=1)
        cx, cB, cC = jnp.split(mix["conv_w"], [d_inner, d_inner + N], axis=1)
        out = {k: v for k, v in mix.items()
               if k not in ("in_proj", "conv_w")}
        return out | {"w_z": z, "w_x": xw, "w_B": Bw, "w_C": Cw,
                      "w_dt": dtw, "conv_x": cx, "conv_B": cB, "conv_C": cC}

    params_s = dict(params)
    params_s["blocks"] = {
        "l0": dict(params["blocks"]["l0"],
                   mixer=jax.vmap(split_from_fused)(
                       params["blocks"]["l0"]["mixer"]))}
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 20), 0,
                              cfg.vocab_size)
    f1, _ = T.forward(params, cfg, toks)
    f2, _ = T.forward(params_s, cfg_s, toks)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               rtol=1e-4, atol=1e-4)
    # decode path of the split variant matches its own forward
    cache = T.init_cache(cfg_s, 1, 20)
    outs = []
    for t in range(20):
        lg, cache = T.decode_step(params_s, cfg_s, toks[:, t:t + 1], cache)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(f2), rtol=1e-3, atol=2e-3)


def test_variants_registry_applies():
    from repro.launch.variants import VARIANTS
    cfg = get_config("mamba2-780m")
    for name, fn in VARIANTS.items():
        c2, opts = fn(cfg, {})
        assert c2.num_layers == cfg.num_layers
    c, _ = VARIANTS["mamba_split"](cfg, {})
    assert c.mamba_split_proj
    c, o = VARIANTS["serve_tp"](cfg, {})
    assert o.get("serve_tp")
    c, _ = VARIANTS["full_opt"](cfg, {})
    assert c.attn_q_chunk and c.remat_blocks and c.loss_seq_chunk
