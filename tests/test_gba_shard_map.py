"""shard_map GBA (explicit psum of decayed per-worker grads) must equal
the functional aggregate_dense reference, and the sharded fused flat
path (core.flat_sharded) must be bit-exact with the per-leaf chain and
the single-host flat path.  Everything runs in subprocesses with forced
host devices (device count locks at first jax init); the sharded-flat
cases share ONE 4-device subprocess via a module fixture so the suite
pays the jax import + compiles once."""
import json
import subprocess
import sys

import pytest


def _run_forced(script: str, timeout: int = 540) -> dict:
    # JAX_PLATFORMS=cpu matters: without it jax probes for accelerator
    # plugins and the probe timeouts dwarf the actual test (minutes vs
    # seconds).  The scripts force host-platform devices anyway.
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo", timeout=timeout)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import aggregate_dense
from repro.core.gba_shard_map import make_gba_psum_step
from repro.optim import sgd

mesh = jax.make_mesh((8,), ("data",))
M = 8
D = 16

def loss_fn(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2)

key = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(key, (D,))}
batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (32, D)),
         "y": jax.random.normal(jax.random.PRNGKey(2), (32,))}
tokens = jnp.array([5, 5, 4, 1, 5, 0, 5, 3], jnp.int32)  # workers' tokens
gstep = jnp.int32(5)
IOTA = 2

opt = sgd(0.1)
state = opt.init(params)
with mesh:
    step = make_gba_psum_step(mesh, loss_fn, opt, IOTA)
    batch_sharded = jax.device_put(batch, NamedSharding(mesh, P("data")))
    tokens_sharded = jax.device_put(tokens, NamedSharding(mesh, P("data")))
    new_params, _, loss = jax.jit(step)(params, state, batch_sharded,
                                        tokens_sharded, gstep)

# reference: per-worker grads aggregated with aggregate_dense
def worker_grads(params):
    gs = []
    for i in range(M):
        shard = {k: v[i * 4:(i + 1) * 4] for k, v in batch.items()}
        gs.append(jax.grad(loss_fn)(params, shard))
    return jax.tree.map(lambda *x: jnp.stack(x), *gs)

agg = aggregate_dense(worker_grads(params), tokens, gstep, iota=IOTA)
ref_params, _ = opt.update(params, agg, opt.init(params))
err = float(jnp.max(jnp.abs(new_params["w"] - ref_params["w"])))
print(json.dumps({"err": err, "devices": jax.device_count()}))
"""


@pytest.mark.slow
def test_shard_map_gba_matches_reference():
    """Marked slow: spawns a fresh 8-device jax process whose jit compile
    alone runs minutes on a loaded CPU container (scripts/ci.sh budget)."""
    res = _run_forced(_SCRIPT, timeout=300)
    assert res["devices"] == 8
    assert res["err"] < 1e-5, res


# ---------------------------------------------------------------------------
# sharded fused flat apply (core.flat_sharded): one subprocess, many checks
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import functools
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.flat_sharded import (ShardedFlatLayout,
                                     init_sharded_flat_buffer,
                                     per_leaf_kernel_apply,
                                     sharded_flat_push_and_maybe_apply)
from repro.core.gba import (init_flat_buffer,
                            flat_buffer_push_and_maybe_apply,
                            init_buffer, buffer_push_and_maybe_apply)
from repro.core.gba_shard_map import (make_gba_fused_psum_step,
                                      make_gba_psum_step)
from repro.distributed import sharding as S
from repro.optim import adagrad

out = {"devices": jax.device_count()}
mesh = jax.make_mesh((4,), ("data",))
key = jax.random.PRNGKey(7)
# non-tile-multiple leaf sizes on purpose: 297, 41, 700 against tile=256
params = {"w": jax.random.normal(key, (33, 9)),
          "b": {"c": jax.random.normal(jax.random.PRNGKey(8), (41,)),
                "d": jax.random.normal(jax.random.PRNGKey(9), (700,))}}
m, iota, lr = 4, 2, 0.05
tokens = [0, 4, 5, 5]
grads = [jax.tree.map(
    lambda p, i=i: jax.random.normal(jax.random.PRNGKey(100 + i), p.shape),
    params) for i in range(m)]

# --- sharded fused path: ONE jitted push/apply step, executed m times ------
layout, buf = init_sharded_flat_buffer(params, m, 4, tile=256)
out["shard_size"] = layout.shard_size
out["padded_total"] = layout.padded_total
specs = S.flat_slice_specs(layout, mesh, "data")
pf = jax.device_put(layout.ravel(params), NamedSharding(mesh, specs["flat"]))
af = jax.device_put(jnp.full((layout.padded_total,), 0.1, jnp.float32),
                    NamedSharding(mesh, specs["flat"]))
buf = jax.device_put(buf, jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs["buffer"],
    is_leaf=lambda s: isinstance(s, P)))

@jax.jit
def push(buf, g, tok, pf, af):
    return sharded_flat_push_and_maybe_apply(
        buf, g, tok, pf, af, lr, mesh=mesh, layout=layout, iota=iota)

p0 = layout.ravel(params)
noop_err, applied_flags = 0.0, []
with mesh:
    for i in range(m):
        pf, af, applied, buf = push(buf, layout.ravel(grads[i]),
                                    jnp.int32(tokens[i]), pf, af)
        applied_flags.append(bool(applied))
        if i < m - 1:  # partial buffer: params must pass through untouched
            noop_err = max(noop_err, float(jnp.max(jnp.abs(pf - p0))))
out["applied"] = applied_flags
out["noop_err"] = noop_err
sharded = jax.tree.leaves(layout.unravel(pf))

# --- single-host flat path on the same pushes ------------------------------
flayout, fbuf = init_flat_buffer(params, m)

@jax.jit
def push1(buf, g, tok, pf, af):
    return flat_buffer_push_and_maybe_apply(buf, g, tok, pf, af, lr,
                                            iota=iota)

pf1 = flayout.ravel(params)
af1 = jnp.full((flayout.total,), 0.1, jnp.float32)
for i in range(m):
    pf1, af1, _, fbuf = push1(fbuf, flayout.ravel(grads[i]),
                              jnp.int32(tokens[i]), pf1, af1)
out["err_flat"] = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                      zip(sharded, jax.tree.leaves(flayout.unravel(pf1))))

# --- per-leaf kernel chain: one gba_apply launch per leaf slice ------------
pl_p, _ = jax.jit(functools.partial(per_leaf_kernel_apply, layout,
                                    iota=iota))(
    layout.ravel(params),
    jnp.full((layout.padded_total,), 0.1, jnp.float32),
    jnp.stack([layout.ravel(g) for g in grads]),
    jnp.asarray(tokens, jnp.int32), jnp.int32(0), lr)
out["err_leaf_kernel"] = max(
    float(jnp.max(jnp.abs(a - b))) for a, b in
    zip(sharded, jax.tree.leaves(layout.unravel(pl_p))))

# --- per-leaf XLA chain (buffer_push_and_maybe_apply + adagrad) ------------
opt = adagrad(lr)

@jax.jit
def chain_push(pbuf, g, tok, params, ostate):
    def apply_fn(agg):
        return opt.update(params, agg, ostate)
    def noop_fn():
        return params, ostate
    return buffer_push_and_maybe_apply(pbuf, g, tok, iota, apply_fn,
                                       noop_fn)

cur_p, cur_o = params, opt.init(params)
pbuf = init_buffer(params, m)
for i in range(m):
    (cur_p, cur_o), pbuf = chain_push(pbuf, grads[i], jnp.int32(tokens[i]),
                                      cur_p, cur_o)
out["err_leaf_xla"] = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                          zip(sharded, jax.tree.leaves(cur_p)))

# --- fused psum step vs per-leaf psum step + adagrad -----------------------
D = 16
def loss_fn(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)

wparams = {"w": jax.random.normal(key, (D,)), "b": jnp.zeros(())}
batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (32, D)),
         "y": jax.random.normal(jax.random.PRNGKey(2), (32,))}
wtokens = jnp.array([5, 4, 1, 5], jnp.int32)
gstep = jnp.int32(5)
wlayout = ShardedFlatLayout.from_params(wparams, 4, tile=64)
with mesh:
    step = make_gba_fused_psum_step(mesh, loss_fn, wlayout, iota=iota,
                                    lr=0.1)
    wspecs = S.flat_slice_specs(wlayout, mesh, "data")
    wpf = jax.device_put(wlayout.ravel(wparams),
                         NamedSharding(mesh, wspecs["flat"]))
    waf = jax.device_put(
        jnp.full((wlayout.padded_total,), 0.1, jnp.float32),
        NamedSharding(mesh, wspecs["flat"]))
    bsh = jax.device_put(batch, NamedSharding(mesh, P("data")))
    tsh = jax.device_put(wtokens, NamedSharding(mesh, P("data")))
    new_pf, _, loss = jax.jit(step)(wpf, waf, bsh, tsh, gstep)
fused = jax.tree.leaves(wlayout.unravel(new_pf))

wopt = adagrad(0.1)  # same accum init (0.1) / eps as the fused kernel
with mesh:
    ref_step = make_gba_psum_step(mesh, loss_fn, wopt, iota)
    ref_params, _, ref_loss = jax.jit(ref_step)(
        wparams, wopt.init(wparams), bsh, tsh, gstep)
out["psum_err"] = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                      zip(fused, jax.tree.leaves(ref_params)))
out["psum_loss_err"] = abs(float(loss) - float(ref_loss))
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_results():
    return _run_forced(_SHARDED_SCRIPT)


def test_sharded_flat_apply_parity_4dev(sharded_results):
    """Tentpole acceptance: on a forced 4-device host mesh, the sharded
    fused apply (one gba_apply launch per PS shard) is bit-exact with
    the single-host flat path, with the per-leaf kernel chain (one
    launch per leaf), and with the per-leaf XLA aggregate+Adagrad chain
    — on non-tile-multiple leaf sizes.  The XLA-chain bound is kept at
    last-ulp tolerance because its reduction order is compiler-chosen."""
    res = sharded_results
    assert res["devices"] == 4
    assert res["padded_total"] == 4 * res["shard_size"]
    assert res["err_flat"] == 0.0, res         # bit-exact: same kernel math
    assert res["err_leaf_kernel"] == 0.0, res  # bit-exact: per-leaf launches
    assert res["err_leaf_xla"] < 1e-6, res


def test_sharded_flat_partial_buffer_noop(sharded_results):
    """The partial-buffer branch is a strict no-op: the first M-1 pushes
    leave params untouched bit-for-bit, the M-th applies."""
    res = sharded_results
    assert res["applied"] == [False, False, False, True]
    assert res["noop_err"] == 0.0, res


def test_fused_psum_step_matches_per_leaf_psum_step(sharded_results):
    """make_gba_fused_psum_step (all_gather params -> per-worker grads ->
    all_to_all into the (M, shard) buffer -> one gba_apply per shard)
    must match make_gba_psum_step + Adagrad; only the scalar loss is
    psum'd."""
    res = sharded_results
    assert res["psum_err"] < 1e-6, res
    assert res["psum_loss_err"] < 1e-6, res
