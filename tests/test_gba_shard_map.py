"""shard_map GBA (explicit psum of decayed per-worker grads) must equal
the functional aggregate_dense reference.  Runs in a subprocess with 8
forced host devices (device count locks at first jax init)."""
import json
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import aggregate_dense
from repro.core.gba_shard_map import make_gba_psum_step
from repro.optim import sgd

mesh = jax.make_mesh((8,), ("data",))
M = 8
D = 16

def loss_fn(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2)

key = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(key, (D,))}
batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (32, D)),
         "y": jax.random.normal(jax.random.PRNGKey(2), (32,))}
tokens = jnp.array([5, 5, 4, 1, 5, 0, 5, 3], jnp.int32)  # workers' tokens
gstep = jnp.int32(5)
IOTA = 2

opt = sgd(0.1)
state = opt.init(params)
with mesh:
    step = make_gba_psum_step(mesh, loss_fn, opt, IOTA)
    batch_sharded = jax.device_put(batch, NamedSharding(mesh, P("data")))
    tokens_sharded = jax.device_put(tokens, NamedSharding(mesh, P("data")))
    new_params, _, loss = jax.jit(step)(params, state, batch_sharded,
                                        tokens_sharded, gstep)

# reference: per-worker grads aggregated with aggregate_dense
def worker_grads(params):
    gs = []
    for i in range(M):
        shard = {k: v[i * 4:(i + 1) * 4] for k, v in batch.items()}
        gs.append(jax.grad(loss_fn)(params, shard))
    return jax.tree.map(lambda *x: jnp.stack(x), *gs)

agg = aggregate_dense(worker_grads(params), tokens, gstep, iota=IOTA)
ref_params, _ = opt.update(params, agg, opt.init(params))
err = float(jnp.max(jnp.abs(new_params["w"] - ref_params["w"])))
print(json.dumps({"err": err, "devices": jax.device_count()}))
"""


@pytest.mark.slow
def test_shard_map_gba_matches_reference():
    """Marked slow: spawns a fresh 8-device jax process whose jit compile
    alone runs minutes on a loaded CPU container (scripts/ci.sh budget)."""
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"}, cwd="/root/repo", timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["devices"] == 8
    assert res["err"] < 1e-5, res
