"""Layer-grouped ShardedFlatLayout + the layer-grouped fused psum step.

Host-side tests cover the grouped layout geometry (per-group contiguous,
shard-aligned extents; shard-major global ordering; per-group and global
ravel/unravel round trips) and the canonical model grouping
(``models.transformer.param_group_key``), including the acceptance bound:
for the granite-8b smoke layout the per-device peak gathered bytes of the
grouped schedule is the largest layer group, strictly below N_total.

The subprocess test is the tentpole acceptance: on a forced 4-device host
mesh, ``make_gba_fused_psum_step`` on a layer-grouped layout (per-group
``all_gather`` + per-group ``all_to_all``) is bit-exact with the same
step on a single-group layout — the PR-4 full-gather schedule — for
params, accum, AND loss over 3 global steps, with slots decayed to zero
by Eq. (1) and non-tile-multiple leaves.
"""
import functools
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flat_sharded import ShardedFlatLayout


def _grouped_params():
    """Deliberately non-tile-multiple leaves across three 'layers'."""
    k = jax.random.PRNGKey(0)
    return {"embed": jax.random.normal(k, (33, 9)),            # 297
            "blocks": {"l0": {"w": jnp.arange(41, dtype=jnp.float32),
                              "b": jax.random.normal(k, (7, 5))}},
            "head": jax.random.normal(k, (700,))}


def _first(names):
    return names[0]


@pytest.mark.parametrize("num_shards,tile", [(1, 256), (4, 256), (4, 128),
                                             (8, 256)])
def test_grouped_layout_geometry(num_shards, tile):
    """Every group's extent is a whole number of num_shards*tile chunks,
    groups tile the padded total, and every leaf lands in some shard."""
    layout = ShardedFlatLayout.from_params(_grouped_params(), num_shards,
                                           tile=tile, group_by=_first)
    assert layout.group_keys == ("blocks", "embed", "head")
    assert sum(layout.group_sizes) == layout.padded_total
    assert layout.shard_size == sum(layout.group_shard_sizes)
    for gs, gsn in zip(layout.group_sizes, layout.group_shard_sizes):
        assert gs % (num_shards * tile) == 0
        assert gsn == gs // num_shards
    for g in range(layout.num_groups):
        lo, hi = layout.group_shard_bounds(g)
        assert lo % tile == 0 and (hi - lo) == layout.group_shard_sizes[g]
    covered = sorted(j for s in range(num_shards)
                     for j in layout.leaves_in_shard(s))
    assert set(covered) == set(range(len(layout.sizes)))
    assert layout.peak_gather_bytes == max(layout.group_sizes) * 4
    if num_shards > 1 or tile == 128:
        assert layout.peak_gather_bytes < layout.full_gather_bytes


def test_grouped_roundtrip_and_group_ravel():
    """unravel(ravel(x)) == x bitwise on the shard-major grouped layout;
    per-group ravel/unravel round-trips each group independently, and the
    global flat is exactly the shard-major interleave of the groups."""
    params = _grouped_params()
    layout = ShardedFlatLayout.from_params(params, 4, tile=256,
                                           group_by=_first)
    flat = layout.ravel(params)
    assert flat.shape == (layout.padded_total,)
    for a, b in zip(jax.tree.leaves(layout.unravel(flat)),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    rows = np.asarray(flat).reshape(layout.num_shards, layout.shard_size)
    for g in range(layout.num_groups):
        gflat = layout.ravel_group(g, params)
        assert gflat.shape == (layout.group_sizes[g],)
        for a, b in zip(layout.unravel_group(g, gflat),
                        [jax.tree.leaves(params)[j]
                         for j in layout.group_leaves(g)]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        lo, hi = layout.group_shard_bounds(g)
        np.testing.assert_array_equal(rows[:, lo:hi].reshape(-1),
                                      np.asarray(gflat))


def test_single_group_layout_matches_pr4_ordering():
    """group_by=None must reproduce the ungrouped layout bit-for-bit:
    one group, global leaf offsets, plain concatenation order."""
    params = _grouped_params()
    layout = ShardedFlatLayout.from_params(params, 4, tile=256)
    assert layout.num_groups == 1
    flat = np.asarray(layout.ravel(params))
    for off, size, leaf in zip(layout.offsets, layout.sizes,
                               jax.tree.leaves(params)):
        np.testing.assert_array_equal(
            flat[off:off + size],
            np.asarray(leaf.reshape(-1).astype(jnp.float32)))


def test_per_leaf_kernel_apply_rejects_grouped_layouts():
    """Leaves are shard-major-interleaved under grouping — no leaf is one
    contiguous global run, so the per-leaf oracle must refuse."""
    from repro.core.flat_sharded import per_leaf_kernel_apply
    layout = ShardedFlatLayout.from_params(_grouped_params(), 4, tile=256,
                                           group_by=_first)
    with pytest.raises(ValueError, match="single-group"):
        per_leaf_kernel_apply(
            layout, jnp.zeros((layout.padded_total,)),
            jnp.zeros((layout.padded_total,)),
            jnp.zeros((4, layout.padded_total)),
            jnp.zeros((4,), jnp.int32), jnp.int32(0), 0.1, iota=2)


def test_param_group_key_canonical_mapping():
    from repro.models.transformer import param_group_key
    assert param_group_key(("embed",)) == "embed"
    assert param_group_key(("lm_head",)) == "head"
    assert param_group_key(("final_norm", "scale")) == "final_norm"
    assert param_group_key(("blocks", "l0", "attn", "wq")) == "blocks.l0"
    assert param_group_key(("blocks", "l1", "moe", "wo")) == "blocks.l1"
    assert param_group_key(("prefix", "#0", "mlp", "wo")) == "prefix.#0"
    assert param_group_key(("shared_attn", "attn", "wq")) == "shared_attn"
    assert param_group_key(("encoder", "attn", "wk")) == "encoder"


def test_granite8b_smoke_peak_gather_is_largest_group():
    """Acceptance bound: on the granite-8b smoke layout the grouped
    schedule's per-device peak gathered bytes equals the largest layer
    group and is strictly below N_total bytes (what the full-vector
    gather pins)."""
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("granite-8b").reduced()
    pshapes = jax.eval_shape(
        functools.partial(T.init_model, cfg=cfg), jax.random.PRNGKey(0))
    layout = ShardedFlatLayout.from_params(pshapes, 4,
                                           group_by=T.param_group_key)
    assert layout.num_groups >= 3
    assert layout.peak_gather_bytes == max(layout.group_sizes) * 4
    assert layout.peak_gather_bytes < layout.total * 4       # < N_total
    assert layout.peak_gather_bytes < layout.full_gather_bytes
    # the grouping covers every leaf exactly once
    assert sorted(j for g in range(layout.num_groups)
                  for j in layout.group_leaves(g)) \
        == list(range(len(layout.sizes)))


# ---------------------------------------------------------------------------
# tentpole acceptance: 4-device grouped vs full-gather parity (subprocess)
# ---------------------------------------------------------------------------

_GROUPED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.flat_sharded import ShardedFlatLayout
from repro.core.gba_shard_map import make_gba_fused_psum_step
from repro.distributed import sharding as S

out = {"devices": jax.device_count()}
mesh = jax.make_mesh((4,), ("data",))
key = jax.random.PRNGKey(7)
# non-tile-multiple leaves across three layer groups, tile=256
params = {"embed": jax.random.normal(key, (33, 9)),
          "blocks": {"l0": {"w": jax.random.normal(
                                jax.random.PRNGKey(8), (41,)),
                            "b": jax.random.normal(
                                jax.random.PRNGKey(9), (7, 5))}},
          "head": jax.random.normal(jax.random.PRNGKey(10), (700,))}
iota, lr = 2, 0.05

def loss_fn(p, batch):
    s = sum(jnp.sum(l.astype(jnp.float32) ** 2)
            for l in jax.tree.leaves(p))
    return jnp.mean(batch["x"]) * s

results = {}
for name, gb in (("grouped", lambda n: n[0]), ("full", None)):
    lay = ShardedFlatLayout.from_params(params, 4, tile=256, group_by=gb)
    specs = S.flat_slice_specs(lay, mesh, "data")
    pf = jax.device_put(lay.ravel(params),
                        NamedSharding(mesh, specs["flat"]))
    af = jax.device_put(jnp.full((lay.padded_total,), 0.1, jnp.float32),
                        NamedSharding(mesh, specs["flat"]))
    with mesh:
        step = make_gba_fused_psum_step(mesh, loss_fn, lay, iota=iota,
                                        lr=lr)
        if name == "grouped":
            # structural check via the static auditor's census: one
            # all_to_all and one param all_gather PER GROUP (+1 gather for
            # the tokens), exact shapes in group_table order
            from repro.analysis.jaxpr_audit import (
                census_counts, check_fused_psum_schedule, collective_census)
            x0 = jax.random.normal(jax.random.PRNGKey(50), (32,))
            jaxpr = jax.make_jaxpr(step)(
                lay.ravel(params),
                jnp.full((lay.padded_total,), 0.1, jnp.float32),
                {"x": x0}, jnp.zeros((4,), jnp.int32), jnp.int32(0))
            counts = census_counts(collective_census(jaxpr))
            out["n_groups"] = lay.num_groups
            out["n_all_to_all"] = counts.get("all_to_all", 0)
            out["n_all_gather"] = counts.get("all_gather", 0)
            out["schedule_findings"] = [
                str(f) for f in check_fused_psum_schedule(
                    jaxpr, lay, 4, "test/grouped")]
            out["peak_gather_bytes"] = lay.peak_gather_bytes
            out["full_gather_bytes"] = lay.full_gather_bytes
        jstep = jax.jit(step)
        losses = []
        for t in range(3):
            x = jax.random.normal(jax.random.PRNGKey(50 + t), (32,))
            bsh = jax.device_put({"x": x}, NamedSharding(mesh, P("data")))
            # worker 2's slot is 3 steps stale: Eq. (1) decays it to zero
            toks = jnp.array([t, t, t - 3, t], jnp.int32)
            tsh = jax.device_put(toks, NamedSharding(mesh, P("data")))
            pf, af, loss = jstep(pf, af, bsh, tsh, jnp.int32(t))
            losses.append(float(loss))
    results[name] = (lay.unravel(pf), lay.unravel(af), losses)

gp, ga, gl = results["grouped"]
fp, fa, fl = results["full"]
out["param_err"] = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                       zip(jax.tree.leaves(gp), jax.tree.leaves(fp)))
out["accum_err"] = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                       zip(jax.tree.leaves(ga), jax.tree.leaves(fa)))
out["loss_err"] = max(abs(a - b) for a, b in zip(gl, fl))
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def grouped_results():
    out = subprocess.run(
        [sys.executable, "-c", _GROUPED_SCRIPT], capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo", timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_layer_grouped_step_bit_exact_with_full_gather(grouped_results):
    """Tentpole acceptance: the layer-grouped step (per-group gathers,
    per-group gradient routing) is bit-exact with the PR-4 full-gather
    step — params, accum, AND loss, across 3 global steps that include a
    slot decayed to zero by Eq. (1), on non-tile-multiple leaves."""
    res = grouped_results
    assert res["devices"] == 4
    assert res["param_err"] == 0.0, res
    assert res["accum_err"] == 0.0, res
    assert res["loss_err"] == 0.0, res


def test_layer_grouped_step_collective_schedule(grouped_results):
    """The grouped step's program really is per-group: one all_to_all per
    layer group, one param all_gather per group plus the (M,) token
    gather — and its peak gathered bytes is strictly below the
    full-vector gather's.  Checked through the static auditor's census
    (GBA-COLL-001/002), not jaxpr string matching."""
    res = grouped_results
    assert res["n_groups"] == 3
    assert res["n_all_to_all"] == res["n_groups"]
    assert res["n_all_gather"] == res["n_groups"] + 1
    assert res["schedule_findings"] == [], res["schedule_findings"]
    assert res["peak_gather_bytes"] < res["full_gather_bytes"]
