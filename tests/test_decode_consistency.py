"""Decode-path correctness: step-by-step decode and prefill+decode must
match the full-sequence forward for every cache mechanism (full KV, ring
buffer, SSM state, shared attention, cross attention)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import transformer as T

ARCHS = ["starcoder2-3b", "mamba2-780m", "zamba2-2.7b", "gemma2-27b",
         "llama-3.2-vision-11b", "granite-8b"]

# the token-by-token variant of these archs costs 30-50s of jit compile
# each on this container; the prefill+decode variant below exercises the
# same cache mechanisms and stays in the default (<10 min) suite
_SLOW_DECODE = {"zamba2-2.7b", "gemma2-27b", "llama-3.2-vision-11b"}
_DECODE_PARAMS = [
    pytest.param(a, marks=[pytest.mark.slow] if a in _SLOW_DECODE else [])
    for a in ARCHS
]


def _setup(arch, window=8):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    if cfg.sliding_window:
        cfg = dataclasses.replace(cfg, sliding_window=window)
    params = T.init_model(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 0,
                              cfg.vocab_size)
    memory = None
    if cfg.family == "vlm":
        memory = jax.random.normal(
            jax.random.PRNGKey(3), (1, cfg.num_image_tokens, cfg.d_model),
            jnp.float32)
    return cfg, params, toks, memory


@pytest.mark.parametrize("arch", _DECODE_PARAMS)
def test_decode_matches_forward(arch):
    cfg, params, toks, memory = _setup(arch)
    full, _ = T.forward(params, cfg, toks, memory=memory)
    cache = T.init_cache(cfg, 1, 16, memory=memory)
    outs = []
    for t in range(16):
        lg, cache = T.decode_step(params, cfg, toks[:, t:t + 1], cache)
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - full)))
    assert err < 2e-3, (arch, err)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    cfg, params, toks, memory = _setup(arch)
    full, _ = T.forward(params, cfg, toks, memory=memory)
    lg_p, cache = T.prefill(params, cfg, toks[:, :12], memory=memory,
                            cache_len=16)
    err0 = float(jnp.max(jnp.abs(lg_p - full[:, 11])))
    assert err0 < 2e-3, (arch, err0)
    outs = []
    for t in range(12, 16):
        lg, cache = T.decode_step(params, cfg, toks[:, t:t + 1], cache)
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - full[:, 12:])))
    assert err < 2e-3, (arch, err)
