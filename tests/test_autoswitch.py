"""Adaptive switching controller (beyond-paper, paper §6 future work)."""
import numpy as np

from repro.core.autoswitch import AutoSwitchController
from repro.sim.cluster import ClusterSpec, simulate


def test_speedup_estimate_homogeneous():
    c = AutoSwitchController()
    # all workers equal: sync loses nothing -> speedup ~1
    assert abs(c.estimate_speedup(np.full(16, 100.0)) - 1.0) < 1e-9


def test_speedup_estimate_straggler():
    c = AutoSwitchController()
    rates = np.array([100.0] * 15 + [10.0])
    s = c.estimate_speedup(rates)
    # sync paced by the 10-sample/s worker: 16*10=160 vs sum=1510
    assert abs(s - 1510.0 / 160.0) < 1e-9


def test_empty_window_keeps_mode():
    """An empty telemetry window (all workers stalled / scrape raced the
    first completion) is no signal: estimate_speedup must not crash on
    min() of nothing, and decide keeps the current mode — in BOTH
    modes."""
    c = AutoSwitchController()
    assert np.isnan(c.estimate_speedup([]))
    assert c.decide([]) == "sync"
    c.decide(np.array([100.0] * 15 + [10.0]))   # genuine straggler -> gba
    assert c.mode == "gba"
    assert c.decide([]) == "gba"
    assert c.decide(np.array([])) == "gba"


def test_summary_empty_window_regression():
    """summary() is safe before any decision AND after an empty-window
    decision: last_speedup is NaN (not a crash on history[-1] of
    nothing), decisions counts every decide() call, and mode is
    whatever decide() kept."""
    c = AutoSwitchController()
    s0 = c.summary()
    assert s0["mode"] == "sync"
    assert np.isnan(s0["last_speedup"])
    assert s0["decisions"] == 0
    assert "bytes_on_wire" not in s0       # no wire map plumbed
    c.decide([])                           # empty window: no signal
    s1 = c.summary()
    assert s1["mode"] == "sync"            # kept, not flipped
    assert np.isnan(s1["last_speedup"])
    assert s1["decisions"] == 1


def test_summary_bytes_on_wire_plumbing():
    """wire_bytes_per_step is telemetry plumbing only: summary() exposes
    the current mode's bytes_on_wire and the full map, and the switching
    decisions are identical with or without it."""
    wire = {"sync": 4.0 * (1 << 20), "gba": 0.251 * 4.0 * (1 << 20)}
    c = AutoSwitchController(wire_bytes_per_step=wire)
    plain = AutoSwitchController()
    assert c.summary()["bytes_on_wire"] == wire["sync"]
    rates = np.array([100.0] * 15 + [10.0])
    assert c.decide(rates) == plain.decide(rates) == "gba"
    s = c.summary()
    assert s["bytes_on_wire"] == wire["gba"]
    assert s["wire_bytes_per_step"] == wire
    assert s["decisions"] == 1 and s["last_speedup"] > 1.5
    assert "bytes_on_wire" not in plain.summary()


def test_history_stays_bounded():
    """history must not grow without bound on long runs: capped at
    max_history, keeping the most recent entries."""
    c = AutoSwitchController(max_history=16)
    for i in range(100):
        c.decide(np.full(4, 100.0 + i))
    assert len(c.history) == 16
    # most recent decision retained, oldest dropped
    assert c.history[-1][1] == c.mode
    speedups = [s for s, _ in c.history]
    assert all(abs(s - 1.0) < 1e-9 for s in speedups)


def test_hysteresis():
    c = AutoSwitchController(switch_up=1.5, switch_down=1.15)
    assert c.mode == "sync"
    assert c.decide(np.array([100.0] * 15 + [20.0])) == "gba"   # 5.2x
    # mild heterogeneity (1.25x) sits inside the hysteresis band
    assert c.decide(np.array([100.0] * 15 + [80.0])) == "gba"
    assert c.decide(np.full(16, 100.0)) == "sync"               # 1.0x


def test_controller_tracks_cluster_state():
    vac = ClusterSpec(num_workers=8, straggler_frac=0.0, jitter=0.05,
                      ps_throughput=100.0, seed=1)
    strained = ClusterSpec(num_workers=8, straggler_frac=0.5,
                           straggler_slowdown=10.0, jitter=0.1,
                           ps_throughput=100.0, seed=1)
    c = AutoSwitchController()
    r_vac = simulate(vac, "sync", 64, 128).metrics.worker_rates
    assert c.decide(r_vac) == "sync"
    r_str = simulate(strained, "sync", 64, 128).metrics.worker_rates
    assert c.decide(r_str) == "gba"
    r_vac2 = simulate(vac, "gba", 64, 128, buffer_size=8,
                      iota=4).metrics.worker_rates
    assert c.decide(r_vac2) == "sync"


def test_ps_throughput_cap_crossover():
    """Fig. 1: finite PS -> sync wins vacant, GBA wins strained."""
    vac = ClusterSpec(num_workers=16, straggler_frac=0.0, jitter=0.05,
                      ps_throughput=100.0, seed=3)
    strained = ClusterSpec(num_workers=16, straggler_frac=0.25,
                           straggler_slowdown=10.0, jitter=0.2,
                           time_varying=True, ps_throughput=100.0, seed=3)
    q = {}
    for name, spec in [("vac", vac), ("str", strained)]:
        for mode in ("sync", "gba"):
            q[(name, mode)] = simulate(spec, mode, 480, 256, buffer_size=16,
                                       iota=4).metrics.qps
    assert q[("vac", "sync")] > q[("vac", "gba")]
    assert q[("str", "gba")] > 2.0 * q[("str", "sync")]


def test_dead_worker_excluded_from_sync_min():
    """A rate of exactly zero is a crashed/stalled worker, not an
    infinitely slow one: it leaves the sync min() (a barrier would
    exclude it, not wait forever) and is reported in summary()."""
    c = AutoSwitchController()
    s = c.estimate_speedup([1.0, 1.0, 1.0, 0.0])
    assert np.isfinite(s) and abs(s - 1.0) < 1e-9
    assert c.dead_workers == 1
    assert c.summary()["dead_workers"] == 1


def test_zero_rate_no_longer_pins_gba():
    """Regression: a single zero rate used to return inf, instantly
    forcing mode='gba' and pinning it there."""
    c = AutoSwitchController()
    assert np.isfinite(c.estimate_speedup([100.0, 100.0, 0.0]))
    assert c.decide([1.0, 1.0, 1.0, 0.0]) == "sync"     # speedup 1.0
    # and a dead worker on an otherwise-straggling cluster still
    # produces the REAL heterogeneity estimate, not inf
    c2 = AutoSwitchController()
    s = c2.estimate_speedup([100.0, 100.0, 10.0, 0.0])
    assert abs(s - 210.0 / 30.0) < 1e-9


def test_all_dead_window_holds_mode():
    c = AutoSwitchController()
    assert np.isnan(c.estimate_speedup([0.0, 0.0]))
    assert c.decide([0.0, 0.0]) == "sync"
    assert c.dead_workers == 2


def test_min_dwell_blocks_flapping():
    """min_dwell decisions must pass after any switch before the next
    one — one noisy window can no longer flap modes."""
    c = AutoSwitchController(min_dwell=2)
    # a fresh controller can still move on its very first decision
    assert c.decide([10.0, 1.0, 1.0, 1.0]) == "gba"
    # homogeneous windows want sync, but the dwell holds gba...
    assert c.decide([1.0, 1.0, 1.0, 1.0]) == "gba"
    assert c.decide([1.0, 1.0, 1.0, 1.0]) == "gba"
    # ...until min_dwell decisions have passed
    assert c.decide([1.0, 1.0, 1.0, 1.0]) == "sync"


def test_min_dwell_zero_keeps_old_behavior():
    c = AutoSwitchController()         # default min_dwell=0
    assert c.decide([10.0, 1.0, 1.0, 1.0]) == "gba"
    assert c.decide([1.0, 1.0, 1.0, 1.0]) == "sync"    # immediate flip


def test_force_resets_dwell():
    """force() (the driver's circuit breaker) restarts the dwell window
    so the next min_dwell decisions cannot immediately flip back."""
    import pytest
    c = AutoSwitchController(min_dwell=2, mode="gba")
    assert c.force("sync") == "sync"
    assert c.decide([10.0, 1.0, 1.0, 1.0]) == "sync"   # held
    assert c.decide([10.0, 1.0, 1.0, 1.0]) == "sync"   # held
    assert c.decide([10.0, 1.0, 1.0, 1.0]) == "gba"    # dwell expired
    with pytest.raises(ValueError):
        c.force("warp")
