"""DMA-streamed embedding kernels: parity at V >> BLOCK_V, block-boundary
edge cases, bit-exactness vs the PR-1 VMEM-resident backward, the
differentiable table-level wrapper, and the interpret-mode resolution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.embeddings import table as embeddings
from repro.kernels import ref
from repro.kernels import runtime
from repro.kernels.embedding_bag import (BLOCK_D, BLOCK_V, CHUNK_E,
                                         embedding_bag, embedding_bag_grad,
                                         embedding_bag_grad_resident,
                                         stream_vmem_bytes)


# ---------------------------------------------------------------------------
# forward parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,f,v,d", [
    (10, 5, 50, 8),              # V smaller than one block
    (100, 26, 1000, 16),
    (33, 3, 101, 7),             # nothing block-multiple
    (64, 26, 100_003, 16),       # V >> BLOCK_V, ~200 streamed tiles
])
def test_streamed_fwd_parity(b, f, v, d):
    key = jax.random.PRNGKey(b)
    ids = jax.random.randint(key, (b, f), 0, v)
    table = jax.random.normal(key, (v, d), jnp.float32)
    out = embedding_bag(ids, table)
    exp = ref.embedding_bag_ref(ids, table)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


def test_streamed_fwd_parity_1m_vocab():
    """Production-scale vocabulary: ~2000 vocab blocks, none VMEM-resident.
    The footprint bound of the acceptance criterion is checked explicitly."""
    b, f, v, d = 16, 8, 1_000_000, 16
    key = jax.random.PRNGKey(0)
    ids = jax.random.randint(key, (b, f), 0, v)
    table = jax.random.normal(key, (v, d), jnp.float32)
    out = embedding_bag(ids, table)
    exp = ref.embedding_bag_ref(ids, table)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)
    vm = stream_vmem_bytes(d)
    bd = vm["block_d"]
    bound = 2 * (BLOCK_V * bd + CHUNK_E * bd) * 4
    assert vm["fwd"] <= bound and vm["bwd"] <= bound
    assert vm["fwd"] < v * d * 4 / 100     # table itself is >100x larger


def test_streamed_fwd_wide_d_tiling():
    """D > BLOCK_D: the output grid's D axis streams per-tile columns."""
    b, f, v, d = 24, 4, 700, 2 * BLOCK_D + 40
    key = jax.random.PRNGKey(3)
    ids = jax.random.randint(key, (b, f), 0, v)
    table = jax.random.normal(key, (v, d), jnp.float32)
    out = embedding_bag(ids, table)
    exp = ref.embedding_bag_ref(ids, table)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


def test_streamed_fwd_bf16_table():
    b, f, v, d = 40, 6, 3000, 16
    key = jax.random.PRNGKey(9)
    ids = jax.random.randint(key, (b, f), 0, v)
    table = jax.random.normal(key, (v, d), jnp.bfloat16)
    out = embedding_bag(ids, table)
    exp = ref.embedding_bag_ref(ids, table)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_streamed_fwd_all_ids_one_block():
    """Every id lands in one vocab block: a single tile is streamed and
    revisited across all entry chunks."""
    b, f, v = 64, 8, 9000
    key = jax.random.PRNGKey(4)
    ids = jax.random.randint(key, (b, f), 100, 500)    # one BLOCK_V block
    table = jax.random.normal(key, (v, 16), jnp.float32)
    out = embedding_bag(ids, table)
    exp = ref.embedding_bag_ref(ids, table)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


def test_streamed_fwd_sentinel_padding():
    """Out-of-range ids (the padded-batch sentinel) contribute nothing —
    in particular they no longer gather row 0."""
    v, d = 64, 8
    table = jax.random.normal(jax.random.PRNGKey(1), (v, d), jnp.float32)
    ids = jnp.array([[3, -1], [5, v], [7, 2 * v]], jnp.int32)
    out = embedding_bag(ids, table)
    exp = jnp.stack([table[3], table[5], table[7]])
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)
    # an all-sentinel batch issues zero gathers and returns zeros
    out0 = embedding_bag(jnp.full((4, 3), v, jnp.int32), table)
    assert float(jnp.abs(out0).max()) == 0.0


def test_streamed_fwd_custom_knobs():
    b, f, v, d = 48, 5, 5000, 24
    key = jax.random.PRNGKey(6)
    ids = jax.random.randint(key, (b, f), 0, v)
    table = jax.random.normal(key, (v, d), jnp.float32)
    out = embedding_bag(ids, table, block_v=128, block_d=8, chunk_e=64)
    exp = ref.embedding_bag_ref(ids, table)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# backward parity + resident-kernel regression
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,f,v,d", [(10, 5, 50, 8), (64, 26, 500, 16),
                                     (33, 3, 613, 7)])
def test_streamed_grad_bit_identical_to_resident(b, f, v, d):
    """The streamed backward must reproduce the PR-1 VMEM-resident kernel
    bit-for-bit on the old (VMEM-sized) configs: same chunking, same
    one-hot matmul accumulation order, only the row transport differs."""
    key = jax.random.PRNGKey(b + 7)
    ids = jax.random.randint(key, (b, f), 0, v)
    gout = jax.random.normal(key, (b, d), jnp.float32)
    gt, cnt = embedding_bag_grad(ids, gout, v)
    gtr, cntr = embedding_bag_grad_resident(ids, gout, v)
    assert np.array_equal(np.asarray(gt), np.asarray(gtr))
    assert np.array_equal(np.asarray(cnt), np.asarray(cntr))


def test_streamed_grad_parity_1m_vocab():
    b, f, v, d = 16, 8, 1_000_000, 16
    key = jax.random.PRNGKey(2)
    ids = jax.random.randint(key, (b, f), 0, v)
    gout = jax.random.normal(key, (b, d), jnp.float32)
    gt, cnt = embedding_bag_grad(ids, gout, v)
    gt2, cnt2 = ref.embedding_bag_grad_ref(ids, gout, v)
    np.testing.assert_allclose(np.asarray(gt), np.asarray(gt2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cnt), np.asarray(cnt2))


def test_streamed_grad_wide_d_tiling():
    b, f, v, d = 12, 3, 300, 2 * BLOCK_D + 4
    key = jax.random.PRNGKey(8)
    ids = jax.random.randint(key, (b, f), 0, v)
    gout = jax.random.normal(key, (b, d), jnp.float32)
    gt, cnt = embedding_bag_grad(ids, gout, v)
    gt2, cnt2 = ref.embedding_bag_grad_ref(ids, gout, v)
    np.testing.assert_allclose(np.asarray(gt), np.asarray(gt2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cnt), np.asarray(cnt2))


def test_streamed_grad_bf16_rows_custom_chunks():
    b, f, v, d = 24, 6, 300, 16
    key = jax.random.PRNGKey(5)
    ids = jax.random.randint(key, (b, f), 0, v)
    gout = jax.random.normal(key, (b, d), jnp.bfloat16)
    gt, cnt = embedding_bag_grad(ids, gout, v, block_v=64, chunk_e=32)
    gt2, cnt2 = ref.embedding_bag_grad_ref(ids, gout, v)
    np.testing.assert_allclose(np.asarray(gt, np.float32),
                               np.asarray(gt2, np.float32),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(cnt), np.asarray(cnt2))


# ---------------------------------------------------------------------------
# table-level differentiable wrapper + presence counts
# ---------------------------------------------------------------------------

def test_pooled_lookup_vjp_matches_autodiff():
    """pooled_lookup's custom VJP (streamed backward) == jax.grad of the
    pure-jnp sum-pool."""
    b, f, v, d = 20, 4, 600, 8
    key = jax.random.PRNGKey(11)
    ids = jax.random.randint(key, (b, f), 0, v)
    tbl = embeddings.init_table(key, v, d)
    target = jax.random.normal(key, (b, d), jnp.float32)

    def loss_kernel(t):
        out = embeddings.pooled_lookup(
            embeddings.EmbeddingTable(t, tbl.last_update), ids)
        return jnp.sum((out - target) ** 2)

    def loss_ref(t):
        return jnp.sum((ref.embedding_bag_ref(ids, t) - target) ** 2)

    g_kernel = jax.grad(loss_kernel)(tbl.table)
    g_ref = jax.grad(loss_ref)(tbl.table)
    np.testing.assert_allclose(np.asarray(g_kernel), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-4)


def test_pooled_lookup_stream_config_knobs():
    b, f, v, d = 16, 3, 400, 8
    key = jax.random.PRNGKey(12)
    ids = jax.random.randint(key, (b, f), 0, v)
    tbl = embeddings.init_table(key, v, d)
    s = embeddings.StreamConfig(block_v=64, block_d=8, chunk_e=32)
    out = embeddings.pooled_lookup(tbl, ids, stream=s)
    exp = ref.embedding_bag_ref(ids, tbl.table)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


def test_presence_counts_matches_scatter():
    cap = 1500
    ids = jax.random.randint(jax.random.PRNGKey(13), (7, 11), 0, cap)
    got = embeddings.presence_counts(ids, cap)
    exp = jnp.zeros((cap,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp))


# ---------------------------------------------------------------------------
# interpret-mode resolution (kernels/runtime)
# ---------------------------------------------------------------------------

def test_runtime_interpret_resolution(monkeypatch):
    # env var wins over the platform probe
    monkeypatch.setenv("REPRO_INTERPRET", "0")
    assert runtime.default_interpret() is False
    monkeypatch.setenv("REPRO_INTERPRET", "1")
    assert runtime.default_interpret() is True
    monkeypatch.delenv("REPRO_INTERPRET")
    # this container has no TPU -> interpret
    assert runtime.default_interpret() is True
    # set_interpret overrides, None restores auto-resolution
    runtime.set_interpret(False)
    try:
        assert runtime.resolve(None) is False
        assert runtime.resolve(True) is True     # per-call override wins
    finally:
        runtime.set_interpret(None)
    assert runtime.resolve(None) is True
    assert runtime.resolve(False) is False
