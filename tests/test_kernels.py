"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp
oracles in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag, embedding_bag_grad
from repro.kernels.fused_adagrad import fused_adagrad
from repro.kernels.gba_aggregate import gba_aggregate


@pytest.mark.parametrize("m,d", [(4, 100), (8, 2048), (16, 5000), (100, 97)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gba_aggregate(m, d, dtype):
    key = jax.random.PRNGKey(m * 1000 + d)
    g = jax.random.normal(key, (m, d), dtype)
    tokens = jax.random.randint(key, (m,), 0, 12)
    step = jnp.int32(10)
    out = gba_aggregate(g, tokens, step, iota=3)
    exp = ref.gba_aggregate_ref(g, tokens, step, iota=3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


def test_gba_aggregate_all_dropped_is_zero():
    g = jnp.ones((4, 64))
    tokens = jnp.zeros((4,), jnp.int32)
    out = gba_aggregate(g, tokens, jnp.int32(100), iota=3)
    assert float(jnp.abs(out).max()) == 0.0


def test_gba_aggregate_no_staleness_is_mean():
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 256))
    tokens = jnp.full((8,), 5, jnp.int32)
    out = gba_aggregate(g, tokens, jnp.int32(5), iota=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g.mean(0)),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("b,f,v,d", [(10, 5, 50, 8), (100, 26, 1000, 16),
                                     (256, 8, 500, 32), (33, 3, 101, 7)])
def test_embedding_bag_fwd(b, f, v, d):
    key = jax.random.PRNGKey(b)
    ids = jax.random.randint(key, (b, f), 0, v)
    table = jax.random.normal(key, (v, d), jnp.float32)
    out = embedding_bag(ids, table)
    exp = ref.embedding_bag_ref(ids, table)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,f,v,d", [(10, 5, 50, 8), (64, 26, 500, 16)])
def test_embedding_bag_grad(b, f, v, d):
    key = jax.random.PRNGKey(b + 7)
    ids = jax.random.randint(key, (b, f), 0, v)
    gout = jax.random.normal(key, (b, d), jnp.float32)
    gt, cnt = embedding_bag_grad(ids, gout, v)
    gt2, cnt2 = ref.embedding_bag_grad_ref(ids, gout, v)
    np.testing.assert_allclose(np.asarray(gt), np.asarray(gt2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cnt), np.asarray(cnt2))


def test_embedding_bag_grad_counts_sum():
    ids = jnp.array([[0, 0, 1], [2, 1, 1]], jnp.int32)
    gout = jnp.ones((2, 4), jnp.float32)
    _, cnt = embedding_bag_grad(ids, gout, 5)
    assert float(cnt.sum()) == 6.0
    np.testing.assert_allclose(np.asarray(cnt), [2, 3, 1, 0, 0])


@pytest.mark.parametrize("n", [100, 4096, 4097, 50_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_adagrad(n, dtype):
    key = jax.random.PRNGKey(n)
    p = jax.random.normal(key, (n,), dtype)
    g = jax.random.normal(jax.random.PRNGKey(n + 1), (n,), dtype)
    a = jnp.abs(jax.random.normal(jax.random.PRNGKey(n + 2), (n,)))
    new_p, new_a = fused_adagrad(p, g, a, 0.01)
    exp_p, exp_a = ref.fused_adagrad_ref(p, g, a, 0.01)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(new_p, np.float32),
                               np.asarray(exp_p, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(new_a), np.asarray(exp_a),
                               rtol=1e-5, atol=1e-5)


def test_kernel_tree_wrappers():
    from repro.kernels import ops
    from repro.core.gba import aggregate_dense
    key = jax.random.PRNGKey(3)
    grads = {"a": jax.random.normal(key, (8, 16, 4)),
             "b": {"c": jax.random.normal(key, (8, 30))}}
    tokens = jax.random.randint(key, (8,), 0, 6)
    step = jnp.int32(5)
    out = ops.gba_aggregate_tree(grads, tokens, step, iota=2)
    exp = aggregate_dense(grads, tokens, step, iota=2)
    for k in ("a",):
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(exp[k]),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out["b"]["c"]),
                               np.asarray(exp["b"]["c"]),
                               rtol=1e-5, atol=1e-6)


def _flash_ref(q, k, v, pos):
    import math
    hd = q.shape[-1]
    L = k.shape[1]
    scores = jnp.einsum("bngh,blnh->bngl", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    mask = jnp.arange(L) <= pos
    scores = jnp.where(mask[None, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bngl,blnh->bngh", p,
                      v.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("b,kv,g,hd,L,pos", [
    (2, 2, 4, 64, 1024, 1000), (1, 4, 1, 32, 512, 511),
    (3, 1, 8, 16, 2048, 37), (1, 8, 2, 128, 512, 200)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(b, kv, g, hd, L, pos, dtype):
    from repro.kernels.flash_decode import flash_decode
    key = jax.random.PRNGKey(b * 100 + kv)
    q = jax.random.normal(key, (b, kv, g, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, L, kv, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, L, kv, hd), dtype)
    out = flash_decode(q, k, v, pos)
    exp = _flash_ref(q, k, v, pos)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)
