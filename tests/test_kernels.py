"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp
oracles in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag, embedding_bag_grad
from repro.kernels.fused_adagrad import fused_adagrad
from repro.kernels.gba_aggregate import gba_aggregate


@pytest.mark.parametrize("m,d", [(4, 100), (8, 2048), (16, 5000), (100, 97)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gba_aggregate(m, d, dtype):
    key = jax.random.PRNGKey(m * 1000 + d)
    g = jax.random.normal(key, (m, d), dtype)
    tokens = jax.random.randint(key, (m,), 0, 12)
    step = jnp.int32(10)
    out = gba_aggregate(g, tokens, step, iota=3)
    exp = ref.gba_aggregate_ref(g, tokens, step, iota=3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


def test_gba_aggregate_all_dropped_is_zero():
    g = jnp.ones((4, 64))
    tokens = jnp.zeros((4,), jnp.int32)
    out = gba_aggregate(g, tokens, jnp.int32(100), iota=3)
    assert float(jnp.abs(out).max()) == 0.0


def test_gba_aggregate_no_staleness_is_mean():
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 256))
    tokens = jnp.full((8,), 5, jnp.int32)
    out = gba_aggregate(g, tokens, jnp.int32(5), iota=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g.mean(0)),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("b,f,v,d", [(10, 5, 50, 8), (100, 26, 1000, 16),
                                     (256, 8, 500, 32), (33, 3, 101, 7)])
def test_embedding_bag_fwd(b, f, v, d):
    key = jax.random.PRNGKey(b)
    ids = jax.random.randint(key, (b, f), 0, v)
    table = jax.random.normal(key, (v, d), jnp.float32)
    out = embedding_bag(ids, table)
    exp = ref.embedding_bag_ref(ids, table)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,f,v,d", [(10, 5, 50, 8), (64, 26, 500, 16),
                                     (33, 3, 613, 7)])
def test_embedding_bag_grad(b, f, v, d):
    """Sorted-scatter backward vs scatter-add oracle (non-block-multiple
    B, D and capacity included)."""
    key = jax.random.PRNGKey(b + 7)
    ids = jax.random.randint(key, (b, f), 0, v)
    gout = jax.random.normal(key, (b, d), jnp.float32)
    gt, cnt = embedding_bag_grad(ids, gout, v)
    gt2, cnt2 = ref.embedding_bag_grad_ref(ids, gout, v)
    np.testing.assert_allclose(np.asarray(gt), np.asarray(gt2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cnt), np.asarray(cnt2))


def test_embedding_bag_grad_counts_sum():
    ids = jnp.array([[0, 0, 1], [2, 1, 1]], jnp.int32)
    gout = jnp.ones((2, 4), jnp.float32)
    _, cnt = embedding_bag_grad(ids, gout, 5)
    assert float(cnt.sum()) == 6.0
    np.testing.assert_allclose(np.asarray(cnt), [2, 3, 1, 0, 0])


def test_embedding_bag_grad_all_ids_collide():
    """Every (b, f) entry hits the same row — the worst scatter-race case
    the sorted segment reduce must serialize correctly."""
    b, f, d, v = 16, 4, 8, 97
    ids = jnp.full((b, f), 13, jnp.int32)
    gout = jax.random.normal(jax.random.PRNGKey(0), (b, d), jnp.float32)
    gt, cnt = embedding_bag_grad(ids, gout, v)
    np.testing.assert_allclose(np.asarray(gt[13]),
                               np.asarray(gout.sum(0) * f),
                               rtol=1e-4, atol=1e-4)
    assert float(cnt[13]) == b * f
    assert float(jnp.abs(gt).sum()) == pytest.approx(
        float(jnp.abs(gt[13]).sum()))


def test_embedding_bag_grad_empty_segments():
    """IDs clustered at the top of a large table: every other vocab block's
    segment is empty and must come back exactly zero."""
    v, d = 4096, 8
    ids = jnp.array([[v - 1, v - 2], [v - 1, v - 3]], jnp.int32)
    gout = jnp.ones((2, d), jnp.float32)
    gt, cnt = embedding_bag_grad(ids, gout, v)
    assert float(jnp.abs(gt[:v - 3]).max()) == 0.0
    assert float(cnt[:v - 3].max()) == 0.0
    np.testing.assert_allclose(np.asarray(cnt[v - 3:]), [1, 1, 2])


def test_embedding_bag_grad_bf16_rows():
    b, f, v, d = 24, 6, 300, 16
    key = jax.random.PRNGKey(5)
    ids = jax.random.randint(key, (b, f), 0, v)
    gout = jax.random.normal(key, (b, d), jnp.bfloat16)
    gt, cnt = embedding_bag_grad(ids, gout, v)
    gt2, cnt2 = ref.embedding_bag_grad_ref(ids, gout, v)
    np.testing.assert_allclose(np.asarray(gt, np.float32),
                               np.asarray(gt2, np.float32),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(cnt), np.asarray(cnt2))


@pytest.mark.parametrize("n", [100, 4096, 4097, 20_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_adagrad(n, dtype):
    key = jax.random.PRNGKey(n)
    p = jax.random.normal(key, (n,), dtype)
    g = jax.random.normal(jax.random.PRNGKey(n + 1), (n,), dtype)
    a = jnp.abs(jax.random.normal(jax.random.PRNGKey(n + 2), (n,)))
    new_p, new_a = fused_adagrad(p, g, a, 0.01)
    exp_p, exp_a = ref.fused_adagrad_ref(p, g, a, 0.01)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(new_p, np.float32),
                               np.asarray(exp_p, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(new_a), np.asarray(exp_a),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,n", [(4, 100), (8, 2048), (16, 5000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gba_apply(m, n, dtype):
    """Fused aggregate+apply vs the two-pass oracle (non-block-multiple N,
    bf16 params included)."""
    from repro.kernels.gba_apply import gba_apply
    key = jax.random.PRNGKey(m * 100 + n)
    p = jax.random.normal(key, (n,), dtype)
    a = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (n,)))
    buf = jax.random.normal(jax.random.PRNGKey(2), (m, n), dtype)
    tokens = jax.random.randint(key, (m,), 0, 12)
    step = jnp.int32(10)
    new_p, new_a = gba_apply(p, a, buf, tokens, step, 0.01, iota=3)
    exp_p, exp_a = ref.gba_apply_ref(p, a, buf, tokens, step, 0.01, iota=3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(new_p, np.float32),
                               np.asarray(exp_p, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(new_a), np.asarray(exp_a),
                               rtol=tol, atol=tol)


def test_gba_apply_all_stale_is_identity_direction():
    """Every slot dropped -> aggregated grad 0 -> params unchanged, accum
    unchanged (g^2 = 0)."""
    from repro.kernels.gba_apply import gba_apply
    p = jax.random.normal(jax.random.PRNGKey(0), (300,))
    a = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (300,)))
    buf = jnp.ones((4, 300))
    tokens = jnp.zeros((4,), jnp.int32)
    new_p, new_a = gba_apply(p, a, buf, tokens, jnp.int32(100), 0.5, iota=3)
    np.testing.assert_allclose(np.asarray(new_p), np.asarray(p), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_a), np.asarray(a), rtol=1e-6)


def test_flat_buffer_roundtrip_matches_per_leaf_chain():
    """ravel -> gba_apply -> unravel  ==  per-leaf aggregate_dense +
    Adagrad: the flat-buffer fusion must be numerically a drop-in."""
    from repro.core.gba import (aggregate_dense, init_flat_buffer,
                                flat_buffer_push_and_maybe_apply)
    from repro.kernels import ref as kref
    key = jax.random.PRNGKey(7)
    params = {"w": jax.random.normal(key, (33, 9)),
              "b": {"c": jax.random.normal(jax.random.PRNGKey(8), (41,))}}
    m, iota, lr = 3, 2, 0.05
    layout, buf = init_flat_buffer(params, m)
    accum = jnp.full((layout.total,), 0.1, jnp.float32)
    grads = [jax.tree.map(
        lambda p, i=i: jax.random.normal(jax.random.PRNGKey(100 + i),
                                         p.shape), params)
        for i in range(m)]
    tokens = [0, 4, 5]

    # fused flat path
    pf, af = layout.ravel(params), accum
    for i in range(m):
        pf, af, applied, buf = flat_buffer_push_and_maybe_apply(
            buf, layout.ravel(grads[i]), jnp.int32(tokens[i]), pf, af, lr,
            iota=iota)
    assert bool(applied)
    fused_params = layout.unravel(pf)

    # per-leaf reference chain: stack -> aggregate_dense -> adagrad per leaf
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *grads)
    agg = aggregate_dense(stacked, jnp.asarray(tokens, jnp.int32),
                          jnp.int32(0), iota=iota)
    exp_tree = jax.tree.map(
        lambda p, g: kref.fused_adagrad_ref(
            p.reshape(-1), g.reshape(-1),
            jnp.full((p.size,), 0.1, jnp.float32), lr),
        params, agg)
    is2 = lambda t: isinstance(t, tuple)
    exp_p_tree = jax.tree.map(lambda t: t[0], exp_tree, is_leaf=is2)
    for new, exp in zip(jax.tree.leaves(fused_params),
                        jax.tree.leaves(exp_p_tree)):
        np.testing.assert_allclose(np.asarray(new).reshape(-1),
                                   np.asarray(exp).reshape(-1),
                                   rtol=1e-5, atol=1e-6)


def test_kernel_tree_wrappers():
    from repro.kernels import ops
    from repro.core.gba import aggregate_dense
    key = jax.random.PRNGKey(3)
    grads = {"a": jax.random.normal(key, (8, 16, 4)),
             "b": {"c": jax.random.normal(key, (8, 30))}}
    tokens = jax.random.randint(key, (8,), 0, 6)
    step = jnp.int32(5)
    out = ops.gba_aggregate_tree(grads, tokens, step, iota=2)
    exp = aggregate_dense(grads, tokens, step, iota=2)
    for k in ("a",):
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(exp[k]),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out["b"]["c"]),
                               np.asarray(exp["b"]["c"]),
                               rtol=1e-5, atol=1e-6)


def _flash_ref(q, k, v, pos):
    import math
    hd = q.shape[-1]
    L = k.shape[1]
    scores = jnp.einsum("bngh,blnh->bngl", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    mask = jnp.arange(L) <= pos
    scores = jnp.where(mask[None, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bngl,blnh->bngh", p,
                      v.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("b,kv,g,hd,L,pos", [
    (2, 2, 4, 64, 1024, 1000), (1, 4, 1, 32, 512, 511),
    (3, 1, 8, 16, 1024, 37), (1, 8, 2, 128, 512, 200)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(b, kv, g, hd, L, pos, dtype):
    from repro.kernels.flash_decode import flash_decode
    key = jax.random.PRNGKey(b * 100 + kv)
    q = jax.random.normal(key, (b, kv, g, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, L, kv, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, L, kv, hd), dtype)
    out = flash_decode(q, k, v, pos)
    exp = _flash_ref(q, k, v, pos)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)
