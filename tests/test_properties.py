"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (aggregate_dense, decay_weights, num_global_steps,
                        token_list)
from repro.metrics import auc
from repro.sim.cluster import ClusterSpec, simulate

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


@given(q=st.integers(1, 500), m=st.integers(1, 50))
def test_token_list_invariants(q, m):
    """Ascending; each value repeats M times (except possibly the last);
    K = ceil(Q/M) distinct values."""
    tl = np.asarray(token_list(q, m))
    assert len(tl) == q
    assert (np.diff(tl) >= 0).all()
    vals, counts = np.unique(tl, return_counts=True)
    assert len(vals) == num_global_steps(q, m)
    assert (counts[:-1] == m).all()
    assert counts[-1] <= m


@given(m=st.integers(1, 32), k=st.integers(0, 100), iota=st.integers(0, 20))
def test_decay_weights_binary_and_monotone(m, k, iota):
    tokens = np.sort(np.random.default_rng(m).integers(0, k + 1, m))
    w = np.asarray(decay_weights(jnp.asarray(tokens, jnp.int32),
                                 jnp.int32(k), iota))
    assert set(np.unique(w)) <= {0.0, 1.0}
    # fresher tokens never have smaller weight (tokens sorted ascending)
    assert (np.diff(w) >= 0).all()


@given(m=st.integers(2, 16), d=st.integers(1, 64),
       seed=st.integers(0, 2**16))
def test_aggregate_permutation_invariant(m, d, seed):
    """Buffer order must not matter (gradients + tokens permuted
    together)."""
    rng = np.random.default_rng(seed)
    g = rng.normal(size=(m, d)).astype(np.float32)
    tokens = rng.integers(0, 10, m).astype(np.int32)
    perm = rng.permutation(m)
    out1 = aggregate_dense({"w": jnp.asarray(g)}, jnp.asarray(tokens),
                           jnp.int32(9), iota=4)
    out2 = aggregate_dense({"w": jnp.asarray(g[perm])},
                           jnp.asarray(tokens[perm]), jnp.int32(9), iota=4)
    np.testing.assert_allclose(np.asarray(out1["w"]),
                               np.asarray(out2["w"]), rtol=1e-5, atol=1e-6)


@given(m=st.integers(1, 16), d=st.integers(1, 64),
       scale=st.floats(0.1, 10.0), seed=st.integers(0, 2**16))
def test_aggregate_linear_in_grads(m, d, scale, seed):
    rng = np.random.default_rng(seed)
    g = rng.normal(size=(m, d)).astype(np.float32)
    tokens = rng.integers(0, 5, m).astype(np.int32)
    a = aggregate_dense({"w": jnp.asarray(g * scale)}, jnp.asarray(tokens),
                        jnp.int32(4), iota=2)
    b = aggregate_dense({"w": jnp.asarray(g)}, jnp.asarray(tokens),
                        jnp.int32(4), iota=2)
    np.testing.assert_allclose(np.asarray(a["w"]),
                               scale * np.asarray(b["w"]),
                               rtol=1e-4, atol=1e-5)


@given(n=st.integers(10, 200), seed=st.integers(0, 2**16))
def test_auc_against_bruteforce(n, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 2, n).astype(np.float32)
    scores = rng.normal(size=n)
    if labels.sum() in (0, n):
        labels[0] = 1 - labels[0]
    got = auc(labels, scores)
    pos = scores[labels > 0.5]
    neg = scores[labels < 0.5]
    cmp = (pos[:, None] > neg[None, :]).sum() \
        + 0.5 * (pos[:, None] == neg[None, :]).sum()
    expect = cmp / (len(pos) * len(neg))
    assert abs(got - expect) < 1e-9


@given(nw=st.integers(2, 24), nb=st.integers(24, 120),
       mode=st.sampled_from(["sync", "async", "bsp", "gba", "hop_bs",
                             "hop_bw"]),
       seed=st.integers(0, 1000))
def test_schedule_invariants(nw, nb, mode, seed):
    """Every scheduled batch appears at most once; dispatch step never
    exceeds the apply step; GBA kept staleness <= iota."""
    spec = ClusterSpec(num_workers=nw, straggler_frac=0.3, jitter=0.2,
                       seed=seed)
    sched = simulate(spec, mode, nb, 64, buffer_size=nw, iota=3,
                     b1=2, b2=max(2, nw // 2), b3=1)
    seen = set()
    for k, slots in enumerate(sched.steps):
        for s in slots:
            assert s.batch_index not in seen
            seen.add(s.batch_index)
            assert s.dispatch_step <= k
            if mode == "gba" and s.weight > 0:
                assert k - s.token <= 3
    assert len(seen) <= nb


@given(m=st.integers(1, 12), b=st.integers(1, 64))
def test_global_batch_preserved(m, b):
    """The tuning-free contract: G_a = B_a * M regardless of worker count
    (paper Sec. 4.1)."""
    from repro.configs.base import GBAConfig
    g = GBAConfig(local_batch=b, buffer_size=m)
    assert g.global_batch == b * m
    assert g.resolved_num_workers == m
